//! Golden tests for every lint rule: a positive fixture that must fire
//! at an exact `file:line`, a negative fixture that must stay silent,
//! and a suppression fixture whose `lint:allow` moves the finding into
//! the suppressed list. Fixtures live under `tests/fixtures/` and are
//! linted under *synthetic* relative paths so the path-gated rules
//! (panic-freedom, determinism, dispatch) see the tree layout they
//! expect. The suite ends with the self-check: the real `rust/src` tree
//! must lint clean against both `docs/FORMAT.md` and `docs/PROTOCOL.md`.

use std::fs;
use std::path::{Path, PathBuf};

use mcnc_lint::{lint_sources, report, source_file, Report};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// Lint one fixture under a synthetic relative path, no spec.
fn lint_one(rel: &str, fixture_name: &str) -> Report {
    lint_sources(&[source_file(rel, &fixture(fixture_name))], &[])
}

fn hits(list: &[mcnc_lint::Finding], rule: &str) -> Vec<(String, usize)> {
    list.iter().filter(|f| f.rule == rule).map(|f| (f.file.clone(), f.line)).collect()
}

fn loc(file: &str, line: usize) -> (String, usize) {
    (file.to_string(), line)
}

// ------------------------------------------------------ unsafe-discipline

#[test]
fn unsafe_discipline_positive() {
    let rep = lint_one("mcnc/generator.rs", "unsafe_discipline/positive.rs");
    assert_eq!(hits(&rep.findings, "unsafe-discipline"), [loc("mcnc/generator.rs", 2)]);
}

#[test]
fn unsafe_discipline_negative() {
    let rep = lint_one("mcnc/generator.rs", "unsafe_discipline/negative.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn unsafe_discipline_suppressed() {
    let rep = lint_one("mcnc/generator.rs", "unsafe_discipline/suppressed.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(hits(&rep.suppressed, "unsafe-discipline"), [loc("mcnc/generator.rs", 3)]);
}

// --------------------------------------------------- dispatch-containment

#[test]
fn dispatch_positive() {
    let rep = lint_one("runtime/session.rs", "dispatch/positive.rs");
    let want = [
        loc("runtime/session.rs", 1), // core::arch import
        loc("runtime/session.rs", 3), // #[target_feature]
        loc("runtime/session.rs", 8), // is_x86_feature_detected!
        loc("runtime/session.rs", 9), // scalar:: reference
    ];
    assert_eq!(hits(&rep.findings, "dispatch-containment"), want);
}

#[test]
fn dispatch_negative_inside_kernel() {
    // the same constructs are legal in mcnc/kernel/{x86,neon}.rs
    let rep = lint_one("mcnc/kernel/x86.rs", "dispatch/negative.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn dispatch_negative_inside_i8_kernels() {
    // the int8 compressed-domain kernels are sanctioned intrinsics files
    for rel in ["mcnc/kernel/x86_i8.rs", "mcnc/kernel/neon_i8.rs"] {
        let rep = lint_one(rel, "dispatch/negative_i8.rs");
        assert!(rep.findings.is_empty(), "{rel}: {:?}", rep.findings);
    }
}

#[test]
fn dispatch_fires_for_i8_constructs_outside_kernel() {
    // the same maddubs-style constructs anywhere else must fire
    let rep = lint_one("codec/container.rs", "dispatch/negative_i8.rs");
    let want = [
        loc("codec/container.rs", 1), // core::arch import
        loc("codec/container.rs", 3), // #[target_feature]
    ];
    assert_eq!(hits(&rep.findings, "dispatch-containment"), want);
}

#[test]
fn dispatch_suppressed() {
    let rep = lint_one("runtime/session.rs", "dispatch/suppressed.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(hits(&rep.suppressed, "dispatch-containment"), [loc("runtime/session.rs", 2)]);
}

// ----------------------------------------------------------- panic-freedom

#[test]
fn panic_freedom_positive() {
    let rep = lint_one("coordinator/server.rs", "panic_freedom/positive.rs");
    let want = [loc("coordinator/server.rs", 2), loc("coordinator/server.rs", 4)];
    assert_eq!(hits(&rep.findings, "panic-freedom"), want);
}

#[test]
fn panic_freedom_negative_test_code_exempt() {
    // .unwrap() inside #[cfg(test)] mod tests is allowed
    let rep = lint_one("coordinator/server.rs", "panic_freedom/negative.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn panic_freedom_suppressed() {
    let rep = lint_one("coordinator/router.rs", "panic_freedom/suppressed.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(hits(&rep.suppressed, "panic-freedom"), [loc("coordinator/router.rs", 3)]);
}

#[test]
fn panic_freedom_ignores_other_files() {
    // the same code outside coordinator/{shard,server,router}.rs is fine
    let rep = lint_one("mcnc/generator.rs", "panic_freedom/positive.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn panic_freedom_applies_to_net() {
    // the socket front-end is a serving path too: net/*.rs is gated
    let rep = lint_one("net/listener.rs", "panic_freedom/positive.rs");
    let want = [loc("net/listener.rs", 2), loc("net/listener.rs", 4)];
    assert_eq!(hits(&rep.findings, "panic-freedom"), want);
}

#[test]
fn panic_freedom_applies_to_qserve() {
    // the quantized-panel engine's cold-fill path serves live requests
    let rep = lint_one("coordinator/qserve.rs", "panic_freedom/positive.rs");
    let want = [loc("coordinator/qserve.rs", 2), loc("coordinator/qserve.rs", 4)];
    assert_eq!(hits(&rep.findings, "panic-freedom"), want);
}

// ------------------------------------------------------------- determinism

#[test]
fn determinism_positive() {
    let rep = lint_one("codec/rans.rs", "determinism/positive.rs");
    let want = [loc("codec/rans.rs", 1), loc("codec/rans.rs", 4)];
    assert_eq!(hits(&rep.findings, "determinism"), want);
}

#[test]
fn determinism_negative_seeded_rng() {
    let rep = lint_one("codec/rans.rs", "determinism/negative.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn determinism_suppressed() {
    let rep = lint_one("coordinator/chaos.rs", "determinism/suppressed.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(hits(&rep.suppressed, "determinism"), [loc("coordinator/chaos.rs", 3)]);
}

#[test]
fn determinism_applies_to_protocol_codec() {
    // MCNP1 encode/deframe must be host-independent, like the container
    let rep = lint_one("net/protocol.rs", "determinism/positive.rs");
    let want = [loc("net/protocol.rs", 1), loc("net/protocol.rs", 4)];
    assert_eq!(hits(&rep.findings, "determinism"), want);
}

#[test]
fn determinism_exempts_net_listener() {
    // the listener owns the clock (deadline anchoring, drain budget)
    let rep = lint_one("net/listener.rs", "determinism/positive.rs");
    assert!(hits(&rep.findings, "determinism").is_empty(), "{:?}", rep.findings);
}

// ---------------------------------------------------------- metrics-naming

#[test]
fn metrics_naming_positive() {
    let rep = lint_one("coordinator/metrics.rs", "metrics_naming/positive.rs");
    let want = [
        loc("coordinator/metrics.rs", 1),  // AtomicU64 import
        loc("coordinator/metrics.rs", 4),  // AtomicU64 field
        loc("coordinator/metrics.rs", 8),  // mcnc_Bad-Name
        loc("coordinator/metrics.rs", 10), // 9leading_digit
    ];
    assert_eq!(hits(&rep.findings, "metrics-naming"), want);
}

#[test]
fn metrics_naming_negative_handles_and_tests_exempt() {
    let rep = lint_one("coordinator/server.rs", "metrics_naming/negative.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn metrics_naming_atomics_fine_outside_coordinator() {
    // the AtomicU64 ban is scoped to coordinator/; name checks still apply
    let rep = lint_one("obs/registry.rs", "metrics_naming/positive.rs");
    let want = [loc("obs/registry.rs", 8), loc("obs/registry.rs", 10)];
    assert_eq!(hits(&rep.findings, "metrics-naming"), want);
}

#[test]
fn metrics_naming_suppressed() {
    let rep = lint_one("coordinator/metrics.rs", "metrics_naming/suppressed.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(hits(&rep.suppressed, "metrics-naming"), [loc("coordinator/metrics.rs", 3)]);
}

// ------------------------------------------------------------- wire-format

#[test]
fn wire_format_clean() {
    let spec = fixture("wire_format/spec.md");
    let sf = source_file("codec/container.rs", &fixture("wire_format/code_ok.rs"));
    let rep = lint_sources(&[sf], &[("spec.md", &spec)]);
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn wire_format_drift_points_at_code_line() {
    let spec = fixture("wire_format/spec.md");
    let sf = source_file("codec/container.rs", &fixture("wire_format/code_drift.rs"));
    let rep = lint_sources(&[sf], &[("spec.md", &spec)]);
    let got = hits(&rep.findings, "wire-format");
    assert_eq!(got, [loc("codec/container.rs", 5)]);
    assert!(rep.findings[0].msg.contains("MAX_DIMS"), "{}", rep.findings[0].msg);
}

#[test]
fn wire_format_protocol_clean() {
    // a spec path ending in PROTOCOL.md binds to net/ instead of codec/
    let spec = fixture("wire_format/proto_spec.md");
    let sf = source_file("net/protocol.rs", &fixture("wire_format/proto_code_ok.rs"));
    let rep = lint_sources(&[sf], &[("docs/PROTOCOL.md", &spec)]);
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn wire_format_protocol_drift_points_at_code_line() {
    let spec = fixture("wire_format/proto_spec.md");
    let sf = source_file("net/protocol.rs", &fixture("wire_format/proto_code_drift.rs"));
    let rep = lint_sources(&[sf], &[("docs/PROTOCOL.md", &spec)]);
    let got = hits(&rep.findings, "wire-format");
    assert_eq!(got, [loc("net/protocol.rs", 10)]);
    assert!(rep.findings[0].msg.contains("MSG_PONG"), "{}", rep.findings[0].msg);
    assert!(rep.findings[0].msg.contains("PROTOCOL.md"), "{}", rep.findings[0].msg);
}

#[test]
fn wire_format_protocol_suppression_is_accounted() {
    let spec = fixture("wire_format/proto_spec.md");
    let sf = source_file("net/protocol.rs", &fixture("wire_format/proto_code_suppressed.rs"));
    let rep = lint_sources(&[sf], &[("docs/PROTOCOL.md", &spec)]);
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(hits(&rep.suppressed, "wire-format"), [loc("net/protocol.rs", 12)]);
}

#[test]
fn wire_format_both_specs_check_disjoint_subtrees() {
    // FORMAT.md sees only codec/, PROTOCOL.md only net/ — running both
    // over both trees at once stays clean and cross-talk-free
    let spec_c = fixture("wire_format/spec.md");
    let spec_n = fixture("wire_format/proto_spec.md");
    let files = [
        source_file("codec/container.rs", &fixture("wire_format/code_ok.rs")),
        source_file("net/protocol.rs", &fixture("wire_format/proto_code_ok.rs")),
    ];
    let rep =
        lint_sources(&files, &[("docs/FORMAT.md", &spec_c), ("docs/PROTOCOL.md", &spec_n)]);
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

// ------------------------------------------------------------ JSON report

#[test]
fn report_json_shape() {
    let rep = lint_one("coordinator/server.rs", "panic_freedom/positive.rs");
    let json = report::to_json(&rep);
    assert!(json.contains("\"files_scanned\": 1"), "{json}");
    assert!(json.contains("\"total_findings\": 2"), "{json}");
    assert!(json.contains("\"panic-freedom\": { \"findings\": 2, \"suppressed\": 0 }"), "{json}");
    assert!(json.contains("\"file\": \"coordinator/server.rs\""), "{json}");
    for rule in report::RULES {
        assert!(json.contains(&format!("\"{rule}\"")), "missing rule {rule} in {json}");
    }
}

// ------------------------------------------------------------ CLI behavior

#[test]
fn cli_exit_code_and_report() {
    let tmp = std::env::temp_dir().join(format!("mcnc-lint-cli-{}", std::process::id()));
    let src = tmp.join("coordinator");
    fs::create_dir_all(&src).expect("mkdir fixture tree");
    let bad = "pub fn f(v: Option<u8>) -> u8 {\n    v.unwrap()\n}\n";
    fs::write(src.join("server.rs"), bad).expect("write fixture");
    let report_path = tmp.join("r.json");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_mcnc-lint"))
        .arg("--report")
        .arg(&report_path)
        .arg(&tmp)
        .output()
        .expect("run mcnc-lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(1), "stdout: {stdout}");
    assert!(stdout.contains("coordinator/server.rs:2: [panic-freedom]"), "{stdout}");
    let json = fs::read_to_string(&report_path).expect("report written");
    assert!(json.contains("\"total_findings\": 1"), "{json}");
    let _ = fs::remove_dir_all(&tmp);
}

// --------------------------------------------------------- tree self-check

fn repo_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../..").join(rel)
}

#[test]
fn real_tree_lints_clean() {
    let root = repo_path("rust/src");
    let specs = [repo_path("docs/FORMAT.md"), repo_path("docs/PROTOCOL.md")];
    let rep = mcnc_lint::lint_tree(&root, &specs).expect("walk rust/src");
    let msgs: Vec<String> = rep
        .findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg))
        .collect();
    assert!(msgs.is_empty(), "unexpected lint findings:\n{}", msgs.join("\n"));
    assert!(rep.files_scanned > 40, "scanned only {} files", rep.files_scanned);
}
