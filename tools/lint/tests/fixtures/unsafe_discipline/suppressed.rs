pub fn peek(xs: &[u8]) -> u8 {
    // lint:allow(unsafe-discipline): audited in review, comment pending
    unsafe { *xs.as_ptr() }
}
