pub fn peek(xs: &[u8]) -> u8 {
    // SAFETY: caller guarantees xs is non-empty.
    unsafe { *xs.as_ptr() }
}

// SAFETY: callers must pass a valid, initialized pointer.
#[inline]
pub unsafe fn peek_raw(p: *const u8) -> u8 {
    // SAFETY: contract inherited from the function's safety docs.
    unsafe { *p }
}
