pub struct Stats {
    // lint:allow(metrics-naming): scratch counter local to this test harness
    hits: std::sync::atomic::AtomicU64,
}
