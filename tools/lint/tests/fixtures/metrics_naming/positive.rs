use std::sync::atomic::AtomicU64;

pub struct Stats {
    hits: AtomicU64,
}

pub fn register(r: &Registry) {
    let _c = r.counter("mcnc_Bad-Name", &[]);
    let _g = r.gauge("mcnc_cache_used_bytes", &[]);
    let _h = r.histogram("9leading_digit", &[]);
}
