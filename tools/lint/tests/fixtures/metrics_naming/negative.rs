use crate::obs::{Counter, IdGen};

pub struct Stats {
    hits: Counter,
    next_id: IdGen,
}

pub fn register(r: &Registry) {
    let _c = r.counter("mcnc_serve_requests_total", &[("shard", "0")]);
    let _g = r.gauge("mcnc_cache_used_bytes", &[]);
    let _h = r.histogram("mcnc_serve_queue_wait_us", &[]);
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::AtomicU64;

    #[test]
    fn raw_atomics_are_fine_in_tests() {
        let c = AtomicU64::new(0);
        let _ = c;
        let _x = registry().counter("Test-Only-Name", &[]);
    }
}
