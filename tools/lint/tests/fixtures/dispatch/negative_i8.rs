use core::arch::x86_64::{_mm256_maddubs_epi16, _mm256_sign_epi8};

#[target_feature(enable = "avx2")]
// SAFETY: fixture only; never executed.
pub unsafe fn maddubs_probe() {}
