use core::arch::x86_64::_mm256_setzero_ps;

#[target_feature(enable = "avx2")]
// SAFETY: fixture only; never executed.
pub unsafe fn zero() {}

pub fn pick() {
    if is_x86_feature_detected!("avx2") {
        scalar::noop();
    }
}
