// lint:allow(dispatch-containment): fixture demonstrates suppression
use core::arch::x86_64::_mm256_setzero_ps;
