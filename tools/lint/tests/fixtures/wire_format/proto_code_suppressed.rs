pub const NET_MAGIC: &[u8; 6] = b"PROT1\n";
pub const NET_VERSION: u64 = 1;
pub const NET_MAX_FRAME: usize = 1 << 20;
pub const MAX_TOKENS: usize = 1 << 16;
pub const MAX_ERR_LEN: usize = 4096;
pub const MSG_REQ: u8 = 1;
pub const MSG_REPLY_OK: u8 = 2;
pub const MSG_REPLY_ERR: u8 = 3;
pub const MSG_PING: u8 = 4;
// lint:allow(wire-format): fixture proving suppression accounting only —
// real drift must be fixed in code or spec, never silenced
pub const MSG_PONG: u8 = 7;
pub const MSG_CONN_ERR: u8 = 6;
pub const ERR_REJECTED: u8 = 1;
pub const ERR_FAILED: u8 = 2;
pub const ERR_DEADLINE: u8 = 3;
