pub fn boot_id() -> u64 {
    // lint:allow(determinism): observability label only, never in the schedule
    let t = std::time::SystemTime::now();
    (format!("{t:?}").len()) as u64
}
