pub struct Lcg(u64);

impl Lcg {
    pub fn new(seed: u64) -> Self {
        Lcg(seed)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }
}
