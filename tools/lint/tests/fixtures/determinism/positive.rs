use std::time::{Instant, SystemTime};

pub fn stamp() -> u128 {
    Instant::now().elapsed().as_nanos()
}
