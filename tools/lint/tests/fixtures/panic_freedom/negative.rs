use anyhow::{anyhow, Result};

pub fn run(v: Option<u32>) -> Result<u32> {
    v.ok_or_else(|| anyhow!("missing"))
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_here() {
        assert_eq!(Some(3u32).unwrap(), 3);
    }
}
