pub fn last(xs: &[u32]) -> u32 {
    // lint:allow(panic-freedom): slice verified non-empty by caller
    xs.last().copied().unwrap()
}
