pub fn run(v: Option<u32>) -> u32 {
    let x = v.unwrap();
    if x > 9 {
        panic!("too big");
    }
    x
}
