//! CLI entry point.
//!
//! ```text
//! mcnc-lint [--report PATH] [--spec PATH]... ROOT
//! ```
//!
//! Lints every `.rs` file under `ROOT`, prints `file:line: [rule] msg`
//! per finding, writes a JSON report (default `LINT_report.json`), and
//! exits 0 when clean, 1 on unsuppressed findings, 2 on usage or IO
//! errors. `--spec` is repeatable (a path ending in `PROTOCOL.md` is
//! cross-checked against `net/`, any other against `codec/`). Without
//! it, `docs/FORMAT.md` and `docs/PROTOCOL.md` are located by walking
//! up from `ROOT`, so `cargo run -p mcnc-lint -- rust/src` from the
//! repo root does the right thing.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use mcnc_lint::{lint_tree, report};

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut report_path = PathBuf::from("LINT_report.json");
    let mut specs: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--report" => match args.next() {
                Some(p) => report_path = PathBuf::from(p),
                None => return usage("--report needs a path"),
            },
            "--spec" => match args.next() {
                Some(p) => specs.push(PathBuf::from(p)),
                None => return usage("--spec needs a path"),
            },
            "--help" | "-h" => {
                println!("usage: mcnc-lint [--report PATH] [--spec PATH]... ROOT");
                return ExitCode::SUCCESS;
            }
            _ if root.is_none() => root = Some(PathBuf::from(a)),
            _ => return usage("unexpected extra argument"),
        }
    }
    let Some(root) = root else {
        return usage("missing ROOT directory");
    };
    if specs.is_empty() {
        for name in ["docs/FORMAT.md", "docs/PROTOCOL.md"] {
            match find_spec(&root, name) {
                Some(p) => specs.push(p),
                None => eprintln!(
                    "mcnc-lint: warning: no {name} found; its wire-format check skipped"
                ),
            }
        }
    }
    let rep = match lint_tree(&root, &specs) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("mcnc-lint: {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for f in &rep.findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
    }
    println!(
        "mcnc-lint: {} finding(s), {} suppressed, {} files scanned",
        rep.findings.len(),
        rep.suppressed.len(),
        rep.files_scanned
    );
    if let Err(e) = std::fs::write(&report_path, report::to_json(&rep)) {
        eprintln!("mcnc-lint: cannot write {}: {e}", report_path.display());
        return ExitCode::from(2);
    }
    if rep.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("mcnc-lint: {msg}");
    eprintln!("usage: mcnc-lint [--report PATH] [--spec PATH]... ROOT");
    ExitCode::from(2)
}

/// Walk up from `ROOT` looking for `name` (e.g. `docs/FORMAT.md`), so
/// the spec is found no matter which subtree is being linted.
fn find_spec(root: &Path, name: &str) -> Option<PathBuf> {
    let start = root.canonicalize().ok()?;
    let mut dir: Option<&Path> = Some(start.as_path());
    while let Some(d) = dir {
        let cand = d.join(name);
        if cand.is_file() {
            return Some(cand);
        }
        dir = d.parent();
    }
    None
}
