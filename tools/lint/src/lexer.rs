//! A small comment/string-aware Rust lexer: just enough structure for
//! line-oriented lint rules. Each source line is split into its *code*
//! text (string/char literal contents masked out, delimiters kept) and
//! its *comment* text (line, block, and doc comments), so rules can match
//! code without tripping over `"unsafe"` inside a string or an example in
//! a doc comment. Handles nested block comments, raw strings (`r#"…"#`),
//! byte strings, and char-literal vs lifetime disambiguation.

/// One source line, split into masked code and comment text.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Code with string/char contents removed (delimiters preserved).
    pub code: String,
    /// Concatenated comment text of the line (line, block, doc).
    pub comment: String,
}

enum State {
    Normal,
    LineComment,
    /// Block comment with nesting depth.
    BlockComment(u32),
    /// Inside a `"…"` or `b"…"` string.
    Str,
    /// Inside a raw string; payload is the hash count of the opener.
    RawStr(usize),
    /// Inside a char or byte-char literal.
    CharLit,
}

/// Split `src` into per-line [`Line`]s. Never fails: unterminated
/// constructs simply run to end of input, which is the right behavior for
/// a linter that must not crash on the code it is judging.
pub fn lex(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Normal;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            lines.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                let c2 = chars.get(i + 1).copied();
                if c == '/' && c2 == Some('/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && c2 == Some('*') {
                    state = State::BlockComment(1);
                    code.push(' ');
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    code.push('"');
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    if let Some((skip, raw, hashes)) = string_prefix(&chars, i) {
                        code.extend(&chars[i..i + skip]);
                        i += skip;
                        state = if raw { State::RawStr(hashes) } else { State::Str };
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    let c1 = chars.get(i + 1).copied();
                    let cc = chars.get(i + 2).copied();
                    code.push('\'');
                    i += 1;
                    let lifetime = c1.map(|ch| ch.is_alphabetic() || ch == '_').unwrap_or(false)
                        && cc != Some('\'');
                    if !lifetime {
                        state = State::CharLit;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let c2 = chars.get(i + 1).copied();
                if c == '/' && c2 == Some('*') {
                    state = State::BlockComment(depth + 1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '*' && c2 == Some('/') {
                    i += 2;
                    if depth == 1 {
                        state = State::Normal;
                    } else {
                        state = State::BlockComment(depth - 1);
                        comment.push_str("*/");
                    }
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '"' {
                        code.push('"');
                        state = State::Normal;
                    }
                    i += 1;
                }
            }
            State::RawStr(h) => {
                let closes = c == '"'
                    && chars
                        .get(i + 1..i + 1 + h)
                        .map(|tail| tail.iter().all(|&x| x == '#'))
                        .unwrap_or(false);
                if closes {
                    code.push('"');
                    for _ in 0..h {
                        code.push('#');
                    }
                    i += 1 + h;
                    state = State::Normal;
                } else {
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '\'' {
                        code.push('\'');
                        state = State::Normal;
                    }
                    i += 1;
                }
            }
        }
    }
    lines.push(Line { code, comment });
    lines
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// Match a string-literal prefix (`b"`, `r"`, `r#"`, `br##"` …) starting
/// at `i`. Returns `(chars consumed incl. the opening quote, is_raw,
/// hash_count)`, or `None` when `i` does not start a string.
fn string_prefix(chars: &[char], i: usize) -> Option<(usize, bool, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    let raw = chars.get(j) == Some(&'r');
    if raw {
        j += 1;
    }
    let mut hashes = 0usize;
    while raw && chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if j > i && chars.get(j) == Some(&'"') {
        Some((j - i + 1, raw, hashes))
    } else {
        None
    }
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offset of the first occurrence of `word` in `code` that is not
/// part of a longer identifier, or `None`.
pub fn find_token(code: &str, word: &str) -> Option<usize> {
    let b = code.as_bytes();
    let w = word.as_bytes();
    if w.is_empty() || b.len() < w.len() {
        return None;
    }
    for (k, win) in b.windows(w.len()).enumerate() {
        if win != w {
            continue;
        }
        let before_ok = k == 0 || !is_ident_byte(b[k - 1]);
        let after = k + w.len();
        let after_ok = after >= b.len() || !is_ident_byte(b[after]);
        if before_ok && after_ok {
            return Some(k);
        }
    }
    None
}

/// Whether `code` contains `word` as a standalone token.
pub fn has_token(code: &str, word: &str) -> bool {
    find_token(code, word).is_some()
}

/// A line that carries comment text and no code.
pub fn comment_only(line: &Line) -> bool {
    line.code.trim().is_empty() && !line.comment.trim().is_empty()
}

fn brace_delta(code: &str) -> i64 {
    let open = code.bytes().filter(|&b| b == b'{').count() as i64;
    let close = code.bytes().filter(|&b| b == b'}').count() as i64;
    open - close
}

/// Per-line flags: inside a `#[cfg(test)]`-gated braced item (typically a
/// `mod tests { … }`). Tracked by brace counting on the masked code, so
/// braces in strings or comments can't skew the depth.
pub fn test_regions(lines: &[Line]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut depth: i64 = 0;
    // depths at which currently-open test regions started
    let mut stack: Vec<i64> = Vec::new();
    // saw a #[cfg(..test..)] attribute, waiting for the gated item
    let mut pending = false;
    for (idx, line) in lines.iter().enumerate() {
        let t = line.code.trim();
        if !stack.is_empty() {
            in_test[idx] = true;
        }
        let cfg_test = t.find("#[cfg(").map(|k| has_token(&t[k..], "test")).unwrap_or(false);
        if cfg_test {
            pending = true;
            depth += brace_delta(t);
            continue;
        }
        if pending && !t.is_empty() {
            if t.starts_with("#[") {
                depth += brace_delta(t);
                continue;
            }
            let ob = t.find('{');
            let sc = t.find(';');
            let opens_region = match (ob, sc) {
                (Some(o), Some(s)) => o < s,
                (Some(_), None) => true,
                _ => false,
            };
            if opens_region {
                in_test[idx] = true;
                stack.push(depth);
                pending = false;
            } else if sc.is_some() {
                // a single `;`-terminated gated item (use, type alias…)
                in_test[idx] = true;
                pending = false;
            }
        }
        depth += brace_delta(t);
        while stack.last().map(|&d| depth <= d).unwrap_or(false) {
            stack.pop();
            in_test[idx] = true;
        }
    }
    in_test
}
