//! Machine-readable report writer (`LINT_report.json`). The JSON is
//! hand-rolled — the crate is dependency-free by design — with a stable
//! shape: scan totals, per-rule counts, then the finding lists.

use crate::{Finding, Report};

/// Every rule ID, in catalog order (see `docs/LINTS.md`).
pub const RULES: [&str; 6] = [
    crate::rules::unsafe_discipline::ID,
    crate::rules::dispatch::ID,
    crate::rules::panic_freedom::ID,
    crate::rules::determinism::ID,
    crate::rules::metrics_naming::ID,
    crate::rules::wire_format::ID,
];

/// Serialize a [`Report`] as pretty-printed JSON.
pub fn to_json(r: &Report) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"files_scanned\": {},\n", r.files_scanned));
    s.push_str(&format!("  \"total_findings\": {},\n", r.findings.len()));
    s.push_str(&format!("  \"total_suppressed\": {},\n", r.suppressed.len()));
    s.push_str("  \"rules\": {\n");
    for (i, rule) in RULES.iter().enumerate() {
        let nf = r.findings.iter().filter(|f| f.rule == *rule).count();
        let ns = r.suppressed.iter().filter(|f| f.rule == *rule).count();
        let comma = if i + 1 < RULES.len() { "," } else { "" };
        s.push_str(&format!(
            "    \"{rule}\": {{ \"findings\": {nf}, \"suppressed\": {ns} }}{comma}\n"
        ));
    }
    s.push_str("  },\n");
    push_list(&mut s, "findings", &r.findings, ",");
    push_list(&mut s, "suppressed", &r.suppressed, "");
    s.push_str("}\n");
    s
}

fn push_list(s: &mut String, key: &str, items: &[Finding], trail: &str) {
    if items.is_empty() {
        s.push_str(&format!("  \"{key}\": []{trail}\n"));
        return;
    }
    s.push_str(&format!("  \"{key}\": [\n"));
    for (i, f) in items.iter().enumerate() {
        s.push_str("    { \"file\": \"");
        s.push_str(&escape(&f.file));
        s.push_str("\", \"line\": ");
        s.push_str(&f.line.to_string());
        s.push_str(", \"rule\": \"");
        s.push_str(f.rule);
        s.push_str("\", \"message\": \"");
        s.push_str(&escape(&f.msg));
        s.push_str("\" }");
        if i + 1 < items.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str(&format!("  ]{trail}\n"));
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
