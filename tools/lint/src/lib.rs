//! `mcnc-lint`: repo-specific static analysis for the MCNC codebase.
//!
//! The compiler cannot see the invariants this repo's claims rest on —
//! bit-identical reconstruction across ISAs, host-independent MCNC2 wire
//! bytes, seed-deterministic fault schedules — so this crate enforces
//! them mechanically. Six rules (catalog: `docs/LINTS.md`):
//!
//! * `unsafe-discipline` — every `unsafe` needs an adjacent `// SAFETY:`;
//! * `dispatch-containment` — ISA intrinsics stay in `mcnc/kernel/`;
//! * `panic-freedom` — no `unwrap`/`expect`/`panic!` on serving paths;
//! * `determinism` — no wall-clock/ambient randomness in `codec/`, chaos;
//! * `metrics-naming` — coordinator counters go through the obs registry,
//!   metric names are snake_case;
//! * `wire-format` — `docs/FORMAT.md` constants match `codec/` constants,
//!   and `docs/PROTOCOL.md` constants match `net/` constants (the spec
//!   path picks the binding: `*PROTOCOL.md` ↔ `net/`, else ↔ `codec/`).
//!
//! Findings carry `file:line` and a rule ID, and can be silenced inline
//! with `// lint:allow(<rule>): <why>` on the offending line or the
//! comment block directly above it. The library is IO-free except for
//! [`lint_tree`]; tests drive [`lint_sources`] on in-memory fixtures.

use std::collections::HashMap;
use std::io;
use std::path::{Path, PathBuf};

pub mod lexer;
pub mod report;
pub mod rules;

/// One lint hit, anchored to a file and 1-based line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Slash-separated path relative to the scan root (or the spec path).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule ID (see [`report::RULES`]).
    pub rule: &'static str,
    /// Human-readable description of the violation.
    pub msg: String,
}

/// A lexed source file plus the metadata rules key off.
pub struct SourceFile {
    /// Slash-separated path relative to the scan root — rules are
    /// path-gated on this, not on where the file physically lives.
    pub rel: String,
    /// Raw text (the wire-format rule reads string literals the lexer
    /// masks out of `lines`).
    pub raw: String,
    /// Per-line masked code + comment text.
    pub lines: Vec<lexer::Line>,
    /// Per-line `#[cfg(test)]`-region flags.
    pub in_test: Vec<bool>,
}

/// Lex `raw` into a [`SourceFile`] scanned under the path `rel`.
pub fn source_file(rel: &str, raw: &str) -> SourceFile {
    let lines = lexer::lex(raw);
    let in_test = lexer::test_regions(&lines);
    SourceFile { rel: rel.to_string(), raw: raw.to_string(), lines, in_test }
}

/// The outcome of a lint run: unsuppressed findings, suppressed ones
/// (kept for the report's per-rule accounting), and the file count.
pub struct Report {
    /// Findings that fail the gate.
    pub findings: Vec<Finding>,
    /// Findings silenced by `lint:allow` comments.
    pub suppressed: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

/// Run every rule over `files`, plus one wire-format cross-check per
/// spec supplied as `(path, text)` — a path ending in `PROTOCOL.md`
/// checks `net/`, any other checks `codec/`. Pure: no filesystem access.
pub fn lint_sources(files: &[SourceFile], specs: &[(&str, &str)]) -> Report {
    let mut found = Vec::new();
    for f in files {
        rules::unsafe_discipline::check(f, &mut found);
        rules::dispatch::check(f, &mut found);
        rules::panic_freedom::check(f, &mut found);
        rules::determinism::check(f, &mut found);
        rules::metrics_naming::check(f, &mut found);
    }
    for (spec_rel, spec_text) in specs {
        if spec_rel.ends_with("PROTOCOL.md") {
            rules::wire_format::check_protocol(spec_rel, spec_text, files, &mut found);
        } else {
            rules::wire_format::check(spec_rel, spec_text, files, &mut found);
        }
    }
    found.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));

    let by_rel: HashMap<&str, &SourceFile> = files.iter().map(|f| (f.rel.as_str(), f)).collect();
    let mut findings = Vec::new();
    let mut suppressed = Vec::new();
    for f in found {
        let allowed = by_rel
            .get(f.file.as_str())
            .map(|sf| is_suppressed(&sf.lines, f.line, f.rule))
            .unwrap_or(false);
        if allowed {
            suppressed.push(f);
        } else {
            findings.push(f);
        }
    }
    Report { findings, suppressed, files_scanned: files.len() }
}

/// Whether the finding at 1-based `line` is covered by a
/// `// lint:allow(<rule>)` comment on that line or in the comment-only
/// block directly above it.
fn is_suppressed(lines: &[lexer::Line], line: usize, rule: &str) -> bool {
    if line == 0 || line > lines.len() {
        return false;
    }
    let ix = line - 1;
    let mut cands: Vec<&str> = vec![&lines[ix].comment];
    let mut j = ix;
    while j > 0 && lexer::comment_only(&lines[j - 1]) {
        j -= 1;
        cands.push(&lines[j].comment);
    }
    cands.iter().any(|c| allow_matches(c, rule))
}

fn allow_matches(comment: &str, rule: &str) -> bool {
    const NEEDLE: &str = "lint:allow(";
    let Some(k) = comment.find(NEEDLE) else {
        return false;
    };
    let Some(close) = comment[k..].find(')') else {
        return false;
    };
    let inner = &comment[k + NEEDLE.len()..k + close];
    inner.split(',').any(|name| name.trim() == rule)
}

/// Recursively collect, lex, and lint every `.rs` file under `root`,
/// reading each wire-format spec from `specs`.
pub fn lint_tree(root: &Path, specs: &[PathBuf]) -> io::Result<Report> {
    let mut paths = Vec::new();
    walk(root, &mut paths)?;
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let rel = rel_path(root, p);
        let raw = std::fs::read_to_string(p)?;
        files.push(source_file(&rel, &raw));
    }
    let mut spec_data = Vec::with_capacity(specs.len());
    for sp in specs {
        spec_data.push((sp.display().to_string(), std::fs::read_to_string(sp)?));
    }
    let spec_refs: Vec<(&str, &str)> =
        spec_data.iter().map(|(p, t)| (p.as_str(), t.as_str())).collect();
    Ok(lint_sources(&files, &spec_refs))
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}
