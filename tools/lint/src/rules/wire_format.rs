//! `wire-format`: the byte-level specs are normative, so the numbers in
//! the prose must equal the constants in code. Two cross-checks share
//! one engine:
//!
//! * `docs/FORMAT.md` (MCNC2 container) ↔ `codec/` constants;
//! * `docs/PROTOCOL.md` (MCNP1 socket framing) ↔ `net/` constants.
//!
//! Each parses its spec (magic line, varint limit, bounds tables,
//! `` `value` (`CONST`) `` cells, codec-tag table, rANS parameters) into
//! expected values, scans the gated source subtree for `const`
//! declarations (resolving simple `A << B` and identifier references),
//! and reports three failure modes: a spec value the parser can no
//! longer locate, a spec value with no matching code constant, and a
//! plain numeric mismatch. Drift is fixed in code or spec — findings on
//! this rule should never be suppressed.

use std::collections::HashMap;

use crate::lexer::find_token;
use crate::{Finding, SourceFile};

/// Stable rule name.
pub const ID: &str = "wire-format";

/// Spec-named integer constants that must exist in `codec/` with the
/// exact spec value. The magic byte string is checked separately.
const WIRE_INTS: [&str; 15] = [
    "MAX_HEADER",
    "MAX_FRAME",
    "MAX_ELEMS",
    "MAX_DIMS",
    "MAX_NAME",
    "MAX_VARINT_BYTES",
    "VERSION",
    "TAG_LOSSLESS",
    "TAG_INT8",
    "TAG_INT4",
    "INT8_BITS",
    "INT4_BITS",
    "M",
    "SCALE_BITS",
    "RANS_L",
];

/// Spec-named integer constants that must exist in `net/` with the
/// exact `docs/PROTOCOL.md` value (MCNP1 framing bounds, message types,
/// error codes). The preamble byte string is checked separately.
const NET_INTS: [&str; 13] = [
    "NET_VERSION",
    "NET_MAX_FRAME",
    "MAX_TOKENS",
    "MAX_ERR_LEN",
    "MSG_REQ",
    "MSG_REPLY_OK",
    "MSG_REPLY_ERR",
    "MSG_PING",
    "MSG_PONG",
    "MSG_CONN_ERR",
    "ERR_REJECTED",
    "ERR_FAILED",
    "ERR_DEADLINE",
];

/// One spec ↔ code binding: which doc, which source subtree, which
/// magic constant, which integer constants.
struct Binding {
    /// Spec label used in finding messages ("FORMAT.md" / "PROTOCOL.md").
    label: &'static str,
    /// Path fragment gating the code side ("codec/" / "net/").
    frag: &'static str,
    /// Name of the byte-string magic constant.
    magic_name: &'static str,
    /// Integer constants the spec must pin.
    ints: &'static [&'static str],
}

/// Cross-check `docs/FORMAT.md` against the `codec/` constants in `files`.
pub fn check(spec_rel: &str, spec_text: &str, files: &[SourceFile], out: &mut Vec<Finding>) {
    let b = Binding { label: "FORMAT.md", frag: "codec/", magic_name: "MAGIC_V2", ints: &WIRE_INTS };
    cross_check(&b, spec_rel, spec_text, files, out);
}

/// Cross-check `docs/PROTOCOL.md` against the `net/` constants in `files`.
pub fn check_protocol(
    spec_rel: &str,
    spec_text: &str,
    files: &[SourceFile],
    out: &mut Vec<Finding>,
) {
    let b =
        Binding { label: "PROTOCOL.md", frag: "net/", magic_name: "NET_MAGIC", ints: &NET_INTS };
    cross_check(&b, spec_rel, spec_text, files, out);
}

fn cross_check(
    b: &Binding,
    spec_rel: &str,
    spec_text: &str,
    files: &[SourceFile],
    out: &mut Vec<Finding>,
) {
    let (exp, magic_spec) = spec_expectations(b.label, spec_rel, spec_text, out);
    let consts = code_constants(files, b.frag);
    let magic_code = find_magic(files, b.frag, b.magic_name);

    match magic_spec {
        None => {
            let m = format!("{}: could not locate spec value for `{}`", b.label, b.magic_name);
            miss(out, spec_rel, 1, &m);
        }
        Some((want, spec_line)) => match magic_code {
            None => {
                let m = format!("{} has no {} byte-string constant", b.frag, b.magic_name);
                miss(out, spec_rel, spec_line, &m);
            }
            Some((got, rel, line)) => {
                if got != want {
                    let g = String::from_utf8_lossy(&got).escape_default().to_string();
                    let w = String::from_utf8_lossy(&want).escape_default().to_string();
                    out.push(Finding {
                        file: rel,
                        line,
                        rule: ID,
                        msg: format!("magic bytes \"{g}\" in code but \"{w}\" in {}", b.label),
                    });
                }
            }
        },
    }

    for &name in b.ints {
        let Some(&(want, spec_line)) = exp.get(name) else {
            let m = format!("{}: could not locate spec value for `{name}`", b.label);
            miss(out, spec_rel, 1, &m);
            continue;
        };
        let Some((got, rel, line)) = consts.get(name) else {
            let m = format!("{} defines no constant `{name}` (spec: {want})", b.frag);
            miss(out, spec_rel, spec_line, &m);
            continue;
        };
        if *got != want {
            out.push(Finding {
                file: rel.clone(),
                line: *line,
                rule: ID,
                msg: format!("`{name}` = {got} in code but {want} in {}", b.label),
            });
        }
    }
}

fn miss(out: &mut Vec<Finding>, file: &str, line: usize, msg: &str) {
    out.push(Finding { file: file.to_string(), line, rule: ID, msg: msg.to_string() });
}

// ------------------------------------------------------------ spec side

type Expectations = HashMap<String, (u64, usize)>;

/// Parse the spec into `{name: (value, 1-based spec line)}`, plus the
/// magic byte string. Self-contradictions in the spec (magic string vs
/// hex bytes) are reported directly.
fn spec_expectations(
    label: &str,
    spec_rel: &str,
    spec_text: &str,
    out: &mut Vec<Finding>,
) -> (Expectations, Option<(Vec<u8>, usize)>) {
    let mut exp = Expectations::new();
    let mut magic = None;
    for (ix0, line) in spec_text.lines().enumerate() {
        let ix = ix0 + 1;
        if line.trim().starts_with("magic") && line.contains('"') && line.contains('=') {
            parse_magic_line(label, spec_rel, line, ix, &mut magic, out);
        }
        if line.contains("than") && line.contains("bytes") {
            if let Some(v) = parse_varint_limit(line) {
                exp.insert("MAX_VARINT_BYTES".to_string(), (v, ix));
            }
        }
        if line.starts_with('|') {
            parse_table_row(line, ix, &mut exp);
        }
        if let Some((_, seg)) = line.split_once("`M = ") {
            let num = seg.split('`').next().unwrap_or("").trim();
            if !num.is_empty() && num.chars().all(|c| c.is_ascii_digit()) {
                if let Ok(v) = num.parse() {
                    exp.insert("M".to_string(), (v, ix));
                }
            }
            if let Some(bits) = trailing_int_before(line, "-bit") {
                exp.insert("SCALE_BITS".to_string(), (bits, ix));
            }
        }
        if let Some((_, seg)) = line.split_once("`L = ") {
            if let Some(v) = parse_value(seg.split('`').next().unwrap_or("")) {
                exp.insert("RANS_L".to_string(), (v, ix));
            }
        }
    }
    (exp, magic)
}

/// `magic    6 bytes   "MCNC2\n" = 4d 43 4e 43 32 0a` — extract the
/// quoted literal, check it against the hex pairs, record it.
fn parse_magic_line(
    label: &str,
    spec_rel: &str,
    line: &str,
    ix: usize,
    magic: &mut Option<(Vec<u8>, usize)>,
    out: &mut Vec<Finding>,
) {
    let Some(q1) = line.find('"') else {
        return;
    };
    let Some(q2r) = line[q1 + 1..].find('"') else {
        return;
    };
    let q2 = q1 + 1 + q2r;
    let lit = unescape(&line[q1 + 1..q2]);
    let Some(eqr) = line[q2..].find('=') else {
        return;
    };
    let mut hexbytes = Vec::new();
    for tok in line[q2 + eqr + 1..].split_whitespace() {
        if tok.len() != 2 {
            continue;
        }
        if let Ok(b) = u8::from_str_radix(tok, 16) {
            hexbytes.push(b);
        }
    }
    if lit != hexbytes {
        miss(out, spec_rel, ix, &format!("{label} magic string and hex bytes disagree"));
    }
    *magic = Some((lit, ix));
}

fn unescape(s: &str) -> Vec<u8> {
    s.replace("\\n", "\n").replace("\\0", "\0").into_bytes()
}

/// `... must reject varints longer than 10 bytes ...` — the number
/// between "than" and "bytes", when both land on this line.
fn parse_varint_limit(line: &str) -> Option<u64> {
    let (_, seg) = line.split_once("than")?;
    let seg = seg.trim_start();
    let num: String = seg.chars().take_while(|c| c.is_ascii_digit()).collect();
    if num.is_empty() || !seg[num.len()..].trim_start().starts_with("bytes") {
        return None;
    }
    num.parse().ok()
}

/// One `| ... |` table row: bounds cells (`≤ value (\`NAME\`)`), the
/// header-table version row, and codec-tag rows.
fn parse_table_row(line: &str, ix: usize, exp: &mut Expectations) {
    let parts: Vec<&str> = line.split('|').collect();
    let cells: Vec<&str> = parts[1..parts.len() - 1].iter().map(|c| c.trim()).collect();
    for cell in &cells {
        let Some(bt) = cell.find("(`") else {
            continue;
        };
        if !cell.ends_with("`)") || bt + 2 > cell.len() - 2 {
            continue;
        }
        let name = &cell[bt + 2..cell.len() - 2];
        if let Some(val) = parse_value(&cell[..bt]) {
            exp.insert(name.to_string(), (val, ix));
        }
    }
    if cells.len() >= 3 && cells[0] == "`version`" && line.contains("must be") {
        let seg = line.split_once("must be").map(|x| x.1).unwrap_or("");
        if let Some(v) = backtick_int(seg) {
            exp.insert("VERSION".to_string(), (v, ix));
        }
    }
    if cells.len() >= 3 && !cells[0].is_empty() && cells[0].chars().all(|c| c.is_ascii_digit()) {
        if let Ok(tag) = cells[0].parse::<u64>() {
            let name = cells[1].trim_matches('`');
            let key = match name {
                "lossless" => Some("TAG_LOSSLESS"),
                "int8" => Some("TAG_INT8"),
                "int4" => Some("TAG_INT4"),
                _ => None,
            };
            if let Some(key) = key {
                exp.insert(key.to_string(), (tag, ix));
                if let Some(bits) = trailing_int_before(cells[2], "-bit") {
                    if name == "int8" {
                        exp.insert("INT8_BITS".to_string(), (bits, ix));
                    } else if name == "int4" {
                        exp.insert("INT4_BITS".to_string(), (bits, ix));
                    }
                }
            }
        }
    }
}

/// The digit run immediately before the first `marker` in `text`.
fn trailing_int_before(text: &str, marker: &str) -> Option<u64> {
    let k = text.find(marker)?;
    let digits: String = text[..k].chars().rev().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() {
        return None;
    }
    digits.chars().rev().collect::<String>().parse().ok()
}

/// First backtick-quoted integer in `seg`.
fn backtick_int(seg: &str) -> Option<u64> {
    let q1 = seg.find('`')?;
    let rest = &seg[q1 + 1..];
    let inner = &rest[..rest.find('`')?];
    if inner.is_empty() || !inner.chars().all(|c| c.is_ascii_digit()) {
        return None;
    }
    inner.parse().ok()
}

fn superscript(c: char) -> Option<u64> {
    "⁰¹²³⁴⁵⁶⁷⁸⁹".chars().position(|x| x == c).map(|p| p as u64)
}

/// Parse a spec-side value: `1 MiB` | `1 GiB` | `2²⁸` | `2^28` | `4096`
/// (leading `≤` and whitespace tolerated).
fn parse_value(text: &str) -> Option<u64> {
    let t = text.trim().trim_start_matches('≤').trim();
    for (suffix, mult) in [("MiB", 1u64 << 20), ("GiB", 1 << 30), ("KiB", 1 << 10)] {
        if let Some(k) = t.find(suffix) {
            let num = t[..k].trim();
            if !num.is_empty() && num.chars().all(|c| c.is_ascii_digit()) {
                return num.parse::<u64>().ok().map(|v| v * mult);
            }
        }
    }
    let mut chars = t.chars();
    if chars.next() == Some('2') && chars.next().and_then(superscript).is_some() {
        let mut e = 0u64;
        for ch in t.chars().skip(1) {
            match superscript(ch) {
                Some(d) => e = e * 10 + d,
                None => break,
            }
        }
        return Some(1u64 << e);
    }
    if let Some(rest) = t.strip_prefix("2^") {
        let digits: String = rest.chars().filter(|c| c.is_ascii_digit()).collect();
        if !digits.is_empty() {
            return digits.parse::<u64>().ok().map(|e| 1u64 << e);
        }
    }
    let mut digits = String::new();
    for ch in t.chars() {
        if ch.is_ascii_digit() {
            digits.push(ch);
        } else if !digits.is_empty() {
            break;
        }
    }
    if digits.is_empty() {
        None
    } else {
        digits.parse().ok()
    }
}

// ------------------------------------------------------------ code side

struct Decl {
    expr: String,
    rel: String,
    line: usize,
}

type Resolved = HashMap<String, (u64, String, usize)>;

/// Collect `const NAME[: ty] = EXPR;` declarations from files whose
/// relative path contains `frag` and resolve them to integers (literals,
/// `A << B`, and references to other collected constants).
fn code_constants(files: &[SourceFile], frag: &str) -> Resolved {
    let mut decls: HashMap<String, Decl> = HashMap::new();
    for f in files {
        if !f.rel.contains(frag) {
            continue;
        }
        for (ix, line) in f.lines.iter().enumerate() {
            let Some(k) = find_token(&line.code, "const") else {
                continue;
            };
            let rest = line.code[k + "const".len()..].trim();
            let Some(eq) = rest.find('=') else {
                continue;
            };
            let name_end = match rest.find(':') {
                Some(c) if c < eq => c,
                _ => eq,
            };
            let name = rest[..name_end].trim();
            if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || c == '_') {
                continue;
            }
            let expr = rest[eq + 1..].trim().trim_end_matches(';').trim().to_string();
            decls.insert(name.to_string(), Decl { expr, rel: f.rel.clone(), line: ix + 1 });
        }
    }
    let mut resolved = Resolved::new();
    let names: Vec<String> = decls.keys().cloned().collect();
    for name in names {
        resolve(&name, &decls, &mut resolved, 0);
    }
    resolved
}

fn resolve(
    name: &str,
    decls: &HashMap<String, Decl>,
    resolved: &mut Resolved,
    depth: usize,
) -> Option<u64> {
    if let Some((v, _, _)) = resolved.get(name) {
        return Some(*v);
    }
    if depth > 8 {
        return None;
    }
    let d = decls.get(name)?;
    let val = eval_expr(&d.expr, decls, resolved, depth)?;
    resolved.insert(name.to_string(), (val, d.rel.clone(), d.line));
    Some(val)
}

fn eval_expr(
    expr: &str,
    decls: &HashMap<String, Decl>,
    resolved: &mut Resolved,
    depth: usize,
) -> Option<u64> {
    let expr = expr.trim();
    if expr.starts_with("b\"") {
        // the magic byte string; handled from raw lines by find_magic
        return None;
    }
    if let Some((lhs, rhs)) = expr.split_once("<<") {
        let lv = eval_atom(lhs, decls, resolved, depth)?;
        let rv = eval_atom(rhs, decls, resolved, depth)?;
        return Some(lv << rv);
    }
    eval_atom(expr, decls, resolved, depth)
}

fn eval_atom(
    atom: &str,
    decls: &HashMap<String, Decl>,
    resolved: &mut Resolved,
    depth: usize,
) -> Option<u64> {
    let mut a = atom.trim().trim_matches(|c: char| c == '(' || c == ')');
    for suf in ["usize", "u64", "u32", "u8", "i32", "i64"] {
        if let Some(head) = a.strip_suffix(suf) {
            let tail_ok = head.chars().last().map(|c| c.is_ascii_digit() || c == '_');
            if tail_ok.unwrap_or(false) {
                a = head;
            }
        }
    }
    let no_us: String = a.chars().filter(|&c| c != '_').collect();
    if !no_us.is_empty() && no_us.chars().all(|c| c.is_ascii_digit()) {
        return no_us.parse().ok();
    }
    if !a.is_empty() && a.chars().all(|c| c.is_alphanumeric() || c == '_') {
        return resolve(a, decls, resolved, depth + 1);
    }
    None
}

/// The magic byte string (`MAGIC_V2` / `NET_MAGIC`) must be read from
/// raw source — the lexer masks string contents out of the code text.
fn find_magic(files: &[SourceFile], frag: &str, name: &str) -> Option<(Vec<u8>, String, usize)> {
    let mut found = None;
    for f in files {
        if !f.rel.contains(frag) {
            continue;
        }
        for (ix, line) in f.raw.lines().enumerate() {
            if !(line.contains(name) && line.contains("b\"") && line.contains("const")) {
                continue;
            }
            let Some(q1) = line.find("b\"") else {
                continue;
            };
            let rest = &line[q1 + 2..];
            let Some(q2) = rest.find('"') else {
                continue;
            };
            found = Some((unescape(&rest[..q2]), f.rel.clone(), ix + 1));
        }
    }
    found
}
