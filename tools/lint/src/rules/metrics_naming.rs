//! `metrics-naming`: serving metrics go through the obs registry, under
//! Prometheus-conventional names. Two checks:
//!
//! * no bare `AtomicU64` counters in `coordinator/` non-test code — every
//!   coordinator counter must be an `obs::Counter`/registry handle so it
//!   shows up in `Server::metrics_snapshot()` (the one sanctioned raw
//!   fetch-add word, the request-id mint, lives in `obs::IdGen`);
//! * every metric name literal at a `.counter("…")` / `.gauge("…")` /
//!   `.histogram("…")` registration site must be snake_case
//!   (`[a-z][a-z0-9_]*`), matching the registry's own debug assertion so
//!   the Prometheus exporter never emits an invalid family name.
//!
//! The lexer masks string contents out of `Line::code`, so call sites are
//! detected on masked code and the literal is re-read from the raw line.

use crate::{Finding, SourceFile};

/// Stable rule name.
pub const ID: &str = "metrics-naming";

const REGISTER_CALLS: [&str; 3] = [".counter(\"", ".gauge(\"", ".histogram(\""];

/// Matches `obs::registry::is_snake_case`: `[a-z][a-z0-9_]*`.
fn is_snake_case(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_lowercase() => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Flag bare atomic counters in `coordinator/` and non-snake_case metric
/// names at registry registration sites.
pub fn check(f: &SourceFile, out: &mut Vec<Finding>) {
    let raw_lines: Vec<&str> = f.raw.lines().collect();
    let in_coordinator = f.rel.contains("coordinator/");
    for (ix, line) in f.lines.iter().enumerate() {
        if f.in_test[ix] {
            continue;
        }
        let code = line.code.as_str();
        if in_coordinator && code.contains("AtomicU64") {
            out.push(Finding {
                file: f.rel.clone(),
                line: ix + 1,
                rule: ID,
                msg: "bare `AtomicU64` counter in coordinator/ — use an `obs::Counter` \
                      (or `obs::IdGen` for id minting) so the metric reaches the registry"
                    .into(),
            });
        }
        for call in REGISTER_CALLS {
            // the masked line keeps delimiters, so the needle (which ends
            // in the opening quote) still matches; the name itself comes
            // from the raw line at the same occurrence
            let Some(k) = code.find(call) else {
                continue;
            };
            let Some(raw) = raw_lines.get(ix) else {
                continue;
            };
            let Some(start) = raw.find(call).map(|p| p + call.len()) else {
                // multi-line registration call: the literal is not on this
                // line, nothing to validate here
                continue;
            };
            let _ = k;
            let Some(end) = raw[start..].find('"').map(|p| start + p) else {
                continue;
            };
            let name = &raw[start..end];
            if !is_snake_case(name) {
                out.push(Finding {
                    file: f.rel.clone(),
                    line: ix + 1,
                    rule: ID,
                    msg: format!(
                        "metric name {name:?} is not snake_case ([a-z][a-z0-9_]*) — \
                         the Prometheus exporter needs valid family names"
                    ),
                });
            }
        }
    }
}
