//! `unsafe-discipline`: every `unsafe` token must have a `// SAFETY:`
//! comment on the same line or in the comment block directly above it
//! (attributes and obvious statement-continuation lines are skipped when
//! walking upward, so the comment may sit above a `#[target_feature]`
//! attribute or a multi-line signature).

use crate::lexer::{comment_only, has_token};
use crate::{Finding, SourceFile};

/// Stable rule name.
pub const ID: &str = "unsafe-discipline";

/// Line endings that mean "the statement continues below", so the walk
/// upward toward the safety comment keeps going.
const CONT_ENDINGS: [&str; 7] = ["=", "(", ",", "&&", "||", "+", "->"];

/// Flag `unsafe` tokens that lack an adjacent `// SAFETY:` comment.
pub fn check(f: &SourceFile, out: &mut Vec<Finding>) {
    for (ix, line) in f.lines.iter().enumerate() {
        if !has_token(&line.code, "unsafe") {
            continue;
        }
        if line.comment.contains("SAFETY:") {
            continue;
        }
        if covered_above(f, ix) {
            continue;
        }
        out.push(Finding {
            file: f.rel.clone(),
            line: ix + 1,
            rule: ID,
            msg: "`unsafe` without an adjacent `// SAFETY:` comment".to_string(),
        });
    }
}

fn covered_above(f: &SourceFile, ix: usize) -> bool {
    let mut j = ix;
    while j > 0 {
        j -= 1;
        if comment_only(&f.lines[j]) {
            // scan the whole contiguous comment block
            loop {
                if f.lines[j].comment.contains("SAFETY:") {
                    return true;
                }
                if j == 0 || !comment_only(&f.lines[j - 1]) {
                    return false;
                }
                j -= 1;
            }
        }
        let t = f.lines[j].code.trim();
        if t.starts_with("#[") || t.starts_with("#![") {
            continue;
        }
        if !t.is_empty() && CONT_ENDINGS.iter().any(|e| t.ends_with(e)) {
            continue;
        }
        return false;
    }
    false
}
