//! `determinism`: the codec and the chaos harness must be pure functions
//! of their inputs. MCNC2 bytes are golden-tested across hosts, and the
//! fault schedule replays from a seed — so `Instant::now`, `SystemTime`,
//! and ambient RNG entropy (`thread_rng`, `from_entropy`, `getrandom`)
//! are banned in `codec/` and `coordinator/chaos.rs` outside tests.
//! Randomness there must flow from an explicit seed.

use crate::{Finding, SourceFile};

/// Stable rule name.
pub const ID: &str = "determinism";

const DET_PATTERNS: [&str; 5] =
    ["Instant::now", "SystemTime", "thread_rng", "from_entropy", "getrandom"];

/// Flag ambient time/randomness in deterministic modules.
pub fn check(f: &SourceFile, out: &mut Vec<Finding>) {
    if !(f.rel.contains("codec/") || f.rel.ends_with("coordinator/chaos.rs")) {
        return;
    }
    for (ix, line) in f.lines.iter().enumerate() {
        if f.in_test[ix] {
            continue;
        }
        for pat in DET_PATTERNS {
            if line.code.contains(pat) {
                out.push(Finding {
                    file: f.rel.clone(),
                    line: ix + 1,
                    rule: ID,
                    msg: format!("ambient nondeterminism `{pat}` in deterministic module"),
                });
            }
        }
    }
}
