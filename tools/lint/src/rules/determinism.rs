//! `determinism`: the codec, the chaos harness, and the MCNP1 protocol
//! codec must be pure functions of their inputs. MCNC2 bytes are
//! golden-tested across hosts, the fault schedule replays from a seed,
//! and protocol encode/deframe must produce host-independent wire bytes
//! — so `Instant::now`, `SystemTime`, and ambient RNG entropy
//! (`thread_rng`, `from_entropy`, `getrandom`) are banned in `codec/`,
//! `coordinator/chaos.rs`, `net/protocol.rs`, and `net/conn.rs` outside
//! tests (the listener keeps the clock: deadlines anchor there).
//! Randomness there must flow from an explicit seed.

use crate::{Finding, SourceFile};

/// Stable rule name.
pub const ID: &str = "determinism";

const DET_PATTERNS: [&str; 5] =
    ["Instant::now", "SystemTime", "thread_rng", "from_entropy", "getrandom"];

/// Flag ambient time/randomness in deterministic modules.
pub fn check(f: &SourceFile, out: &mut Vec<Finding>) {
    if !(f.rel.contains("codec/")
        || f.rel.ends_with("coordinator/chaos.rs")
        || f.rel.ends_with("net/protocol.rs")
        || f.rel.ends_with("net/conn.rs"))
    {
        return;
    }
    for (ix, line) in f.lines.iter().enumerate() {
        if f.in_test[ix] {
            continue;
        }
        for pat in DET_PATTERNS {
            if line.code.contains(pat) {
                out.push(Finding {
                    file: f.rel.clone(),
                    line: ix + 1,
                    rule: ID,
                    msg: format!("ambient nondeterminism `{pat}` in deterministic module"),
                });
            }
        }
    }
}
