//! `dispatch-containment`: ISA-specific code stays behind the dispatch
//! layer. Intrinsics (`core::arch`, `#[target_feature]`) may appear only
//! in `mcnc/kernel/{x86,neon,x86_i8,neon_i8}.rs` — the f32 microkernels
//! and their int8 compressed-domain siblings; runtime feature probes only
//! there or in `mcnc/kernel/dispatch.rs`; and the
//! `x86::`/`neon::`/`scalar::` backend modules may be named only inside
//! `mcnc/kernel/`. Everything above the kernel layer must go through
//! `kernel::dispatch`, which is what makes "scalar and SIMD backends are
//! bit-identical" a checkable claim instead of a convention.

use crate::lexer::find_token;
use crate::{Finding, SourceFile};

/// Stable rule name.
pub const ID: &str = "dispatch-containment";

const ARCH_FILES: [&str; 4] = [
    "mcnc/kernel/x86.rs",
    "mcnc/kernel/neon.rs",
    "mcnc/kernel/x86_i8.rs",
    "mcnc/kernel/neon_i8.rs",
];
const DETECT_FILES: [&str; 3] =
    ["mcnc/kernel/x86.rs", "mcnc/kernel/neon.rs", "mcnc/kernel/dispatch.rs"];
const KERNEL_DIR: &str = "mcnc/kernel/";

/// Flag ISA-specific constructs outside their sanctioned files.
pub fn check(f: &SourceFile, out: &mut Vec<Finding>) {
    let in_arch = ARCH_FILES.iter().any(|s| f.rel.ends_with(s));
    let in_detect = DETECT_FILES.iter().any(|s| f.rel.ends_with(s));
    let in_kernel = f.rel.contains(KERNEL_DIR);
    for (ix, line) in f.lines.iter().enumerate() {
        let code = line.code.as_str();
        if !in_arch {
            for pat in ["std::arch", "core::arch"] {
                if code.contains(pat) {
                    push(out, f, ix, format!("`{pat}` outside kernel/{{x86,neon}}.rs"));
                }
            }
            if code.contains("#[target_feature") {
                push(out, f, ix, "`#[target_feature]` outside kernel/{x86,neon}.rs".into());
            }
        }
        if !in_detect && code.contains("is_x86_feature_detected!") {
            push(out, f, ix, "feature detection outside kernel/dispatch.rs".into());
        }
        if !in_kernel {
            for m in ["x86", "neon", "scalar"] {
                let hit = find_token(code, m)
                    .map(|k| code[k + m.len()..].starts_with("::"))
                    .unwrap_or(false);
                if hit {
                    push(out, f, ix, format!("ISA module `{m}::` outside mcnc/kernel/"));
                }
            }
        }
    }
}

fn push(out: &mut Vec<Finding>, f: &SourceFile, ix: usize, msg: String) {
    out.push(Finding { file: f.rel.clone(), line: ix + 1, rule: ID, msg });
}
