//! The rule set. Each module exposes `ID` (the stable rule name used in
//! findings and `lint:allow(...)` suppressions) and a `check` function.
//! Five rules are per-file; `wire_format` is a whole-tree cross-check
//! between `docs/FORMAT.md` and the `codec/` constants.

pub mod determinism;
pub mod dispatch;
pub mod metrics_naming;
pub mod panic_freedom;
pub mod unsafe_discipline;
pub mod wire_format;
