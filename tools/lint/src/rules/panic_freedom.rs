//! `panic-freedom`: the serving path must degrade, not die. A panic in
//! `coordinator/{shard,server,router,qserve}.rs` takes down a shard that
//! the supervisor then has to resurrect, and a panic in `net/` takes down
//! the socket front-end's poll loop with every connection on it — every
//! fallible step there must propagate a `Result` so the deadline/
//! circuit-breaker machinery (and per-connection error replies) can do
//! their job. `qserve.rs` is on the list because its panel cold-fill path
//! runs inside `run_batch` on live requests. `#[cfg(test)]` regions are
//! exempt.

use crate::lexer::find_token;
use crate::{Finding, SourceFile};

/// Stable rule name.
pub const ID: &str = "panic-freedom";

const PANIC_FILES: [&str; 4] = [
    "coordinator/shard.rs",
    "coordinator/server.rs",
    "coordinator/router.rs",
    "coordinator/qserve.rs",
];

/// Flag `.unwrap()`/`.expect()` calls and panicking macros in non-test
/// code of the serving-path files (the coordinator hot path and the
/// whole `net/` subtree).
pub fn check(f: &SourceFile, out: &mut Vec<Finding>) {
    if !(PANIC_FILES.iter().any(|s| f.rel.ends_with(s)) || f.rel.contains("net/")) {
        return;
    }
    for (ix, line) in f.lines.iter().enumerate() {
        if f.in_test[ix] {
            continue;
        }
        let code = line.code.as_str();
        for word in ["unwrap", "expect"] {
            if let Some(k) = find_token(code, word) {
                let prev = code[..k].trim_end();
                let rest = code[k + word.len()..].trim_start();
                if prev.ends_with('.') && rest.starts_with('(') {
                    push(out, f, ix, format!("`.{word}()` on a serving path"));
                }
            }
        }
        for mac in ["panic", "unreachable", "todo", "unimplemented"] {
            if let Some(k) = find_token(code, mac) {
                if code[k + mac.len()..].trim_start().starts_with('!') {
                    push(out, f, ix, format!("`{mac}!` on a serving path"));
                }
            }
        }
    }
}

fn push(out: &mut Vec<Finding>, f: &SourceFile, ix: usize, msg: String) {
    out.push(Finding { file: f.rel.clone(), line: ix + 1, rule: ID, msg });
}
