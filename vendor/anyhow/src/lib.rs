//! In-tree twin of the `anyhow` error facade (offline vendor set — see
//! `rust/src/util/mod.rs`). Implements exactly the subset this workspace
//! uses: `Result`, `Error`, `anyhow!`, `bail!`, `ensure!`, and the
//! `Context` extension for `Result<_, impl std::error::Error>`,
//! `Result<_, Error>` and `Option<_>`.
//!
//! An `Error` is a stack of messages, outermost context first. `{}` shows
//! the outermost message, `{:#}` the full `outer: ...: root` chain, and
//! `{:?}` an anyhow-style report with a `Caused by:` section.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

pub struct Error {
    /// Outermost context first; the last element is the root cause.
    msgs: Vec<String>,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msgs: vec![m.to_string()] }
    }

    fn wrap(mut self, ctx: String) -> Error {
        self.msgs.insert(0, ctx);
        self
    }

    /// The messages of this error and everything below it, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(String::as_str)
    }

    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`: that keeps
// this blanket conversion coherent (same trick as the real crate).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f().to_string()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.wrap(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: no such file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn with_context_on_option_and_anyhow_result() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
        let r: Result<u32> = Err(anyhow!("root {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root 7");
    }

    #[test]
    fn macros_compile_in_all_forms() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert!(f(5).is_err());
        assert!(format!("{:#}", f(11).unwrap_err()).contains("11"));
        let e: Error = anyhow!("plain");
        assert_eq!(e.root_cause(), "plain");
        assert_eq!(e.chain().count(), 1);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
