//! Multi-task adapter serving (the paper's Table-4 scenario): N tasks, each
//! with its own compressed adapter, served under an open-loop Zipf workload
//! by a sharded engine coordinator. Compares MCNC-LoRA vs NOLA vs LoRA on
//! throughput / latency / on-the-fly reconstruction cost.
//!
//!     cargo run --release --example adapter_server -- [--rate 100 --secs 3 --shards 2]

use std::time::Duration;

use mcnc::coordinator::workload::{open_loop, replay};
use mcnc::coordinator::{BatchPolicy, Mode, Server, ServerCfg};
use mcnc::data::MarkovLm;
use mcnc::runtime::artifacts_dir;
use mcnc::util::bench::Table;
use mcnc::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rate = args.f32_or("rate", 100.0) as f64;
    let secs = args.f32_or("secs", 3.0) as f64;
    let n_tasks = args.usize_or("tasks", 8);
    let n_shards = args.usize_or("shards", 1);

    let lm = MarkovLm::base(1, 128, 32);
    let schedule = open_loop(7, rate, Duration::from_secs_f64(secs), n_tasks, 1.0);
    println!(
        "{} requests over {:.0}s, {} tasks (zipf 1.0), {} shard(s)\n",
        schedule.len(),
        secs,
        n_tasks,
        n_shards
    );

    let mut table = Table::new(
        "Adapter serving (Table 4 analog)",
        &["method", "ok", "rejected/failed", "throughput req/s", "p50", "p99", "queue p99",
          "recon GFLOPs"],
    );

    for kind in ["lm_lora8", "lm_nola8", "lm_mcnclora8"] {
        let cfg = ServerCfg {
            kind: kind.into(),
            n_tasks,
            n_shards,
            policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(5) },
            mode: Mode::OnTheFly,
            cache_bytes: 64 << 20,
            seed: 1,
            ..ServerCfg::default()
        };
        let server = Server::start(artifacts_dir(), cfg)?;
        let rep = replay(&server, &lm, 9, &schedule);
        let stats = server.stop()?;
        table.row(vec![
            kind.into(),
            format!("{}/{}", rep.ok, schedule.len()),
            format!("{}/{}", rep.rejected, rep.failed),
            format!("{:.1}", stats.throughput()),
            format!("{:?}", stats.latency.percentile(50.0)),
            format!("{:?}", stats.latency.percentile(99.0)),
            format!("{:?}", stats.queue_wait.percentile(99.0)),
            format!("{:.3}", stats.recon_flops as f64 / 1e9),
        ]);
    }
    table.print();
    table.save_csv("adapter_server");
    Ok(())
}
