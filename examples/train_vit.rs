//! End-to-end training driver (the EXPERIMENTS.md E2E run): train the
//! ViT-tiny classifier from scratch on the synthetic CIFAR-like task,
//! once dense and once MCNC-compressed to 10%, for a few hundred steps
//! each; log both loss curves to results/e2e_vit_loss.csv.
//!
//!     cargo run --release --example train_vit -- [--steps 300]

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::runtime::{artifacts_dir, Session};
use mcnc::train::{self, LrSchedule, TrainCfg, TrainState};
use mcnc::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 300);
    let sess = Session::open(&artifacts_dir())?;
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::cifar_like(77, 10));

    let mut csv = String::from("step,dense_loss,mcnc10_loss\n");
    let mut curves: Vec<Vec<f32>> = Vec::new();
    let mut finals = Vec::new();

    for (name, lr) in [("vit_dense_train", 0.004f32), ("vit_mcnc10_train", 0.02)] {
        let mut state = TrainState::new(&sess, name, 7)?;
        println!(
            "== {name}: {} trainable params ({:.2}% of compressible) ==",
            state.compressed_params(),
            state.entry.rate() * 100.0
        );
        let cfg = TrainCfg {
            steps,
            batch: 64,
            schedule: LrSchedule::Cosine { base: lr, total: steps, floor_frac: 0.05 },
            eval_every: (steps / 5).max(1),
            eval_batches: 4,
            log_every: (steps / 10).max(1),
            verbose: true,
        };
        let hist = train::run(&mut state, Arc::clone(&data), &cfg)?;
        println!(
            "{name}: final val_loss {:.4} val_acc {:.3}",
            hist.final_val_loss(),
            hist.final_val_acc()
        );
        finals.push((name, hist.final_val_acc()));
        curves.push(hist.losses);
    }

    for i in 0..curves[0].len() {
        csv += &format!("{},{},{}\n", i, curves[0][i], curves[1][i]);
    }
    std::fs::create_dir_all("results")?;
    std::fs::write("results/e2e_vit_loss.csv", csv)?;
    println!("\nloss curves → results/e2e_vit_loss.csv");
    for (name, acc) in finals {
        println!("{name:<22} final val_acc {acc:.3}");
    }
    Ok(())
}
