//! Compress-and-ship (the paper's Table-8 scenario): compare shipping a
//! full dense model to a device against shipping the (α, β) representation
//! and expanding it on-device with the generator executable.
//!
//!     cargo run --release --example compress_and_ship

use std::time::Instant;

use mcnc::runtime::{artifacts_dir, init, Role, Session};
use mcnc::tensor::Tensor;
use mcnc::util::bench::fmt_time;

fn main() -> anyhow::Result<()> {
    let sess = Session::open(&artifacts_dir())?;
    let entry = sess.entry("mlp_mcnc02_recon")?.clone();
    let slots = init::init_inputs(&entry, 1)?;
    let inputs: Vec<Tensor> = slots.iter().map(|(_, t)| t.clone().unwrap()).collect();
    let dc: usize = entry.registry()?.dc;

    // Warm the compile cache (not part of the transfer cost).
    sess.load("mlp_mcnc02_recon")?;
    let full = sess.run("mlp_mcnc02_recon", &inputs)?.remove(0);

    // --- uncompressed path: stage the full weights to the device ---
    let t0 = Instant::now();
    let iters = 50;
    for _ in 0..iters {
        let _buf = sess.to_device(&full)?;
    }
    let dense_t = t0.elapsed() / iters;

    // --- compressed path: stage (α, β) + run the on-device expansion ---
    // (generator weights are device-resident in steady state, like the
    // paper's "as long as the generator is loaded into GPU memory")
    let small: Vec<Tensor> = entry
        .inputs
        .iter()
        .zip(&inputs)
        .filter(|(s, _)| s.role == Role::Trainable)
        .map(|(_, t)| t.clone())
        .collect();
    let t0 = Instant::now();
    for _ in 0..iters {
        for t in &small {
            let _buf = sess.to_device(t)?;
        }
        let _expanded = sess.run("mlp_mcnc02_recon", &inputs)?;
    }
    let comp_t = t0.elapsed() / iters;

    let small_bytes: usize = small.iter().map(Tensor::size_bytes).sum();
    println!("model: {dc} params ({} KiB dense)", dc * 4 / 1024);
    println!(
        "ship dense weights : {:>10} ({} KiB moved)",
        fmt_time(dense_t.as_secs_f64()),
        dc * 4 / 1024
    );
    println!(
        "ship (α,β) + expand: {:>10} ({} KiB moved + generator pass)",
        fmt_time(comp_t.as_secs_f64()),
        small_bytes / 1024
    );
    println!(
        "bytes moved reduced {}x; wall-clock speedup {:.2}x (paper: 2.0x on PCIe)",
        dc * 4 / small_bytes.max(1),
        dense_t.as_secs_f64() / comp_t.as_secs_f64()
    );
    println!(
        "\nNB: on CPU PJRT the \"transfer\" is a memcpy, so the wall-clock gap \
         understates a PCIe link; the moved-bytes ratio is the transferable result."
    );
    Ok(())
}
