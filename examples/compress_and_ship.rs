//! Compress-and-ship (the paper's Table-8 scenario): compare shipping a
//! full dense model to a device against shipping the (α, β) representation
//! and expanding it on-device with the generator executable — and, since
//! the MCNC2 codec landed, against shipping the (α, β) tensors as an
//! entropy-coded wire stream (lossless and int8).
//!
//!     cargo run --release --example compress_and_ship

use std::time::Instant;

use mcnc::codec::{Codec, ContainerHeader, Decoder, Encoder};
use mcnc::runtime::{artifacts_dir, init, Role, Session};
use mcnc::tensor::Tensor;
use mcnc::util::bench::fmt_time;

fn main() -> anyhow::Result<()> {
    let sess = Session::open(&artifacts_dir())?;
    let entry = sess.entry("mlp_mcnc02_recon")?.clone();
    let slots = init::init_inputs(&entry, 1)?;
    let inputs: Vec<Tensor> = slots.iter().map(|(_, t)| t.clone().unwrap()).collect();
    let dc: usize = entry.registry()?.dc;

    // Warm the compile cache (not part of the transfer cost).
    sess.load("mlp_mcnc02_recon")?;
    let full = sess.run("mlp_mcnc02_recon", &inputs)?.remove(0);

    // --- uncompressed path: stage the full weights to the device ---
    let t0 = Instant::now();
    let iters = 50;
    for _ in 0..iters {
        let _buf = sess.to_device(&full)?;
    }
    let dense_t = t0.elapsed() / iters;

    // --- compressed path: stage (α, β) + run the on-device expansion ---
    // (generator weights are device-resident in steady state, like the
    // paper's "as long as the generator is loaded into GPU memory")
    let small: Vec<Tensor> = entry
        .inputs
        .iter()
        .zip(&inputs)
        .filter(|(s, _)| s.role == Role::Trainable)
        .map(|(_, t)| t.clone())
        .collect();
    let t0 = Instant::now();
    for _ in 0..iters {
        for t in &small {
            let _buf = sess.to_device(t)?;
        }
        let _expanded = sess.run("mlp_mcnc02_recon", &inputs)?;
    }
    let comp_t = t0.elapsed() / iters;

    let small_bytes: usize = small.iter().map(Tensor::size_bytes).sum();
    println!("model: {dc} params ({} KiB dense)", dc * 4 / 1024);
    println!(
        "ship dense weights : {:>10} ({} KiB moved)",
        fmt_time(dense_t.as_secs_f64()),
        dc * 4 / 1024
    );
    println!(
        "ship (α,β) + expand: {:>10} ({} KiB moved + generator pass)",
        fmt_time(comp_t.as_secs_f64()),
        small_bytes / 1024
    );
    println!(
        "bytes moved reduced {}x; wall-clock speedup {:.2}x (paper: 2.0x on PCIe)",
        dc * 4 / small_bytes.max(1),
        dense_t.as_secs_f64() / comp_t.as_secs_f64()
    );
    println!(
        "\nNB: on CPU PJRT the \"transfer\" is a memcpy, so the wall-clock gap \
         understates a PCIe link; the moved-bytes ratio is the transferable result."
    );

    // --- wire format: what actually goes over the network ---
    // The raw (α, β) staging above still moves 4 bytes/param; the MCNC2
    // codec entropy-codes (and optionally quantizes) the same tensors.
    let names: Vec<&str> = entry
        .inputs
        .iter()
        .filter(|s| s.role == Role::Trainable)
        .map(|s| s.name.as_str())
        .collect();
    println!("\nwire encodings of the (α, β) payload ({} KiB raw):", small_bytes / 1024);
    for codec in [Codec::Lossless, Codec::Int8 { block: 64 }] {
        let header = ContainerHeader {
            entry: "mlp_mcnc02_recon".into(),
            seed: 1,
            step: 0.0,
            n_tensors: Some(small.len()),
        };
        let t0 = Instant::now();
        let mut enc = Encoder::new(Vec::new(), &header)?;
        for (name, t) in names.iter().zip(&small) {
            enc.write_tensor(name, t, codec)?;
        }
        let (wire, total) = enc.finish()?;
        let enc_t = t0.elapsed();

        let t0 = Instant::now();
        let mut dec = Decoder::new(&wire[..])?;
        let mut decoded = 0usize;
        while let Some((_, t, _)) = dec.next_tensor()? {
            decoded += t.numel();
        }
        let dec_t = t0.elapsed();
        println!(
            "  {:<8}: {:>7} B on the wire ({:.2}x vs raw f32), encode {:>9}, decode {:>9}, {} params",
            codec.name(),
            total,
            small_bytes as f64 / total as f64,
            fmt_time(enc_t.as_secs_f64()),
            fmt_time(dec_t.as_secs_f64()),
            decoded
        );
    }
    println!("(`cargo bench --bench table8_transfer` measures these across fixtures)");
    Ok(())
}
