//! Quickstart: compress-train an MLP at ~0.2% of its parameter count,
//! checkpoint the (α, β) representation, reload it from disk and verify.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::runtime::{artifacts_dir, Session};
use mcnc::train::{self, Checkpoint, LrSchedule, TrainCfg, TrainState};

fn main() -> anyhow::Result<()> {
    let sess = Session::open(&artifacts_dir())?;

    // The paper's MNIST ablation setting: MLP 784-256-256-10 (268,800
    // compressible params) re-expressed as 54 chunks × (α ∈ R^9, β) = 540
    // trainable parameters — 0.2% of the original.
    let mut state = TrainState::new(&sess, "mlp_mcnc02_train", /*seed=*/ 1)?;
    println!(
        "MCNC MLP: {} trainable params for a {}-param model ({:.2}%)",
        state.compressed_params(),
        268_800,
        state.entry.rate() * 100.0
    );

    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(1001, 10, 28, 28, 1));
    let cfg = TrainCfg {
        steps: 150,
        batch: 128,
        schedule: LrSchedule::Cosine { base: 0.05, total: 150, floor_frac: 0.1 },
        eval_every: 50,
        eval_batches: 4,
        log_every: 25,
        verbose: true,
    };
    let hist = train::run(&mut state, Arc::clone(&data), &cfg)?;
    println!(
        "trained: val_loss {:.4} val_acc {:.3}",
        hist.final_val_loss(),
        hist.final_val_acc()
    );

    // Ship it: the checkpoint stores seed + (α, β) only.
    let path = std::env::temp_dir().join("quickstart.mcnc");
    let ck = Checkpoint::from_state(&state);
    ck.save(&path)?;
    println!(
        "checkpoint: {} bytes vs {} bytes dense ({}x smaller)",
        ck.stored_bytes(),
        268_800 * 4,
        268_800 * 4 / ck.stored_bytes()
    );

    // Reload into a fresh state (θ0 + generator re-derived from the seed).
    let mut restored = TrainState::new(&sess, "mlp_mcnc02_train", 1)?;
    Checkpoint::load(&path)?.restore(&mut restored)?;
    let (x, y) = data.batch(mcnc::data::Split::Val, 0, 128);
    let out = restored.eval(x, y)?;
    println!("restored eval: loss {:.4} acc {:.3} — matches", out.loss, out.acc);
    Ok(())
}
