//! Table 1: ViT + MCNC vs Magnitude / PLATON-lite pruning across model-size
//! budgets {50, 20, 10, 5, 2, 1}%. Pruning follows the paper's accounting:
//! index storage costs half-precision per surviving weight, so pruning runs
//! at 1.5× the sparsity of the size budget.

use std::sync::Arc;

use mcnc::baselines::{cubic_sparsity, sparsity_for_size, topk_mask, Platon};
use mcnc::data::{Dataset, Split, SynthVision};
use mcnc::exp::{steps_vit, Ctx};
use mcnc::tensor::Tensor;
use mcnc::train::{self, Checkpoint, LrSchedule, TrainCfg, TrainState};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::cifar_like(77, 10));
    let steps = steps_vit();
    let mut table = Table::new(
        "Table 1 — ViT-tiny, % of model size vs accuracy",
        &["method", "size %", "val acc"],
    );

    // dense baseline, trained once and checkpointed for the pruning arms
    let mut dense = TrainState::new(&ctx.session, "vit_dense_train", 7).unwrap();
    let dense_cfg = TrainCfg {
        steps: steps * 2,
        batch: 64,
        schedule: LrSchedule::Cosine { base: 0.004, total: steps * 2, floor_frac: 0.05 },
        ..TrainCfg::default()
    };
    let hist = train::run(&mut dense, Arc::clone(&data), &dense_cfg).unwrap();
    table.row(vec!["baseline".into(), "100".into(), format!("{:.3}", hist.final_val_acc())]);
    let dense_ck = Checkpoint::from_state(&dense);

    for pct in [50u32, 20, 10, 5, 2, 1] {
        let size = pct as f32 / 100.0;
        let sparsity = sparsity_for_size(size);

        // --- magnitude: one-shot prune + finetune ---
        let mut st = TrainState::new(&ctx.session, "vit_dense_train", 7).unwrap();
        dense_ck.restore(&mut st).unwrap();
        let theta = st.get("theta_c").unwrap().f32s().unwrap().to_vec();
        let mask = topk_mask(&theta, sparsity);
        st.set("mask", Tensor::from_f32(mask, &[theta.len()]).unwrap()).unwrap();
        st.reset_optimizer();
        let ft = TrainCfg {
            steps: steps / 2,
            batch: 64,
            schedule: LrSchedule::Const(0.0005),
            ..TrainCfg::default()
        };
        let h = train::run(&mut st, Arc::clone(&data), &ft).unwrap();
        table.row(vec!["magnitude".into(), pct.to_string(), format!("{:.3}", h.final_val_acc())]);

        // --- PLATON-lite: iterative importance pruning with cubic schedule ---
        let mut st = TrainState::new(&ctx.session, "vit_dense_train", 7).unwrap();
        dense_ck.restore(&mut st).unwrap();
        st.reset_optimizer();
        let dc = theta.len();
        let mut platon = Platon::new(dc, 0.85, 0.95);
        let prune_steps = steps / 2;
        let (t_i, t_f) = (prune_steps / 10, prune_steps * 3 / 4);
        for step in 0..prune_steps {
            let (x, y) = data.batch(Split::Train, step as u64, 64);
            let (extra, _) = st.step_full(x, y, 0.0005).unwrap();
            platon.update(extra[0].f32s().unwrap());
            if step % 10 == 0 || step == prune_steps - 1 {
                let s = cubic_sparsity(step, t_i, t_f, sparsity);
                st.set("mask", Tensor::from_f32(platon.mask(s), &[dc]).unwrap()).unwrap();
            }
        }
        let (_, acc) = train::evaluate(&st, data.as_ref(), 64, 4).unwrap();
        table.row(vec!["platon-lite".into(), pct.to_string(), format!("{acc:.3}")]);

        // --- MCNC from scratch at the same size budget ---
        let exec = format!("vit_mcnc{pct}_train");
        let (acc, _) = ctx
            .best_acc(&exec, Arc::clone(&data), steps, &[0.02, 0.01, 0.05], 7)
            .unwrap();
        table.row(vec!["MCNC".into(), pct.to_string(), format!("{acc:.3}")]);
    }

    table.print();
    table.save_csv("table1_vit_pruning");
    println!("\npaper shape: pruning competitive at mild budgets, MCNC wins at ≤10%.");
}
