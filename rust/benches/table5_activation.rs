//! Table 5: generator activation ablation on the MLP/MNIST-analog setting
//! (0.2% compression). Linear recovers a PRANC variant.

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{steps_mlp, Ctx};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(42, 10, 28, 28, 1));
    let steps = steps_mlp();
    let lrs = [0.05f32, 0.01, 0.1];
    let mut table =
        Table::new("Table 5 — activation function vs accuracy (MLP @0.2%)", &["activation", "val acc"]);
    for (label, exec) in [
        ("none (linear/PRANC)", "mlp_mcnc02_linear_train"),
        ("relu", "mlp_mcnc02_relu_train"),
        ("leaky relu", "mlp_mcnc02_lrelu_train"),
        ("elu", "mlp_mcnc02_elu_train"),
        ("sigmoid", "mlp_mcnc02_sigmoid_train"),
        ("sine", "mlp_mcnc02_train"),
    ] {
        let (acc, _) = ctx.best_acc(exec, Arc::clone(&data), steps, &lrs, 5).unwrap();
        table.row(vec![label.into(), format!("{acc:.3}")]);
    }
    table.print();
    table.save_csv("table5_activation");
    println!("\npaper shape: sine best, sigmoid second, relu-family ≤ linear.");
}
