//! Figure 2: traversal of S² by a 1-D manifold — uniformity exp(−τ·W2²)
//! for Sigmoid / ReLU / Sine generators across input bounds L, random
//! init (left panel) and SWGAN-optimized (right panel).

use mcnc::exp::Ctx;
use mcnc::mcnc::{Act, GenCfg, Generator};
use mcnc::runtime::init::init_inputs;
use mcnc::runtime::Role;
use mcnc::sphere;
use mcnc::tensor::Tensor;
use mcnc::util::bench::{bench_steps, Table};
use mcnc::util::prng::Stream;

const TAU: f64 = 10.0;
const N_PTS: usize = 4096;

fn coverage(gen: &Generator, l: f32) -> f64 {
    // 4096 tiny chunks batched as [n, w] layer GEMMs by forward()
    let alpha = Stream::new(7).uniform_f32(N_PTS, -l, l);
    let pts = gen.forward(&alpha, &vec![1.0; N_PTS]);
    sphere::uniformity(&pts, 3, TAU, 11, 64)
}

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let mut table = Table::new(
        "Fig 2 — sphere coverage, k=1 → S², exp(-10·W2²)",
        &["activation", "L", "random init", "optimized"],
    );

    // --- optimized sine generator via the SWGAN artifact ---
    let entry = ctx.session.entry("swgan_k1d3").unwrap().clone();
    let cfg3 = GenCfg::from_json(entry.meta.get("gen").unwrap()).unwrap();
    let swgan_steps = bench_steps(150, 1500);
    let trained_ws = {
        let slots = init_inputs(&entry, 42).unwrap();
        let mut ws: Vec<Tensor> = slots
            .iter()
            .filter(|(s, _)| s.role == Role::Trainable)
            .map(|(_, t)| t.clone().unwrap())
            .collect();
        let mut ms: Vec<Tensor> = ws.iter().map(|w| Tensor::zeros(&w.dims)).collect();
        let mut vs = ms.clone();
        let mut t = 0.0f32;
        let b = entry.meta.get("batch").unwrap().as_usize().unwrap();
        let p = entry.meta.get("n_proj").unwrap().as_usize().unwrap();
        for step in 0..swgan_steps as u64 {
            let alpha = Tensor::from_f32(
                Stream::new(100 + step).uniform_f32(b * cfg3.k, -1.0, 1.0),
                &[b, cfg3.k],
            )
            .unwrap();
            let target =
                Tensor::from_f32(sphere::sample_sphere(200 + step, b, cfg3.d), &[b, cfg3.d])
                    .unwrap();
            let proj = Tensor::from_f32(
                sphere::sample_projections(300 + step, p, cfg3.d)
                    .chunks(cfg3.d)
                    .flat_map(|r| r.to_vec())
                    .collect::<Vec<f32>>(),
                &[cfg3.d, p],
            )
            .unwrap();
            // proj layout: artifact wants [d, P]; we sampled [P, d] → transpose
            let pf = proj.f32s().unwrap();
            let mut pt = vec![0.0f32; cfg3.d * p];
            for i in 0..p {
                for j in 0..cfg3.d {
                    pt[j * p + i] = pf[i * cfg3.d + j];
                }
            }
            let proj = Tensor::from_f32(pt, &[cfg3.d, p]).unwrap();

            let mut inputs = ws.clone();
            inputs.extend(ms.clone());
            inputs.extend(vs.clone());
            inputs.push(Tensor::scalar_f32(t));
            inputs.push(Tensor::scalar_f32(0.003));
            inputs.push(alpha);
            inputs.push(target);
            inputs.push(proj);
            let out = ctx.session.run("swgan_k1d3", &inputs).unwrap();
            let d = ws.len();
            ws = out[..d].to_vec();
            ms = out[d..2 * d].to_vec();
            vs = out[2 * d..3 * d].to_vec();
            t = out[3 * d].scalar().unwrap();
        }
        ws.into_iter().map(|w| w.f32s().unwrap().to_vec()).collect::<Vec<_>>()
    };

    for act in ["sigmoid", "relu", "sine"] {
        for l in [1.0f32, 5.0, 25.0, 100.0] {
            let cfg = GenCfg {
                k: 1,
                d: 3,
                width: cfg3.width,
                depth: 3,
                freq: 1.0,
                act: Act::parse(act).unwrap(),
                normalize: true,
                ..GenCfg::default()
            };
            let random = Generator::from_seed(cfg.clone(), 42);
            let u_rand = coverage(&random, l);
            // optimized panel: only the sine generator was SWGAN-trained
            // (the paper optimizes each; random-vs-trained gap is what
            // matters and is largest for sine at high L)
            let u_opt = if act == "sine" {
                let trained =
                    Generator::with_weights(cfg, trained_ws.clone()).unwrap();
                coverage(&trained, l)
            } else {
                f64::NAN
            };
            table.row(vec![
                act.into(),
                format!("{l}"),
                format!("{u_rand:.4}"),
                if u_opt.is_nan() { "-".into() } else { format!("{u_opt:.4}") },
            ]);
        }
    }
    table.print();
    table.save_csv("fig2_sphere_coverage");
    println!(
        "\npaper shape: sine @ large L ≈ uniform already at random init; \
         sigmoid/relu collapse to arcs (low score)."
    );
}
