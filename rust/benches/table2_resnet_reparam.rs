//! Table 2: ResNet (CIFAR-style, the ResNet-18 analog) — MCNC ± LoRA vs
//! PRANC vs NOLA across compression rates on the synthetic CIFAR-10 task.

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{steps_resnet, Ctx};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::cifar_like(55, 10));
    let steps = steps_resnet();
    let lrs = [0.02f32, 0.01, 0.05];
    let mut table = Table::new(
        "Table 2 — ResNet20 (R18 analog), % size vs accuracy",
        &["method", "size %", "val acc"],
    );

    let (acc, _) = ctx.best_acc("r20c10_dense_train", Arc::clone(&data), steps, &[0.004], 3).unwrap();
    table.row(vec!["baseline".into(), "100".into(), format!("{acc:.3}")]);

    for pct in [10u32, 5, 2, 1] {
        let (acc, _) = ctx
            .best_acc(&format!("r20c10_mcnc{pct}_train"), Arc::clone(&data), steps, &lrs, 3)
            .unwrap();
        table.row(vec!["MCNC".into(), pct.to_string(), format!("{acc:.3}")]);
    }
    for pct in [2u32, 1] {
        let (acc, _) = ctx
            .best_acc(&format!("r20c10_mcnclora{pct}_train"), Arc::clone(&data), steps, &lrs, 3)
            .unwrap();
        table.row(vec!["MCNC w/ LoRA".into(), pct.to_string(), format!("{acc:.3}")]);
        let (acc, _) = ctx
            .best_acc(&format!("r20c10_pranc{pct}_train"), Arc::clone(&data), steps, &lrs, 3)
            .unwrap();
        table.row(vec!["PRANC".into(), pct.to_string(), format!("{acc:.3}")]);
    }
    let (acc, _) = ctx.best_acc("r20c10_nola_train", Arc::clone(&data), steps, &lrs, 3).unwrap();
    table.row(vec!["NOLA".into(), "1".into(), format!("{acc:.3}")]);

    table.print();
    table.save_csv("table2_resnet_reparam");
    println!("\npaper shape: MCNC > PRANC at equal budget; LoRA variant best at extreme rates.");
}
