//! Table 8: host→device transfer of a compressed vs uncompressed model.
//! Measures (a) bytes moved, (b) wall-clock to stage + expand on the CPU
//! PJRT device, and (c) a PCIe-gen4 analytic projection (16 GB/s link +
//! measured expansion), since the CPU "device" hides the link cost.

use mcnc::exp::Ctx;
use mcnc::runtime::{init, Role};
use mcnc::tensor::Tensor;
use mcnc::util::bench::{fmt_time, time_it, Table};

const PCIE_GBPS: f64 = 16.0e9;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let mut table = Table::new(
        "Table 8 — ship compressed vs dense (CPU measured + PCIe model)",
        &["model", "mode", "bytes moved", "measured", "PCIe-projected", "speedup (proj)"],
    );

    for (model, recon) in [
        ("mlp (269k)", "mlp_mcnc02_recon"),
        ("vit-tiny (135k)", "vit_dense_recon"), // dense recon = identity: dense ship only
    ] {
        let entry = ctx.session.entry(recon).unwrap().clone();
        let slots = init::init_inputs(&entry, 1).unwrap();
        let inputs: Vec<Tensor> = slots.iter().map(|(_, t)| t.clone().unwrap()).collect();
        ctx.session.load(recon).unwrap();
        let full = ctx.session.run(recon, &inputs).unwrap().remove(0);
        let dense_bytes = full.size_bytes();

        // dense ship: move all weights
        let s_dense = time_it(3, 15, || {
            let _ = ctx.session.to_device(&full).unwrap();
        });
        let dense_proj = dense_bytes as f64 / PCIE_GBPS + 0.0; // pure transfer
        table.row(vec![
            model.into(),
            "dense".into(),
            format!("{} KiB", dense_bytes / 1024),
            fmt_time(s_dense.median()),
            fmt_time(dense_proj),
            "1.0x".into(),
        ]);

        if !recon.contains("mcnc") {
            continue;
        }
        // compressed ship: move (α, β), expand on device
        let small: Vec<Tensor> = entry
            .inputs
            .iter()
            .zip(&inputs)
            .filter(|(s, _)| s.role == Role::Trainable)
            .map(|(_, t)| t.clone())
            .collect();
        let small_bytes: usize = small.iter().map(Tensor::size_bytes).sum();
        let s_expand = time_it(3, 15, || {
            let _ = ctx.session.run(recon, &inputs).unwrap();
        });
        let s_small = time_it(3, 15, || {
            for t in &small {
                let _ = ctx.session.to_device(t).unwrap();
            }
        });
        let measured = s_small.median() + s_expand.median();
        let comp_proj = small_bytes as f64 / PCIE_GBPS + s_expand.median();
        table.row(vec![
            model.into(),
            "MCNC (α,β)+expand".into(),
            format!("{} KiB", small_bytes / 1024),
            fmt_time(measured),
            fmt_time(comp_proj),
            format!("{:.2}x", dense_proj / comp_proj),
        ]);
    }
    table.print();
    table.save_csv("table8_transfer");

    // Paper-scale analytic check (ViT-S, 22.05M params, 100x compression,
    // RTX A6000): effective host→device bandwidth calibrated from the
    // paper's dense measurement (88.2 MB / 35.5 ms ≈ 2.48 GB/s), generator
    // throughput from a ~30% MXU/CUDA-core utilization of the A6000's
    // 38.7 f32 TFLOP/s on these skinny matmuls.
    let dense_mb = 22.05e6 * 4.0;
    let bw = dense_mb / 35.5e-3; // calibrated
    let gen = mcnc::mcnc::GenCfg { k: 9, d: 1000, width: 1000, depth: 3, ..Default::default() };
    let n_chunks = (22.05e6 / gen.d as f64).ceil();
    let recon_flops = n_chunks * gen.flops_per_chunk() as f64;
    let gpu = 38.7e12 * 0.3;
    let comp = dense_mb / 100.0 / bw + recon_flops / gpu;
    println!(
        "\npaper-scale projection (ViT-S @100x, A6000): dense {:.1} ms vs \
         (α,β)+expand {:.1} ms → {:.1}x (paper measured 35.5 → 17.8 ms = 2.0x)",
        35.5,
        comp * 1e3,
        35.5e-3 / comp
    );
    println!(
        "CPU-measured rows above are expansion-bound at this model scale; \
         the bytes-moved ratio (the transferable quantity) matches the paper's 100x."
    );

    // Sharded-serving corollary (the coordinator's n_shards sweep): every
    // engine shard stages its own replica of the model statics, so the
    // bytes staged grow ×N for a dense ship but stay tiny when each shard
    // ships (α, β) and expands locally — the same cheap-reconstruction
    // argument, multiplied by the shard count.
    println!("\nshard replication (ViT-S @100x shapes, bytes staged per replica set):");
    for n_shards in [1usize, 2, 4] {
        let dense = dense_mb * n_shards as f64;
        let comp = dense_mb / 100.0 * n_shards as f64;
        println!(
            "  n_shards={n_shards}: dense {:.1} MB vs MCNC (α,β) {:.2} MB ({:.0}x less staged)",
            dense / 1e6,
            comp / 1e6,
            dense / comp
        );
    }
}
