//! Table 8: shipping a compressed vs uncompressed model.
//!
//! Three parts:
//!
//! 1. **Wire format** (runs everywhere, no artifacts needed): raw-f32
//!    MCNC1 checkpoints vs the MCNC2 codec (lossless byte-plane rANS,
//!    int8/int4 block-quantized + rANS) on checkpoint fixtures — wire
//!    bytes, compression ratio, and encode/decode throughput. Emitted to
//!    `BENCH_table8_transfer.json` (+ `results/table8_transfer_wire.csv`)
//!    so the transfer trajectory is diffable across PRs.
//! 2. **Parallel decode + warm start** (runs everywhere): in-memory decode
//!    throughput of `Decoder::decode_all_with` at {1, 2, 4, 8} pool
//!    threads per codec (checked bit-identical to the serial path), the
//!    fused decode→`PackedB` path vs decode-then-pack, the compressed-domain
//!    end-to-end rows (artifact → quantized panels → int8 GEMM with the
//!    dispatched result checked bit-identical to the forced-scalar oracle,
//!    so every `--smoke` CI run re-pins the cross-ISA invariant), and the
//!    warm-start decode+group wall-clock on a multi-task artifact. Rows land
//!    in the same table/JSON, labeled `∥ N threads`.
//! 3. **Host→device staging** (needs artifacts + `--features pjrt`): the
//!    original measured + PCIe-projected comparison of dense weights vs
//!    (α, β)+expand, and the shard-replication analytic.
//!
//! `-- --smoke` shrinks the fixtures to CI scale, runs single samples, and
//! skips the JSON/CSV outputs so a quick gate run never clobbers a full
//! run's recorded trajectory.

use mcnc::codec::{Codec, ContainerHeader, Decoder, Encoder, PackedPanels};
use mcnc::coordinator::warm;
use mcnc::exp::Ctx;
use mcnc::mcnc::kernel::{self, Isa};
use mcnc::runtime::{init, IoSpec, Role};
use mcnc::tensor::{DType, Tensor};
use mcnc::train::Checkpoint;
use mcnc::util::bench::{fmt_time, time_it, Table};
use mcnc::util::prng::Stream;
use mcnc::util::threadpool::ThreadPool;

const PCIE_GBPS: f64 = 16.0e9;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut table = Table::new(
        "Table 8a — wire format: MCNC1 raw f32 vs MCNC2 codec (checkpoint fixtures)",
        &["fixture", "format", "wire bytes", "ratio vs MCNC1", "encode", "decode", "enc MB/s",
            "dec MB/s"],
    );
    codec_wire_table(&mut table, smoke);
    parallel_decode_rows(&mut table, smoke);
    compressed_domain_rows(&mut table, smoke);
    warm_start_rows(&mut table, smoke);
    table.print();
    println!(
        "(encode/decode include file IO; MCNC2 lossless is checked bit-exact and \
         strictly smaller than MCNC1 on every fixture; ∥-rows decode in-memory \
         and are checked bit-identical to the serial decoder)"
    );
    if smoke {
        println!("[bench] --smoke: skipping JSON/CSV outputs (tiny fixtures)");
    } else {
        table.save_csv("table8_transfer_wire");
        table.save_json("table8_transfer");
        pjrt_staging();
    }
}

// ---------------------------------------------------------------------------
// Part 1 — wire format (no artifacts needed)
// ---------------------------------------------------------------------------

fn fixtures() -> Vec<(&'static str, Checkpoint)> {
    // Trained-like tensors: N(0, σ) weights have the skewed exponent-byte
    // structure the lossless plane coder exploits (the ZipNN observation).
    let mut s = Stream::new(7);
    let mlp = Checkpoint {
        entry: "mlp_mcnc02_train".into(),
        seed: 42,
        step: 100.0,
        tensors: vec![
            ("alpha".into(), Tensor::from_f32(s.normal_f32(486, 0.05), &[54, 9]).unwrap()),
            ("beta".into(), Tensor::ones(&[54])),
        ],
    };
    let vit = Checkpoint {
        entry: "vit_lora8_train".into(),
        seed: 42,
        step: 100.0,
        tensors: vec![
            ("alpha".into(), Tensor::from_f32(s.normal_f32(131_072, 0.05), &[512, 256]).unwrap()),
            ("beta".into(), Tensor::from_f32(s.normal_f32(512, 0.02), &[512]).unwrap()),
            ("head".into(), Tensor::from_f32(s.normal_f32(131_072, 0.02), &[128, 1024]).unwrap()),
        ],
    };
    vec![("mlp02-αβ (540 p)", mlp), ("vit-lora (262k p)", vit)]
}

fn mbps(payload_bytes: usize, secs: f64) -> String {
    format!("{:.1}", payload_bytes as f64 / secs.max(1e-12) / 1e6)
}

fn codec_wire_table(table: &mut Table, smoke: bool) {
    let samples = if smoke { 1 } else { 5 };
    let dir = std::env::temp_dir().join(format!("mcnc_table8_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    for (name, ck) in fixtures() {
        let payload = ck.stored_params() * 4;
        let p1 = dir.join("fixture.mcnc");
        ck.save(&p1).unwrap();
        let v1_bytes = std::fs::metadata(&p1).unwrap().len() as usize;

        // MCNC1 must keep reading byte-for-byte identically.
        let back = Checkpoint::load(&p1).unwrap();
        assert_eq!(back.tensors, ck.tensors, "MCNC1 read changed");
        assert_eq!(back.seed, ck.seed);

        let enc1 = time_it(1, samples, || ck.save(&p1).unwrap());
        let dec1 = time_it(1, samples, || {
            let _ = Checkpoint::load(&p1).unwrap();
        });
        table.row(vec![
            name.into(),
            "MCNC1 raw f32".into(),
            format!("{v1_bytes}"),
            "1.00x".into(),
            fmt_time(enc1.median()),
            fmt_time(dec1.median()),
            mbps(payload, enc1.median()),
            mbps(payload, dec1.median()),
        ]);

        for codec in [Codec::Lossless, Codec::Int8 { block: 64 }, Codec::Int4 { block: 64 }] {
            let p2 = dir.join("fixture.mcnc2");
            let wire = ck.save_v2(&p2, codec).unwrap();
            let back = Checkpoint::load(&p2).unwrap();
            assert_eq!(back.tensors.len(), ck.tensors.len());
            if codec.is_lossless() {
                assert_eq!(back.tensors, ck.tensors, "lossless roundtrip drifted");
                assert!(
                    wire < v1_bytes,
                    "{name}: MCNC2 lossless ({wire} B) not smaller than MCNC1 ({v1_bytes} B)"
                );
            }
            let enc2 = time_it(1, samples, || {
                ck.save_v2(&p2, codec).unwrap();
            });
            let dec2 = time_it(1, samples, || {
                let _ = Checkpoint::load(&p2).unwrap();
            });
            table.row(vec![
                name.into(),
                format!("MCNC2 {}", codec.name()),
                format!("{wire}"),
                format!("{:.2}x", v1_bytes as f64 / wire as f64),
                fmt_time(enc2.median()),
                fmt_time(dec2.median()),
                mbps(payload, enc2.median()),
                mbps(payload, dec2.median()),
            ]);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Part 2 — parallel decode throughput + warm-start wall-clock (no artifacts)
// ---------------------------------------------------------------------------

/// A multi-tensor "fleet" checkpoint encoded in memory: big enough that
/// entropy decode dominates and the per-frame fan-out has work to split.
fn fleet_container(n_tensors: usize, per: usize, codec: Codec) -> (Vec<u8>, usize) {
    let header = ContainerHeader {
        entry: "fleet_bench".into(),
        seed: 7,
        step: 0.0,
        n_tensors: Some(n_tensors),
    };
    let mut enc = Encoder::new(Vec::new(), &header).unwrap();
    let cols = 64usize;
    for i in 0..n_tensors {
        let vals = Stream::new(100 + i as u64).normal_f32(per, 0.05);
        let t = Tensor::from_f32(vals, &[per / cols, cols]).unwrap();
        enc.write_tensor(&format!("w{i}"), &t, codec).unwrap();
    }
    let (bytes, _) = enc.finish().unwrap();
    (bytes, n_tensors * per * 4)
}

fn parallel_decode_rows(table: &mut Table, smoke: bool) {
    let (n_tensors, per) = if smoke { (4, 2_048) } else { (16, 131_072) };
    let threads: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let samples = if smoke { 1 } else { 5 };
    let fixture = format!("fleet ({n_tensors}x{per} p)");

    for codec in [Codec::Lossless, Codec::Int8 { block: 64 }, Codec::Int4 { block: 64 }] {
        let (bytes, payload) = fleet_container(n_tensors, per, codec);

        // serial reference decode, used for the bit-identity check below
        let mut serial = Vec::new();
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        while let Some(f) = dec.next_tensor().unwrap() {
            serial.push(f);
        }

        for &t in threads {
            let pool = ThreadPool::new(t);
            let out = Decoder::new(&bytes[..]).unwrap().decode_all_with(&pool).unwrap();
            assert_eq!(out.len(), serial.len());
            for ((an, at, ac), (bn, bt, bc)) in out.iter().zip(&serial) {
                assert_eq!((an, ac), (bn, bc), "parallel decode drifted");
                let (af, bf) = (at.f32s().unwrap(), bt.f32s().unwrap());
                assert!(
                    af.iter().zip(bf).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "parallel decode not bit-identical ({} threads)",
                    t
                );
            }
            let stats = time_it(1, samples, || {
                let out = Decoder::new(&bytes[..]).unwrap().decode_all_with(&pool).unwrap();
                assert_eq!(out.len(), n_tensors);
            });
            // best-of-N: thread scaling is the signal, scheduler noise isn't
            let best = stats.min();
            table.row(vec![
                fixture.clone(),
                format!("MCNC2 {} ∥ {t} threads", codec.name()),
                format!("{}", bytes.len()),
                format!("{:.2}x", payload as f64 / bytes.len() as f64),
                "-".into(),
                fmt_time(best),
                "-".into(),
                mbps(payload, best),
            ]);
        }

        // fused decode→PackedB vs decode-then-pack (serial, per-frame)
        let (cols, rows) = (64usize, per / 64);
        let fused = time_it(1, samples, || {
            let mut dec = Decoder::new(&bytes[..]).unwrap();
            let mut n = 0;
            while let Some((_, pb, _)) = dec.next_packed(kernel::active()).unwrap() {
                assert_eq!((pb.k, pb.n), (rows, cols));
                n += 1;
            }
            assert_eq!(n, n_tensors);
        });
        let two_pass = time_it(1, samples, || {
            let mut dec = Decoder::new(&bytes[..]).unwrap();
            let mut n = 0;
            while let Some((_, t, _)) = dec.next_tensor().unwrap() {
                let pb = kernel::pack_b(t.f32s().unwrap(), rows, cols);
                assert_eq!(pb.n, cols);
                n += 1;
            }
            assert_eq!(n, n_tensors);
        });
        for (label, stats) in
            [("fused decode→PackedB", &fused), ("decode, then pack_b", &two_pass)]
        {
            table.row(vec![
                fixture.clone(),
                format!("MCNC2 {} {label}", codec.name()),
                format!("{}", bytes.len()),
                format!("{:.2}x", payload as f64 / bytes.len() as f64),
                "-".into(),
                fmt_time(stats.min()),
                "-".into(),
                mbps(payload, stats.min()),
            ]);
        }
    }
}

/// Compressed-domain end to end: artifact → panels → GEMM. Quantized
/// codecs never materialize f32 weights (rANS → `PackedBQ` → `gemm_q`);
/// the lossless row is the f32 baseline (rANS → `PackedB` → `gemm`).
/// Before timing, the dispatched quantized results are checked
/// bit-identical to a forced-scalar pass — the cross-ISA invariant the
/// prop_int8_gemm battery pins, re-asserted here on the full pipeline (and
/// therefore on every `--smoke` CI run). The f32 baseline is exempt: its
/// SIMD accumulation order legitimately differs from scalar.
fn compressed_domain_rows(table: &mut Table, smoke: bool) {
    let (n_tensors, per) = if smoke { (4, 2_048) } else { (8, 131_072) };
    let samples = if smoke { 1 } else { 5 };
    let pool = ThreadPool::new(if smoke { 2 } else { 4 });
    let cols = 64usize;
    let rows = per / cols;
    let m = 16usize;
    let a = Stream::new(300).uniform_f32(m * rows, -1.0, 1.0);
    let fixture = format!("e2e ({n_tensors}x{per} p)");

    for codec in
        [Codec::Lossless, Codec::Int8 { block: 4 * cols }, Codec::Int4 { block: 4 * cols }]
    {
        let (bytes, payload) = fleet_container(n_tensors, per, codec);

        // full pipeline under one ISA: decode every frame to panels on the
        // pool, then run the per-frame GEMM on its native path
        let run = |isa: Isa| -> Vec<Vec<f32>> {
            let panels =
                Decoder::new(&bytes[..]).unwrap().decode_all_panels_with(&pool, isa, false).unwrap();
            panels
                .iter()
                .map(|(_, p, _)| {
                    let mut c = vec![0.0f32; m * cols];
                    match p {
                        PackedPanels::F32(pb) => kernel::gemm(&a, m, pb, &mut c),
                        PackedPanels::Quant(pq) => {
                            let qa = kernel::quantize_a(&a, m, rows, pq.group_rows());
                            kernel::gemm_q(&qa, pq, &mut c);
                        }
                    }
                    c
                })
                .collect()
        };
        if !codec.is_lossless() {
            let oracle = run(Isa::Scalar);
            let disp = run(kernel::active());
            for (i, (x, y)) in disp.iter().zip(&oracle).enumerate() {
                assert!(
                    x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits()),
                    "e2e frame {i}: dispatched {} path not bit-identical to scalar oracle",
                    codec.name()
                );
            }
        }

        let stats = time_it(1, samples, || {
            let out = run(kernel::active());
            assert_eq!(out.len(), n_tensors);
        });
        table.row(vec![
            fixture.clone(),
            format!("MCNC2 {} artifact→panels→GEMM", codec.name()),
            format!("{}", bytes.len()),
            format!("{:.2}x", payload as f64 / bytes.len() as f64),
            "-".into(),
            fmt_time(stats.min()),
            "-".into(),
            mbps(payload, stats.min()),
        ]);
    }
}

/// Warm-start ingest cost: decode a multi-task `task{t}/{slot}` artifact
/// and group it into per-task adapters (the shard-side `warm_from_artifact`
/// pipeline minus engine installation, so it runs without PJRT artifacts).
fn warm_start_rows(table: &mut Table, smoke: bool) {
    let (n_tasks, a_rows, a_cols) = if smoke { (2, 32, 16) } else { (8, 512, 256) };
    let threads: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let samples = if smoke { 1 } else { 5 };

    let specs = vec![
        IoSpec {
            name: "alpha".into(),
            shape: vec![a_rows, a_cols],
            dtype: DType::F32,
            role: Role::Trainable,
            init: None,
        },
        IoSpec {
            name: "beta".into(),
            shape: vec![a_rows],
            dtype: DType::F32,
            role: Role::Trainable,
            init: None,
        },
    ];
    let adapters: Vec<(usize, Vec<(String, Tensor)>)> = (0..n_tasks)
        .map(|t| {
            let mut s = Stream::new(200 + t as u64);
            (
                t,
                vec![
                    (
                        "alpha".to_string(),
                        Tensor::from_f32(s.normal_f32(a_rows * a_cols, 0.05), &[a_rows, a_cols])
                            .unwrap(),
                    ),
                    (
                        "beta".to_string(),
                        Tensor::from_f32(s.normal_f32(a_rows, 0.02), &[a_rows]).unwrap(),
                    ),
                ],
            )
        })
        .collect();
    let payload = n_tasks * (a_rows * a_cols + a_rows) * 4;

    for codec in [Codec::Lossless, Codec::Int8 { block: 64 }, Codec::Int4 { block: 64 }] {
        let mut bytes = Vec::new();
        warm::write_artifact(&mut bytes, "lm_mcnclora8", 7, codec, &adapters).unwrap();
        for &t in threads {
            let pool = ThreadPool::new(t);
            let stats = time_it(1, samples, || {
                let frames =
                    Decoder::new(&bytes[..]).unwrap().decode_all_with(&pool).unwrap();
                let (owned, skipped) = warm::group_for_shard(frames, &specs, 0, 1).unwrap();
                assert_eq!(owned.len(), n_tasks);
                assert_eq!(skipped, 0);
            });
            table.row(vec![
                format!("warm artifact ({n_tasks} tasks)"),
                format!("warm-start {} ∥ {t} threads", codec.name()),
                format!("{}", bytes.len()),
                format!("{:.2}x", payload as f64 / bytes.len() as f64),
                "-".into(),
                fmt_time(stats.min()),
                "-".into(),
                mbps(payload, stats.min()),
            ]);
        }
    }
}

// ---------------------------------------------------------------------------
// Part 3 — host→device staging (artifacts + pjrt feature)
// ---------------------------------------------------------------------------

fn pjrt_staging() {
    let Some(ctx) = Ctx::open() else { return };
    let mut table = Table::new(
        "Table 8 — ship compressed vs dense (CPU measured + PCIe model)",
        &["model", "mode", "bytes moved", "measured", "PCIe-projected", "speedup (proj)"],
    );

    for (model, recon) in [
        ("mlp (269k)", "mlp_mcnc02_recon"),
        ("vit-tiny (135k)", "vit_dense_recon"), // dense recon = identity: dense ship only
    ] {
        let entry = ctx.session.entry(recon).unwrap().clone();
        let slots = init::init_inputs(&entry, 1).unwrap();
        let inputs: Vec<Tensor> = slots.iter().map(|(_, t)| t.clone().unwrap()).collect();
        ctx.session.load(recon).unwrap();
        let full = ctx.session.run(recon, &inputs).unwrap().remove(0);
        let dense_bytes = full.size_bytes();

        // dense ship: move all weights
        let s_dense = time_it(3, 15, || {
            let _ = ctx.session.to_device(&full).unwrap();
        });
        let dense_proj = dense_bytes as f64 / PCIE_GBPS + 0.0; // pure transfer
        table.row(vec![
            model.into(),
            "dense".into(),
            format!("{} KiB", dense_bytes / 1024),
            fmt_time(s_dense.median()),
            fmt_time(dense_proj),
            "1.0x".into(),
        ]);

        if !recon.contains("mcnc") {
            continue;
        }
        // compressed ship: move (α, β), expand on device
        let small: Vec<Tensor> = entry
            .inputs
            .iter()
            .zip(&inputs)
            .filter(|(s, _)| s.role == Role::Trainable)
            .map(|(_, t)| t.clone())
            .collect();
        let small_bytes: usize = small.iter().map(Tensor::size_bytes).sum();
        let s_expand = time_it(3, 15, || {
            let _ = ctx.session.run(recon, &inputs).unwrap();
        });
        let s_small = time_it(3, 15, || {
            for t in &small {
                let _ = ctx.session.to_device(t).unwrap();
            }
        });
        let measured = s_small.median() + s_expand.median();
        let comp_proj = small_bytes as f64 / PCIE_GBPS + s_expand.median();
        table.row(vec![
            model.into(),
            "MCNC (α,β)+expand".into(),
            format!("{} KiB", small_bytes / 1024),
            fmt_time(measured),
            fmt_time(comp_proj),
            format!("{:.2}x", dense_proj / comp_proj),
        ]);
    }
    table.print();
    table.save_csv("table8_transfer");

    // Paper-scale analytic check (ViT-S, 22.05M params, 100x compression,
    // RTX A6000): effective host→device bandwidth calibrated from the
    // paper's dense measurement (88.2 MB / 35.5 ms ≈ 2.48 GB/s), generator
    // throughput from a ~30% MXU/CUDA-core utilization of the A6000's
    // 38.7 f32 TFLOP/s on these skinny matmuls.
    let dense_mb = 22.05e6 * 4.0;
    let bw = dense_mb / 35.5e-3; // calibrated
    let gen = mcnc::mcnc::GenCfg { k: 9, d: 1000, width: 1000, depth: 3, ..Default::default() };
    let n_chunks = (22.05e6 / gen.d as f64).ceil();
    let recon_flops = n_chunks * gen.flops_per_chunk() as f64;
    let gpu = 38.7e12 * 0.3;
    let comp = dense_mb / 100.0 / bw + recon_flops / gpu;
    println!(
        "\npaper-scale projection (ViT-S @100x, A6000): dense {:.1} ms vs \
         (α,β)+expand {:.1} ms → {:.1}x (paper measured 35.5 → 17.8 ms = 2.0x)",
        35.5,
        comp * 1e3,
        35.5e-3 / comp
    );
    println!(
        "CPU-measured rows above are expansion-bound at this model scale; \
         the bytes-moved ratio (the transferable quantity) matches the paper's 100x."
    );

    // Sharded-serving corollary (the coordinator's n_shards sweep): every
    // engine shard stages its own replica of the model statics, so the
    // bytes staged grow ×N for a dense ship but stay tiny when each shard
    // ships (α, β) and expands locally — the same cheap-reconstruction
    // argument, multiplied by the shard count.
    println!("\nshard replication (ViT-S @100x shapes, bytes staged per replica set):");
    for n_shards in [1usize, 2, 4] {
        let dense = dense_mb * n_shards as f64;
        let comp = dense_mb / 100.0 * n_shards as f64;
        println!(
            "  n_shards={n_shards}: dense {:.1} MB vs MCNC (α,β) {:.2} MB ({:.0}x less staged)",
            dense / 1e6,
            comp / 1e6,
            dense / comp
        );
    }
}
