//! Table 6: first-layer input frequency ablation — one HLO, freq is a
//! runtime static input.

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{steps_mlp, Ctx};
use mcnc::tensor::Tensor;
use mcnc::train::{self, LrSchedule, TrainCfg, TrainState};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(42, 10, 28, 28, 1));
    let steps = steps_mlp();
    let mut table = Table::new("Table 6 — input frequency vs accuracy", &["frequency", "val acc"]);
    for freq in [1.0f32, 2.0, 4.0, 8.0, 16.0, 32.0] {
        let mut st = TrainState::new(&ctx.session, "mlp_mcnc02_freqin_train", 5).unwrap();
        st.set("freq", Tensor::scalar_f32(freq)).unwrap();
        let cfg = TrainCfg {
            steps,
            batch: 128,
            schedule: LrSchedule::Cosine { base: 0.05, total: steps, floor_frac: 0.05 },
            ..TrainCfg::default()
        };
        let hist = train::run(&mut st, Arc::clone(&data), &cfg).unwrap();
        table.row(vec![format!("{freq}"), format!("{:.3}", hist.final_val_acc())]);
    }
    table.print();
    table.save_csv("table6_frequency");
    println!("\npaper shape: freq 1.0 ≈ linear generator; gains saturate by ~4-8.");
}
