//! Tables 15 & 16 (appendix): generator width sweep and depth sweep
//! (± residual connections).

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{steps_mlp, Ctx};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(42, 10, 28, 28, 1));
    let steps = steps_mlp();
    let lrs = [0.05f32, 0.01];

    let mut t15 = Table::new("Table 15 — generator width", &["width", "val acc"]);
    for w in [64usize, 128, 256, 512, 1024] {
        let exec = if w == 256 {
            "mlp_mcnc02_train".to_string()
        } else {
            format!("mlp_mcnc02_w{w}_train")
        };
        let (acc, _) = ctx.best_acc(&exec, Arc::clone(&data), steps, &lrs, 5).unwrap();
        t15.row(vec![w.to_string(), format!("{acc:.3}")]);
    }
    t15.print();
    t15.save_csv("table15_width");

    let mut t16 = Table::new(
        "Table 16 — generator depth (± residual)",
        &["depth", "acc (plain)", "acc (residual)"],
    );
    for depth in [2usize, 3, 4, 5] {
        let plain = if depth == 3 {
            "mlp_mcnc02_train".to_string()
        } else {
            format!("mlp_mcnc02_dep{depth}_train")
        };
        let (acc_p, _) = ctx.best_acc(&plain, Arc::clone(&data), steps, &lrs, 5).unwrap();
        let acc_r = if depth >= 3 {
            let (a, _) = ctx
                .best_acc(&format!("mlp_mcnc02_dep{depth}res_train"), Arc::clone(&data), steps, &lrs, 5)
                .unwrap();
            format!("{a:.3}")
        } else {
            "n/a".into()
        };
        t16.row(vec![depth.to_string(), format!("{acc_p:.3}"), acc_r]);
    }
    t16.print();
    t16.save_csv("table16_depth");
    println!("\npaper shape: width saturates ≥~128; depth ≥ 3 helps, residuals don't.");
}
