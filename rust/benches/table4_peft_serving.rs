//! Table 4: instruction-finetuning + serving — LoRA vs NOLA vs MCNC on the
//! LM analog. Reports trainable params, task quality (train/val loss +
//! next-token acc, the MMLU stand-in), serving throughput + queue wait
//! under a multi-task workload, and on-the-fly reconstruction GFLOPs
//! (measured here + the paper's LLaMA-shape numbers from the analytic
//! model). A second table sweeps the coordinator's shard count
//! (n_shards ∈ {1, 2, 4}) on the MCNC kind and writes the scaling
//! trajectory to `BENCH_table4_serving.json`. A third table replays the
//! same open-loop workload against a mock engine under a deterministic
//! chaos fault schedule (batch panics, batch errors, shard kills) and
//! reports availability. A fourth table drives the same workload through
//! the MCNP1 socket front-end over C ∈ {1, 8, 32} loopback connections and
//! reports client-measured end-to-end p50/p99. The chaos and socket
//! sections need no PJRT artifacts and are the ones run under `-- --smoke`.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use mcnc::coordinator::workload::{open_loop, replay, replay_socket};
use mcnc::coordinator::{
    Batch, BatchPolicy, Chaos, ChaosCfg, EngineCore, Mode, ServeStats, Server, ServerCfg,
};
use mcnc::net::{NetCfg, NetListener};
use mcnc::data::{Dataset, MarkovLm, Split};
use mcnc::exp::{steps_lm, Ctx};
use mcnc::flops;
use mcnc::train::{self, LrSchedule, TrainCfg, TrainState};
use mcnc::util::bench::{bench_steps, Table};

/// Minimal engine for the availability table: every task served, constant
/// prediction. Fault behaviour comes entirely from the [`Chaos`] wrapper,
/// so the table isolates the coordinator's recovery path.
struct AvailMock {
    n_tasks: usize,
    stats: ServeStats,
}

impl EngineCore for AvailMock {
    fn seq(&self) -> usize {
        32
    }

    fn has_task(&self, task: usize) -> bool {
        task < self.n_tasks
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        self.stats.batches += 1;
        Ok(batch.requests.iter().map(|_| 0).collect())
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }
}

/// Table 4c: replay an open-loop workload while a seeded chaos schedule
/// injects batch panics, batch errors and shard kills; report how much of
/// the offered load still completes and what the supervisor had to do.
fn availability_under_faults(smoke: bool) {
    let n_tasks = 6;
    let rate = 300.0;
    let secs = if smoke { 0.4 } else { 2.0 };
    let lm = MarkovLm::base(1, 128, 32);
    let schedule = open_loop(7, rate, Duration::from_secs_f64(secs), n_tasks, 1.0);
    let mut table = Table::new(
        "Table 4c — availability under a deterministic fault schedule (mock engine)",
        &["n_shards", "ok", "failed", "rejected", "restarts", "batch panics",
          "breaker opens", "throughput req/s"],
    );
    for n_shards in [1usize, 2, 4] {
        let chaos = Chaos::new(ChaosCfg {
            seed: 0xFA_017 + n_shards as u64,
            window: 16,
            panics: 2,
            errors: 2,
            kills: 1,
            ..ChaosCfg::default()
        });
        let cfg = ServerCfg {
            n_tasks,
            n_shards,
            policy: BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(2) },
            heartbeat: Duration::from_millis(10),
            seed: 1,
            ..ServerCfg::default()
        };
        let c = chaos.clone();
        let server = Server::start_with(&cfg, move |_shard| {
            c.factory_gate()?;
            Ok(c.wrap(AvailMock { n_tasks, stats: ServeStats::default() }))
        })
        .expect("start chaos mock server");
        let rep = replay(&server, &lm, 9, &schedule);
        assert_eq!(rep.dropped, 0, "{n_shards} shards: a receiver closed without a response");
        let stats = server.stop().unwrap();
        table.row(vec![
            n_shards.to_string(),
            format!("{}/{}", rep.ok, schedule.len()),
            rep.failed.to_string(),
            rep.rejected.to_string(),
            stats.restarts.to_string(),
            stats.batch_panics.to_string(),
            stats.breaker_opens.to_string(),
            format!("{:.1}", stats.throughput()),
        ]);
    }
    table.print();
    if !smoke {
        table.save_csv("table4_availability");
        table.save_json("table4_availability");
    }
}

/// Table 4e: end-to-end latency through the MCNP1 socket front-end —
/// a loopback `serve --listen` + `replay --connect` round trip against a
/// mock engine, swept over C concurrent connections. The latency here is
/// client-measured (request write → reply decode), so it includes framing,
/// kernel socket hops and the listener poll loop on top of the dispatch
/// path the other tables measure. Needs no PJRT artifacts; runs under
/// `-- --smoke` so CI exercises the socket path every run.
fn socket_sweep(smoke: bool) {
    use std::sync::atomic::{AtomicBool, Ordering};

    let n_tasks = 6;
    let rate = if smoke { 200.0 } else { 400.0 };
    let secs = if smoke { 0.3 } else { 2.0 };
    let lm = MarkovLm::base(1, 128, 32);
    let schedule = open_loop(11, rate, Duration::from_secs_f64(secs), n_tasks, 1.0);
    let mut table = Table::new(
        "Table 4e — end-to-end latency over the MCNP1 socket front-end (loopback, mock engine)",
        &["conns", "ok", "rejected", "failed", "e2e p50", "e2e p99", "e2e max"],
    );
    for conns in [1usize, 8, 32] {
        let cfg = ServerCfg {
            n_tasks,
            n_shards: 2,
            policy: BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(2) },
            heartbeat: Duration::from_millis(10),
            seed: 1,
            ..ServerCfg::default()
        };
        let server = Server::start_with(&cfg, move |_shard| {
            Ok(AvailMock { n_tasks, stats: ServeStats::default() })
        })
        .expect("start mock server");
        let listener = NetListener::bind(NetCfg::default()).expect("bind loopback");
        let addr = listener.local_addr().expect("local addr").to_string();
        let stop = AtomicBool::new(false);
        let rep = std::thread::scope(|scope| {
            let pump = scope.spawn(|| listener.run(&server, &stop));
            let rep =
                replay_socket(&addr, &lm, 9, &schedule, conns, None, Duration::from_secs(30))
                    .expect("socket replay");
            stop.store(true, Ordering::Relaxed);
            pump.join().expect("listener thread").expect("listener run");
            rep
        });
        assert_eq!(rep.conn_errors, 0, "{conns} conns: fatal connection errors");
        assert_eq!(rep.missing, 0, "{conns} conns: unanswered requests");
        server.stop().expect("stop mock server");
        table.row(vec![
            conns.to_string(),
            format!("{}/{}", rep.ok, rep.sent),
            rep.rejected.to_string(),
            rep.failed.to_string(),
            format!("{:?}", rep.latency.percentile(50.0)),
            format!("{:?}", rep.latency.percentile(99.0)),
            format!("{:?}", rep.latency.max()),
        ]);
    }
    table.print();
    if !smoke {
        table.save_csv("table4_socket");
        table.save_json("table4_socket");
    }
}

/// Table 4d: the serving runs above as seen through the process-wide
/// metrics registry — the same figures an operator scraping
/// `mcnc serve --metrics-file` would get. Cumulative across every server
/// this process started (the registry is global by design), so the rows
/// are cross-checks of the per-server tables, not replacements.
fn registry_view(smoke: bool) {
    let snap = mcnc::obs::registry().snapshot();
    let qw = snap.histogram_merged("mcnc_serve_queue_wait_us");
    let lat = snap.histogram_merged("mcnc_serve_latency_us");
    let batches = snap.counter_sum("mcnc_serve_batches_total");
    let batch_requests = snap.counter_sum("mcnc_serve_batch_requests_total");
    let occupancy = if batches == 0 {
        "-".to_string()
    } else {
        format!("{:.2}", batch_requests as f64 / batches as f64)
    };
    let mut table =
        Table::new("Table 4d — registry view (process-wide, cumulative)", &["metric", "value"]);
    table.row(vec![
        "requests".into(),
        snap.counter_sum("mcnc_serve_requests_total").to_string(),
    ]);
    table.row(vec![
        "queue wait p50/p99".into(),
        format!("{:?}/{:?}", qw.percentile_mid(50.0), qw.percentile_mid(99.0)),
    ]);
    table.row(vec![
        "latency p50/p99".into(),
        format!("{:?}/{:?}", lat.percentile_mid(50.0), lat.percentile_mid(99.0)),
    ]);
    table.row(vec!["batch occupancy".into(), occupancy]);
    table.row(vec![
        "deadline shed".into(),
        snap.counter_sum("mcnc_serve_deadline_shed_total").to_string(),
    ]);
    table.row(vec![
        "restarts".into(),
        snap.counter_sum("mcnc_serve_restarts_total").to_string(),
    ]);
    table.row(vec![
        "breaker opens".into(),
        snap.counter_sum("mcnc_serve_breaker_opens_total").to_string(),
    ]);
    table.print();
    if !smoke {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("BENCH_table4_metrics.json");
        let body = mcnc::util::json::to_string(&mcnc::obs::export::snapshot_json(&snap));
        match std::fs::write(&path, body) {
            Ok(()) => println!("[bench] wrote {}", path.display()),
            Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    availability_under_faults(smoke);
    socket_sweep(smoke);
    if !smoke {
        if let Some(ctx) = Ctx::open() {
            full_run(&ctx);
        }
    }
    registry_view(smoke);
}

fn full_run(ctx: &Ctx) {
    let steps = steps_lm();
    let base_chain = MarkovLm::base(11, 128, 32);
    let task_chain = MarkovLm::task(&base_chain, 1, 0.8);
    let task_data: Arc<dyn Dataset> = Arc::new(task_chain);

    let mut table = Table::new(
        "Table 4 — PEFT quality + serving (LM analog of LLaMA-2)",
        &["method", "trainable", "task acc", "train loss", "val loss",
          "throughput req/s", "queue p50/p99", "recon GFLOPs/pass"],
    );

    // serving workload shared across methods
    let rate = 150.0;
    let secs = bench_steps(2, 10) as f64;
    let n_tasks = 6;
    let schedule = open_loop(7, rate, Duration::from_secs_f64(secs), n_tasks, 1.0);

    for (kind, lr) in [("lm_lora1", 0.005f32), ("lm_lora8", 0.005), ("lm_nola8", 0.02), ("lm_mcnclora8", 0.02)] {
        // --- fine-tune on the task ---
        let mut st = TrainState::new(&ctx.session, &format!("{kind}_train"), 21).unwrap();
        let cfg = TrainCfg {
            steps,
            batch: 16,
            schedule: LrSchedule::Cosine { base: lr, total: steps, floor_frac: 0.1 },
            ..TrainCfg::default()
        };
        let hist = train::run(&mut st, Arc::clone(&task_data), &cfg).unwrap();
        let train_loss = hist.losses[hist.losses.len().saturating_sub(5)..]
            .iter()
            .sum::<f32>()
            / 5.0;
        let (x, y) = task_data.batch(Split::Val, 0, 16);
        let ev = st.eval(x, y).unwrap();

        // --- serve under the multi-task workload ---
        let cfg = ServerCfg {
            kind: kind.into(),
            n_tasks,
            policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(5) },
            mode: Mode::OnTheFly,
            cache_bytes: 64 << 20,
            seed: 1,
            ..ServerCfg::default()
        };
        let server = Server::start(mcnc::runtime::artifacts_dir(), cfg).expect("start server");
        let rep = replay(&server, &base_chain, 9, &schedule);
        assert_eq!(rep.dropped, 0, "{kind}: receivers dropped without a response");
        let stats = server.stop().unwrap();

        let entry = ctx.session.entry(&format!("{kind}_predict")).unwrap();
        table.row(vec![
            kind.into(),
            entry.trainable_comp().to_string(),
            format!("{:.3}", ev.acc),
            format!("{train_loss:.3}"),
            format!("{:.3}", ev.loss),
            format!("{:.1}", stats.throughput()),
            format!(
                "{:?}/{:?}",
                stats.queue_wait.percentile(50.0),
                stats.queue_wait.percentile(99.0)
            ),
            format!("{:.4}", entry.recon_flops() as f64 / 1e9),
        ]);
    }
    table.print();
    table.save_csv("table4_peft_serving");

    // --- shard-scaling sweep: same workload, N engine shards ---
    let mut sweep = Table::new(
        "Table 4b — coordinator shard scaling (lm_mcnclora8, OnTheFly)",
        &["n_shards", "ok", "rejected", "errors", "throughput req/s", "p50", "p99",
          "queue p50", "queue p99"],
    );
    for n_shards in [1usize, 2, 4] {
        let cfg = ServerCfg {
            kind: "lm_mcnclora8".into(),
            n_tasks,
            n_shards,
            policy: BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(5) },
            mode: Mode::OnTheFly,
            cache_bytes: 64 << 20,
            seed: 1,
            ..ServerCfg::default()
        };
        let server = Server::start(mcnc::runtime::artifacts_dir(), cfg).expect("start server");
        let rep = replay(&server, &base_chain, 9, &schedule);
        let stats = server.stop().unwrap();
        sweep.row(vec![
            n_shards.to_string(),
            format!("{}/{}", rep.ok, schedule.len()),
            stats.rejected.to_string(),
            stats.errors.to_string(),
            format!("{:.1}", stats.throughput()),
            format!("{:?}", stats.latency.percentile(50.0)),
            format!("{:?}", stats.latency.percentile(99.0)),
            format!("{:?}", stats.queue_wait.percentile(50.0)),
            format!("{:?}", stats.queue_wait.percentile(99.0)),
        ]);
    }
    sweep.print();
    sweep.save_csv("table4_shard_scaling");
    sweep.save_json("table4_serving");

    // paper's A.6 numbers from the analytic FLOPs model
    println!("\nAppendix A.6 (paper shapes, analytic):");
    println!("  LLaMA-7B : NOLA {:.2} GF vs MCNC {:.2} GF ({:.0}% fewer)",
             flops::paper_nola_7b() / 1e9, flops::paper_mcnc_7b() / 1e9,
             100.0 * (1.0 - flops::paper_mcnc_7b() / flops::paper_nola_7b()));
    println!("  LLaMA-13B: NOLA {:.2} GF vs MCNC {:.2} GF ({:.1}x)",
             flops::paper_nola_13b() / 1e9, flops::paper_mcnc_13b() / 1e9,
             flops::paper_nola_13b() / flops::paper_mcnc_13b());
    println!("\npaper shape: MCNC ≈ NOLA quality at equal params, higher serving \
              throughput from cheaper on-the-fly reconstruction; LoRA needs 10-100x \
              more trainable params. Shards scale throughput until the XLA CPU \
              executor saturates; queue wait is the backpressure signal.");
}
