//! Table 3: extreme compression (~5k trainable params) on ResNet-20/56 ×
//! CIFAR-10/100 analogs — MCNC ± LoRA vs PRANC vs NOLA vs dense baseline.

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{full_mode, steps_resnet, Ctx};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let steps = steps_resnet();
    let lrs = [0.02f32, 0.05, 0.01];
    let mut table = Table::new(
        "Table 3 — ~5k trainable params, arch × dataset",
        &["arch", "dataset", "method", "params", "val acc"],
    );

    // quick mode: skip the slow ResNet-56 rows unless running full
    let settings: Vec<(&str, usize)> = if full_mode() {
        vec![("r20c10", 10), ("r20c100", 100), ("r56c10", 10), ("r56c100", 100)]
    } else {
        vec![("r20c10", 10), ("r20c100", 100)]
    };

    for (arch, classes) in settings {
        let data: Arc<dyn Dataset> = Arc::new(SynthVision::cifar_like(55, classes));
        let (acc, _) = ctx
            .best_acc(&format!("{arch}_dense5k_train"), Arc::clone(&data), steps, &[0.004], 3)
            .unwrap();
        let dc = ctx.session.entry(&format!("{arch}_dense5k_train")).unwrap().registry().unwrap().dc;
        table.row(vec![arch.into(), format!("c{classes}"), "baseline".into(), dc.to_string(), format!("{acc:.3}")]);
        for method in ["pranc5k", "nola5k", "mcnc5k", "mcnclora5k"] {
            let exec = format!("{arch}_{method}_train");
            let params = ctx.session.entry(&exec).unwrap().trainable_comp();
            let (acc, _) = ctx.best_acc(&exec, Arc::clone(&data), steps, &lrs, 3).unwrap();
            table.row(vec![
                arch.into(),
                format!("c{classes}"),
                method.trim_end_matches("5k").into(),
                params.to_string(),
                format!("{acc:.3}"),
            ]);
        }
    }
    table.print();
    table.save_csv("table3_cifar_extreme");
    println!("\npaper shape: MCNC ≥ NOLA > PRANC ≫ dense-at-5k-impossible; LoRA variant best.");
    if !full_mode() {
        println!("(ResNet-56 rows: MCNC_BENCH_FULL=1)");
    }
}
