//! Table 7: model size grows, compressed budget fixed (540 params) — the
//! over-parameterization premise: bigger models have more good solutions
//! reachable from the fixed-size manifold.

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{steps_mlp, Ctx};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(42, 10, 28, 28, 1));
    let steps = steps_mlp();
    let lrs = [0.05f32, 0.01, 0.1];
    let mut table = Table::new(
        "Table 7 — MLP hidden size @ fixed 540 compressed params",
        &["hidden", "model params", "val acc"],
    );
    for hidden in [16usize, 32, 64, 128, 256, 512] {
        let exec = if hidden == 256 {
            "mlp_mcnc02_train".to_string()
        } else {
            format!("mlp{hidden}_mcnc_fix_train")
        };
        let dc = ctx.session.entry(&exec).unwrap().registry().unwrap().dc;
        let (acc, _) = ctx.best_acc(&exec, Arc::clone(&data), steps, &lrs, 5).unwrap();
        table.row(vec![hidden.to_string(), dc.to_string(), format!("{acc:.3}")]);
    }
    table.print();
    table.save_csv("table7_model_scale");
    println!("\npaper shape: accuracy rises with model size at fixed budget.");
}
