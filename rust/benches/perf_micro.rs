//! Micro-benchmarks for the §Perf pass: generator reconstruction throughput
//! (native vs PJRT), router/batcher ops, LRU cache, JSON parsing, session
//! overhead, and the observability hook costs (EXPERIMENTS.md §Perf /
//! docs/OBSERVABILITY.md §Overhead). `-- --smoke` runs only the obs
//! overhead section — the CI gate that disabled tracing stays one relaxed
//! atomic load.

use std::time::{Duration, Instant};

use anyhow::Result;
use mcnc::codec::{quantizer, Codec, ContainerHeader, Decoder, Encoder};
use mcnc::coordinator::{
    Batch, BatchPolicy, EngineCore, Request, Router, ServeStats, Server, ServerCfg,
};
use mcnc::exp::Ctx;
use mcnc::mcnc::kernel::{self, Isa};
use mcnc::mcnc::{GenCfg, Generator};
use mcnc::obs::{self, trace, Kind, TraceMode};
use mcnc::runtime::init;
use mcnc::tensor::Tensor;
use mcnc::util::bench::{fmt_si, fmt_time, time_it, Table};
use mcnc::util::prng::Stream;

/// Free-running engine for the serve-overhead rows: fault behaviour and
/// artifact IO are out of the picture, so tracing on/off is the only
/// variable between the two measurements.
#[derive(Default)]
struct NullEngine {
    stats: ServeStats,
}

impl EngineCore for NullEngine {
    fn seq(&self) -> usize {
        8
    }

    fn has_task(&self, task: usize) -> bool {
        task < 4
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        self.stats.batches += 1;
        Ok(batch.requests.iter().map(|_| 0).collect())
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }
}

/// Closed-loop mock-serve throughput under a given trace mode.
fn serve_throughput(mode: TraceMode, window: Duration) -> f64 {
    trace::set_mode(mode);
    trace::clear();
    let cfg = ServerCfg {
        n_tasks: 4,
        n_shards: 1,
        policy: BatchPolicy { max_batch: 8, max_delay: Duration::ZERO },
        ..ServerCfg::default()
    };
    let server = Server::start_with(&cfg, |_| -> Result<NullEngine> { Ok(NullEngine::default()) })
        .expect("start overhead server");
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed() < window {
        let rxs: Vec<_> = (0..4).map(|t| server.submit(t, vec![0; 8])).collect();
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(5)).expect("response");
        }
        n += 4;
    }
    let thr = n as f64 / t0.elapsed().as_secs_f64();
    server.stop().expect("stop overhead server");
    trace::set_mode(TraceMode::Off);
    trace::clear();
    thr
}

/// Observability hook costs: the disabled-tracing fast path (one relaxed
/// atomic load), registry counter/histogram updates, ring writes with
/// tracing on, and the end-to-end serve delta between tracing modes.
fn obs_overhead(table: &mut Table, smoke: bool) {
    let ops: u64 = if smoke { 200_000 } else { 1_000_000 };
    let per = |s: &mcnc::util::bench::Stats| format!("{:.2}", s.median() * 1e9 / ops as f64);

    // (a) the disabled hook: trace::span behind `enabled()` — this row is
    // the "tracing off costs one relaxed load" claim, measured.
    trace::set_mode(TraceMode::Off);
    let t = Instant::now();
    let s = time_it(2, 8, || {
        for i in 0..ops {
            trace::span(i, 0, 0, Kind::Gemm, t, t);
        }
    });
    table.row(vec!["obs span, tracing off".into(), "ns/op".into(), per(&s)]);

    // (b) the same hook with the ring live
    trace::set_mode(TraceMode::All);
    let s = time_it(2, 8, || {
        for i in 0..ops {
            trace::span(i, 0, 0, Kind::Gemm, t, t);
        }
    });
    trace::set_mode(TraceMode::Off);
    trace::clear();
    table.row(vec!["obs span, tracing all".into(), "ns/op".into(), per(&s)]);

    // (c) registry handles: pre-bound counter inc and histogram record
    let c = obs::registry().counter("perf_obs_counter_total", &[]);
    let s = time_it(2, 8, || {
        for _ in 0..ops {
            c.inc();
        }
    });
    table.row(vec!["obs counter inc (pre-bound)".into(), "ns/op".into(), per(&s)]);
    let h = obs::registry().histogram("perf_obs_record_us", &[]);
    let d = Duration::from_micros(7);
    let s = time_it(2, 8, || {
        for _ in 0..ops {
            h.record(d);
        }
    });
    table.row(vec!["obs histogram record".into(), "ns/op".into(), per(&s)]);

    // (d) end to end: mock-serve throughput, tracing off vs sampled vs all
    let window = Duration::from_millis(if smoke { 120 } else { 400 });
    let off = serve_throughput(TraceMode::Off, window);
    let sampled = serve_throughput(TraceMode::Sampled(64), window);
    let all = serve_throughput(TraceMode::All, window);
    table.row(vec!["mock serve, tracing off".into(), "req/s".into(), fmt_si(off)]);
    table.row(vec!["mock serve, tracing sampled:64".into(), "req/s".into(), fmt_si(sampled)]);
    table.row(vec!["mock serve, tracing all".into(), "req/s".into(), fmt_si(all)]);
    table.row(vec![
        "serve overhead, all vs off".into(),
        "%".into(),
        format!("{:.2}", 100.0 * (1.0 - all / off.max(f64::MIN_POSITIVE))),
    ]);
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut table = Table::new("perf micro", &["target", "metric", "value"]);
    if smoke {
        obs_overhead(&mut table, true);
        table.print();
        return;
    }

    // --- native generator reconstruction: seed matvec path vs GEMM ---
    let cfg = GenCfg { k: 9, d: 5000, width: 256, depth: 3, ..GenCfg::default() };
    let n = 54usize;
    let gen = Generator::from_seed(cfg.clone(), 1);
    let alpha = Stream::new(2).normal_f32(n * cfg.k, 0.5);
    let beta = vec![1.0f32; n];
    let mut out = vec![0.0f32; n * cfg.d];
    let rate = |s: &mcnc::util::bench::Stats| {
        let params = (n * cfg.d) as f64 / s.median();
        let flops = (n * cfg.flops_per_chunk()) as f64 / s.median();
        (params, flops, format!("{} | {:.2}", fmt_si(params), flops / 1e9))
    };

    // (a) retained reference: per-chunk matvecs, single thread
    let s_st = time_it(3, 20, || gen.forward_naive(&alpha, &beta, &mut out));
    let (_, _, cell) = rate(&s_st);
    table.row(vec![
        "native gen, naive matvec 1T (mlp02)".into(),
        "params/s | GFLOP/s".into(),
        cell,
    ]);

    // (b) the seed hot path: naive matvecs + one OS-thread spawn per call
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let s_seed = time_it(3, 20, || {
        let per = n.div_ceil(threads.min(n));
        std::thread::scope(|scope| {
            let mut rest = &mut out[..];
            let mut start = 0usize;
            while start < n {
                let take = per.min(n - start);
                let (head, tail) = rest.split_at_mut(take * cfg.d);
                rest = tail;
                let a = &alpha[start * cfg.k..(start + take) * cfg.k];
                let b = &beta[start..start + take];
                let g = &gen;
                scope.spawn(move || g.forward_naive(a, b, head));
                start += take;
            }
        });
    });
    let (seed_params, _, cell) = rate(&s_seed);
    table.row(vec![
        "native gen, seed path (spawn/call)".into(),
        "params/s | GFLOP/s".into(),
        cell,
    ]);

    // (c) blocked-GEMM engine on the persistent pool (the new hot path)
    let s_gemm = time_it(3, 20, || gen.forward_into(&alpha, &beta, &mut out));
    let (gemm_params, _, cell) = rate(&s_gemm);
    table.row(vec![
        "native gen, blocked GEMM + pool".into(),
        "params/s | GFLOP/s".into(),
        cell,
    ]);
    table.row(vec![
        "native gen speedup vs seed path".into(),
        "x".into(),
        format!("{:.2}", gemm_params / seed_params),
    ]);

    // --- raw kernel: scalar vs dispatched SIMD microkernel ---
    // single-threaded single GEMM (no pool, no generator) so the two rows
    // isolate the microkernel itself; methodology in EXPERIMENTS.md
    // §Kernels. MCNC_SIMD=scalar forces the dispatched row to match the
    // scalar one.
    table.row(vec![
        "kernel dispatch".into(),
        "isa".into(),
        kernel::active().name().into(),
    ]);
    let (km, kk, kn) = (192usize, 512usize, 768usize);
    let ka = Stream::new(11).uniform_f32(km * kk, -1.0, 1.0);
    let kb = Stream::new(12).uniform_f32(kk * kn, -1.0, 1.0);
    let mut kc = vec![0.0f32; km * kn];
    let kflops = 2.0 * (km * kk * kn) as f64;
    let pb_scalar = kernel::pack_b_for(Isa::Scalar, &kb, kk, kn);
    let s = time_it(3, 15, || kernel::gemm(&ka, km, &pb_scalar, &mut kc));
    let scalar_gflops = kflops / s.median() / 1e9;
    table.row(vec![
        "kernel gemm 192x512x768, scalar".into(),
        "GFLOP/s".into(),
        format!("{scalar_gflops:.2}"),
    ]);
    let pb_simd = kernel::pack_b(&kb, kk, kn);
    let s = time_it(3, 15, || kernel::gemm(&ka, km, &pb_simd, &mut kc));
    let simd_gflops = kflops / s.median() / 1e9;
    table.row(vec![
        format!("kernel gemm 192x512x768, {}", pb_simd.isa().name()),
        "GFLOP/s".into(),
        format!("{simd_gflops:.2}"),
    ]);
    table.row(vec![
        "kernel gemm simd speedup vs scalar".into(),
        "x".into(),
        format!("{:.2}", simd_gflops / scalar_gflops),
    ]);

    // --- compressed-domain kernel: int8 gemm_q vs the f32 microkernel ---
    // same shape as the f32 rows so GOP/s compares directly (2·m·k·n MACs
    // either way); B carries 4-row scale groups (block = 4·n, the SIMD-
    // admissible layout) and A is quantized once outside the timed loop —
    // the per-request quantize cost is visible in the serve benches.
    let qblock = 4 * kn;
    let qz = quantizer::quantize_with(Isa::Scalar, &kb, 8, qblock);
    let bq_scalar = kernel::pack_bq_for(Isa::Scalar, kk, kn, 8, qblock, &qz.scales, &qz.symbols)
        .expect("pack int8 B (scalar)");
    let qa = kernel::quantize_a(&ka, km, kk, bq_scalar.group_rows());
    let s = time_it(3, 15, || kernel::gemm_q(&qa, &bq_scalar, &mut kc));
    let scalar_q_gops = kflops / s.median() / 1e9;
    table.row(vec![
        "kernel gemm_q int8 192x512x768, scalar".into(),
        "GOP/s".into(),
        format!("{scalar_q_gops:.2}"),
    ]);
    let bq_simd =
        kernel::pack_bq(kk, kn, 8, qblock, &qz.scales, &qz.symbols).expect("pack int8 B (simd)");
    let s = time_it(3, 15, || kernel::gemm_q(&qa, &bq_simd, &mut kc));
    let simd_q_gops = kflops / s.median() / 1e9;
    table.row(vec![
        format!("kernel gemm_q int8 192x512x768, {}", bq_simd.isa().name()),
        "GOP/s".into(),
        format!("{simd_q_gops:.2}"),
    ]);
    table.row(vec![
        "kernel int8 speedup vs f32 (dispatched)".into(),
        "x".into(),
        format!("{:.2}", simd_q_gops / simd_gflops),
    ]);

    // --- quantized cold fill: rANS int8 frame → PackedBQ, no f32 detour ---
    // GB/s is f32-equivalent logical weight bytes per second — the number a
    // serving fill effectively delivers, comparable across codecs.
    let cold_w = Tensor::from_f32(kb.clone(), &[kk, kn]).expect("cold-fill tensor");
    let hdr = ContainerHeader {
        entry: "perf_cold_fill".into(),
        seed: 0,
        step: 0.0,
        n_tensors: Some(1),
    };
    let mut enc = Encoder::new(Vec::new(), &hdr).expect("cold-fill encoder");
    enc.write_tensor("w", &cold_w, Codec::Int8 { block: qblock }).expect("cold-fill frame");
    let (cold_bytes, _) = enc.finish().expect("cold-fill container");
    let logical_gb = (kk * kn * std::mem::size_of::<f32>()) as f64 / 1e9;
    let s = time_it(2, 10, || {
        let mut dec = Decoder::new(&cold_bytes[..]).expect("cold-fill decoder");
        let _ = dec.next_packed_q(kernel::active()).expect("cold-fill frame decode");
    });
    table.row(vec![
        "cold fill int8 frame -> PackedBQ 512x768".into(),
        "GB/s (f32-equiv)".into(),
        format!("{:.2}", logical_gb / s.median()),
    ]);

    // --- quantizer scans (MCNC2 encode hot path): scalar vs SIMD ---
    let qw = Stream::new(13).normal_f32(1 << 21, 0.05);
    let qgb = (qw.len() * std::mem::size_of::<f32>()) as f64 / 1e9;
    let s = time_it(2, 10, || {
        let _ = quantizer::quantize_with(Isa::Scalar, &qw, 8, 64);
    });
    let scalar_gbs = qgb / s.median();
    table.row(vec![
        "quantize int8/64 scan, scalar".into(),
        "GB/s".into(),
        format!("{scalar_gbs:.2}"),
    ]);
    let s = time_it(2, 10, || {
        let _ = quantizer::quantize_with(kernel::active(), &qw, 8, 64);
    });
    let simd_gbs = qgb / s.median();
    table.row(vec![
        format!("quantize int8/64 scan, {}", kernel::active().name()),
        "GB/s".into(),
        format!("{simd_gbs:.2}"),
    ]);
    table.row(vec![
        "quantize simd speedup vs scalar".into(),
        "x".into(),
        format!("{:.2}", simd_gbs / scalar_gbs),
    ]);

    // --- PJRT generator executable ---
    if let Some(ctx) = Ctx::open() {
        let entry = ctx.session.entry("gen_mlp02_fwd").unwrap().clone();
        let slots = init::init_inputs(&entry, 1).unwrap();
        let mut inputs: Vec<Tensor> = slots.iter().map(|(_, t)| t.clone().unwrap()).collect();
        inputs[0] = Tensor::from_f32(alpha.clone(), &[n, cfg.k]).unwrap();
        inputs[1] = Tensor::from_f32(beta.clone(), &[n]).unwrap();
        ctx.session.load("gen_mlp02_fwd").unwrap();
        let s = time_it(3, 20, || {
            let _ = ctx.session.run("gen_mlp02_fwd", &inputs).unwrap();
        });
        table.row(vec![
            "PJRT generator (incl. marshal)".into(),
            "params/s".into(),
            fmt_si((n * cfg.d) as f64 / s.median()),
        ]);

        // session overhead: smallest executable round-trip
        let s = time_it(3, 30, || {
            let _ = ctx.session.run("gen_mlp02_fwd", &inputs).unwrap();
        });
        table.row(vec![
            "session round-trip".into(),
            "median".into(),
            fmt_time(s.median()),
        ]);
    }

    // --- router + batcher throughput ---
    let t0 = Instant::now();
    let mut total = 0u64;
    while t0.elapsed().as_secs_f64() < 0.3 {
        let mut r = Router::default();
        let now = Instant::now();
        for i in 0..10_000u64 {
            r.push(Request {
                id: i,
                task: (i % 16) as usize,
                tokens: Vec::new(),
                enqueued: now,
                deadline: None,
            });
        }
        let p = BatchPolicy { max_batch: 16, max_delay: std::time::Duration::ZERO };
        while r.next_batch(p, now, true).is_some() {}
        total += 10_000;
    }
    table.row(vec![
        "router push+batch".into(),
        "req/s".into(),
        fmt_si(total as f64 / t0.elapsed().as_secs_f64()),
    ]);

    // --- JSON manifest parse ---
    let man_path = mcnc::runtime::artifacts_dir().join("manifest.json");
    if let Ok(text) = std::fs::read_to_string(&man_path) {
        let s = time_it(2, 10, || {
            let _ = mcnc::util::json::parse(&text).unwrap();
        });
        table.row(vec![
            "json parse (manifest)".into(),
            "MB/s".into(),
            format!("{:.1}", text.len() as f64 / 1e6 / s.median()),
        ]);
    }

    // --- data generation ---
    use mcnc::data::{Dataset, Split, SynthVision};
    let ds = SynthVision::cifar_like(1, 10);
    let s = time_it(2, 10, || {
        let _ = ds.batch(Split::Train, 0, 64);
    });
    table.row(vec![
        "synth-cifar batch(64)".into(),
        "median".into(),
        fmt_time(s.median()),
    ]);

    // --- observability hook + serve overhead ---
    obs_overhead(&mut table, false);

    table.print();
    table.save_csv("perf_micro");
    table.save_json("perf_micro");
}
