//! Table 9: random vs SWGAN-trained generator for downstream compression
//! (ResNet-20 analog @ ~5k params, CIFAR-10/100 analogs). The trained
//! weights come from driving the swgan_r20gen artifact, then get installed
//! into the train state's gw* statics.

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{steps_resnet, Ctx};
use mcnc::mcnc::GenCfg;
use mcnc::runtime::{init, Role};
use mcnc::sphere;
use mcnc::tensor::Tensor;
use mcnc::train::{self, LrSchedule, TrainCfg, TrainState};
use mcnc::util::bench::{bench_steps, Table};
use mcnc::util::prng::Stream;

fn train_swgan(ctx: &Ctx, name: &str, steps: usize) -> Vec<Tensor> {
    let entry = ctx.session.entry(name).unwrap().clone();
    let cfg = GenCfg::from_json(entry.meta.get("gen").unwrap()).unwrap();
    let b = entry.meta.get("batch").unwrap().as_usize().unwrap();
    let p = entry.meta.get("n_proj").unwrap().as_usize().unwrap();
    let slots = init::init_inputs(&entry, 42).unwrap();
    let mut ws: Vec<Tensor> = slots
        .iter()
        .filter(|(s, _)| s.role == Role::Trainable)
        .map(|(_, t)| t.clone().unwrap())
        .collect();
    let mut ms: Vec<Tensor> = ws.iter().map(|w| Tensor::zeros(&w.dims)).collect();
    let mut vs = ms.clone();
    let mut t = 0.0f32;
    for step in 0..steps as u64 {
        let alpha =
            Tensor::from_f32(Stream::new(100 + step).uniform_f32(b * cfg.k, -1.0, 1.0), &[b, cfg.k])
                .unwrap();
        let target =
            Tensor::from_f32(sphere::sample_sphere(200 + step, b, cfg.d), &[b, cfg.d]).unwrap();
        let projs = sphere::sample_projections(300 + step, p, cfg.d);
        let mut pt = vec![0.0f32; cfg.d * p];
        for i in 0..p {
            for j in 0..cfg.d {
                pt[j * p + i] = projs[i * cfg.d + j];
            }
        }
        let proj = Tensor::from_f32(pt, &[cfg.d, p]).unwrap();
        let mut inputs = ws.clone();
        inputs.extend(ms.clone());
        inputs.extend(vs.clone());
        inputs.push(Tensor::scalar_f32(t));
        inputs.push(Tensor::scalar_f32(0.002));
        inputs.push(alpha);
        inputs.push(target);
        inputs.push(proj);
        let out = ctx.session.run(name, &inputs).unwrap();
        let d = ws.len();
        ws = out[..d].to_vec();
        ms = out[d..2 * d].to_vec();
        vs = out[2 * d..3 * d].to_vec();
        t = out[3 * d].scalar().unwrap();
    }
    ws
}

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let steps = steps_resnet();
    let mut table = Table::new(
        "Table 9 — random vs SWGAN-trained generator (R20 @ ~5k params)",
        &["dataset", "acc (random gen)", "acc (trained gen)"],
    );

    for classes in [10usize, 100] {
        let data: Arc<dyn Dataset> = Arc::new(SynthVision::cifar_like(55, classes));
        let exec = format!("r20c{classes}_mcnc5k_train");
        let swgan = if classes == 10 { "swgan_r20gen" } else { "swgan_r20c100gen" };
        let trained = train_swgan(&ctx, swgan, bench_steps(100, 1000));
        let mut accs = Vec::new();
        for use_trained in [false, true] {
            let mut st = TrainState::new(&ctx.session, &exec, 3).unwrap();
            if use_trained {
                for (i, w) in trained.iter().enumerate() {
                    st.set(&format!("gw{i}"), w.clone()).unwrap();
                }
            }
            let cfg = TrainCfg {
                steps,
                batch: 32,
                schedule: LrSchedule::Cosine { base: 0.02, total: steps, floor_frac: 0.05 },
                ..TrainCfg::default()
            };
            let hist = train::run(&mut st, Arc::clone(&data), &cfg).unwrap();
            accs.push(hist.final_val_acc());
        }
        table.row(vec![
            format!("c{classes}"),
            format!("{:.3}", accs[0]),
            format!("{:.3}", accs[1]),
        ]);
    }
    table.print();
    table.save_csv("table9_trained_generator");
    println!("\npaper shape: trained generator helps marginally (≤ ~1.5 pts).");
}
