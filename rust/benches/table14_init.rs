//! Table 14 (appendix): generator weight init law — uniform vs normal ×
//! variance scale c. Weights are runtime inputs, so one executable covers
//! the whole sweep: we synthesize each variant natively and install it
//! into the gw* statics (first layer keeps c=1, like the paper).

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{steps_mlp, Ctx};
use mcnc::mcnc::GenCfg;
use mcnc::tensor::Tensor;
use mcnc::train::{self, LrSchedule, TrainCfg, TrainState};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(42, 10, 28, 28, 1));
    let steps = steps_mlp();
    let entry = ctx.session.entry("mlp_mcnc02_train").unwrap().clone();
    let base = GenCfg::from_json(
        entry.meta.get("gen").expect("mcnc entry carries gen cfg"),
    )
    .unwrap();

    let mut table =
        Table::new("Table 14 — generator weight init", &["init", "c", "val acc"]);
    for init in ["uniform", "normal"] {
        for c in [0.5f32, 1.0, 2.0, 4.0] {
            let mut st = TrainState::new(&ctx.session, "mlp_mcnc02_train", 5).unwrap();
            let cfg = GenCfg { init: init.into(), init_scale: c, ..base.clone() };
            let ws = cfg.make_weights(42);
            let ws1 = GenCfg { init: init.into(), init_scale: 1.0, ..base.clone() }
                .make_weights(42);
            for (i, (a, b)) in cfg.layer_shapes().into_iter().enumerate() {
                // first layer keeps c = 1 (c also changes the input
                // frequency, which Table 6 sweeps separately)
                let w = if i == 0 { &ws1[i] } else { &ws[i] };
                st.set(&format!("gw{i}"), Tensor::from_f32(w.clone(), &[a, b]).unwrap())
                    .unwrap();
            }
            let tc = TrainCfg {
                steps,
                batch: 128,
                schedule: LrSchedule::Cosine { base: 0.05, total: steps, floor_frac: 0.05 },
                ..TrainCfg::default()
            };
            let hist = train::run(&mut st, Arc::clone(&data), &tc).unwrap();
            table.row(vec![init.into(), format!("{c}"), format!("{:.3}", hist.final_val_acc())]);
        }
    }
    table.print();
    table.save_csv("table14_init");
    println!("\npaper shape: uniform ≥ normal; smaller variance better for uniform.");
}
