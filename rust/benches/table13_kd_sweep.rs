//! Table 13 (appendix): k and d scaled together at a fixed compression
//! rate — small k starves the generator (amplitudes eat the budget).

use std::sync::Arc;

use mcnc::data::{Dataset, SynthVision};
use mcnc::exp::{steps_mlp, Ctx};
use mcnc::util::bench::Table;

fn main() {
    let Some(ctx) = Ctx::open() else { return };
    let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(42, 10, 28, 28, 1));
    let steps = steps_mlp();
    let lrs = [0.05f32, 0.01, 0.1];
    let mut table =
        Table::new("Table 13 — (k, d) at fixed rate", &["k", "d", "val acc"]);
    for (k, d) in [(1usize, 1000usize), (3, 2000), (7, 4000), (15, 8000), (31, 16000)] {
        let exec = format!("mlp_mcnc_k{k}_train");
        let (acc, _) = ctx.best_acc(&exec, Arc::clone(&data), steps, &lrs, 5).unwrap();
        table.row(vec![k.to_string(), d.to_string(), format!("{acc:.3}")]);
    }
    table.print();
    table.save_csv("table13_kd_sweep");
    println!("\npaper shape: accuracy rises with k at fixed rate; k=1 is poor.");
}
