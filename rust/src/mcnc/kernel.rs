//! Register-tiled, cache-blocked f32 GEMM — the native reconstruction
//! micro-kernel behind `Generator::forward_into` and the NOLA baseline.
//!
//! Layout follows the classic GotoBLAS decomposition: B (the frozen layer
//! weights, `[K, N]` row-major) is packed once per `Generator` into
//! NR-wide column panels; the driver loops NC → MC → NR-panel → MR-tile and
//! the micro-kernel keeps an `MR × NR` accumulator block in registers.
//!
//! **Reduction-order contract.** Every output element is accumulated over
//! the *full* K dimension in ascending order, exactly like the per-chunk
//! `matvec` reference (`Generator::forward_naive`). That is why there is no
//! KC blocking: splitting K would reorder the f32 sums and break the
//! bit-exactness the property tests pin (fan-in is at most `width`, ≤ ~1k
//! floats per A-row, so the A panel rows fit L1 comfortably anyway). With
//! ascending-K accumulation from a `+0.0` accumulator, skipping exact-zero
//! terms (as the naive path does) cannot change any result bit, so the two
//! paths agree bit-for-bit — see `rust/tests/prop_generator_gemm.rs`.

/// Micro-tile rows (batch/chunk dimension).
pub const MR: usize = 4;
/// Micro-tile columns (output-feature dimension); packing granularity.
pub const NR: usize = 8;
/// Row block: A panel of MC×K f32 stays in L2 while a B panel streams L1.
const MC: usize = 64;
/// Column block, a multiple of NR.
const NC: usize = 512;

/// `B [K, N]` packed into ⌈N/NR⌉ panels of `K × NR` (k-major inside a
/// panel); the last panel is zero-padded to NR columns.
#[derive(Debug, Clone)]
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    panels: Vec<f32>,
}

impl PackedB {
    #[inline]
    fn panel(&self, idx: usize) -> &[f32] {
        &self.panels[idx * self.k * NR..(idx + 1) * self.k * NR]
    }

    pub fn size_bytes(&self) -> usize {
        self.panels.len() * std::mem::size_of::<f32>()
    }
}

/// Pack row-major `b [k, n]` into NR-wide column panels.
pub fn pack_b(b: &[f32], k: usize, n: usize) -> PackedB {
    assert!(b.len() >= k * n, "B smaller than {k}x{n}");
    let np = n.div_ceil(NR.max(1)).max(1);
    let mut panels = vec![0.0f32; np * k * NR];
    for p in 0..np {
        let j0 = p * NR;
        let w = NR.min(n - j0.min(n));
        let dst = &mut panels[p * k * NR..(p + 1) * k * NR];
        for kk in 0..k {
            dst[kk * NR..kk * NR + w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
        }
    }
    PackedB { k, n, panels }
}

/// `C[M, N] = A[M, K] · B` (C overwritten, all row-major). Bit-identical to
/// the ascending-K naive product per the reduction-order contract above.
pub fn gemm(a: &[f32], m: usize, b: &PackedB, c: &mut [f32]) {
    let (k, n) = (b.k, b.n);
    assert!(a.len() >= m * k, "A smaller than {m}x{k}");
    assert!(c.len() >= m * n, "C smaller than {m}x{n}");
    if m == 0 || n == 0 {
        return;
    }
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for ic in (0..m).step_by(MC) {
            let mc = MC.min(m - ic);
            for jr in (0..nc).step_by(NR) {
                let j = jc + jr;
                let nr = NR.min(n - j);
                let panel = b.panel(j / NR);
                for ir in (0..mc).step_by(MR) {
                    let i = ic + ir;
                    let mr = MR.min(m - i);
                    micro(&a[i * k..], k, mr, panel, &mut c[i * n + j..], n, nr);
                }
            }
        }
    }
}

/// One MR×NR tile: `c[r, j] = Σ_p a[r, p] · panel[p, j]`, p ascending.
/// Padded panel columns are computed but never stored.
#[inline]
fn micro(a: &[f32], k: usize, mr: usize, panel: &[f32], c: &mut [f32], ldc: usize, nr: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    if mr == MR {
        for p in 0..k {
            let brow: &[f32; NR] = panel[p * NR..p * NR + NR].try_into().unwrap();
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = a[r * k + p];
                for (x, &bv) in accr.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
    } else {
        for p in 0..k {
            let brow: &[f32; NR] = panel[p * NR..p * NR + NR].try_into().unwrap();
            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                let av = a[r * k + p];
                for (x, &bv) in accr.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(mr) {
        c[r * ldc..r * ldc + nr].copy_from_slice(&accr[..nr]);
    }
}

/// Row-streaming GEMV: `out[N] = x[K] · b[K, N]` (row-major, unpacked).
/// The M = 1 shape NOLA's basis combination needs — packing would double
/// the memory traffic, so B streams directly; per-output accumulation is
/// still ascending-K.
pub fn gemv(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    assert!(b.len() >= k * n, "basis smaller than {k}x{n}");
    assert!(out.len() >= n, "out smaller than {n}");
    out[..n].fill(0.0);
    for (p, &xv) in x[..k].iter().enumerate() {
        let row = &b[p * n..(p + 1) * n];
        for (o, &bv) in out[..n].iter_mut().zip(row) {
            *o += xv * bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    /// Ascending-K reference product (the contract both paths honor).
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gemm_bit_identical_to_naive_across_shapes() {
        // edge coverage: m {<,=,>} MR multiples, n {<,=,>} NR multiples,
        // plus blocks larger than MC/NC.
        for &(m, k, n) in
            &[(1, 1, 1), (3, 9, 8), (4, 16, 7), (5, 13, 17), (54, 9, 256), (70, 33, 523)]
        {
            let a = Stream::new(1).uniform_f32(m * k, -1.0, 1.0);
            let b = Stream::new(2).uniform_f32(k * n, -0.5, 0.5);
            let pb = pack_b(&b, k, n);
            let mut c = vec![f32::NAN; m * n];
            gemm(&a, m, &pb, &mut c);
            let want = naive(&a, &b, m, k, n);
            for (i, (&got, &w)) in c.iter().zip(&want).enumerate() {
                assert!(
                    got.to_bits() == w.to_bits(),
                    "({m},{k},{n})[{i}]: {got} vs {w}"
                );
            }
        }
    }

    #[test]
    fn gemm_with_exact_zero_inputs_matches_skip_reference() {
        // the naive matvec path skips x == 0 terms; ascending-K accumulation
        // from +0.0 must agree bit-for-bit anyway.
        let (m, k, n) = (6, 10, 12);
        let mut a = Stream::new(3).uniform_f32(m * k, -1.0, 1.0);
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let b = Stream::new(4).uniform_f32(k * n, -1.0, 1.0);
        let mut skip = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    skip[i * n + j] += av * b[p * n + j];
                }
            }
        }
        let mut c = vec![0.0f32; m * n];
        gemm(&a, m, &pack_b(&b, k, n), &mut c);
        assert!(c.iter().zip(&skip).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn gemv_matches_naive_row() {
        let (k, n) = (7, 29);
        let x = Stream::new(5).uniform_f32(k, -2.0, 2.0);
        let b = Stream::new(6).uniform_f32(k * n, -1.0, 1.0);
        let mut out = vec![f32::NAN; n];
        gemv(&x, &b, k, n, &mut out);
        let want = naive(&x, &b, 1, k, n);
        assert!(out.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn pack_pads_last_panel_with_zeros() {
        let (k, n) = (3, NR + 2); // one full panel + a 2-wide tail
        let b: Vec<f32> = (0..k * n).map(|i| i as f32 + 1.0).collect();
        let pb = pack_b(&b, k, n);
        assert_eq!(pb.size_bytes(), 2 * k * NR * 4);
        let tail = pb.panel(1);
        for kk in 0..k {
            assert_eq!(tail[kk * NR], b[kk * n + NR]);
            assert_eq!(tail[kk * NR + 1], b[kk * n + NR + 1]);
            assert!(tail[kk * NR + 2..(kk + 1) * NR].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn degenerate_shapes_are_safe() {
        let pb = pack_b(&[], 0, 0);
        gemm(&[], 0, &pb, &mut []);
        let pb = pack_b(&[1.0, 2.0], 2, 1);
        let mut c = [0.0f32];
        gemm(&[3.0, 4.0], 1, &pb, &mut c);
        assert_eq!(c[0], 3.0 * 1.0 + 4.0 * 2.0);
    }
}
