//! The paper's core machinery, native side: the frozen random generator φ
//! (mirror of the Pallas kernel), the blocked-GEMM reconstruction kernel
//! behind it, and the chunk-partition math.

pub mod chunker;
pub mod generator;
pub mod kernel;

pub use chunker::ChunkSpec;
pub use generator::{Act, GenCfg, Generator};
