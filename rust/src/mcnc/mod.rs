//! The paper's core machinery, native side: the frozen random generator φ
//! (mirror of the Pallas kernel), the SIMD-dispatched blocked-GEMM
//! reconstruction kernel behind it (`kernel` — AVX2+FMA / NEON microtiles
//! probed once at startup, scalar reference fallback), and the
//! chunk-partition math.

pub mod chunker;
pub mod generator;
pub mod kernel;

pub use chunker::ChunkSpec;
pub use generator::{Act, GenCfg, Generator};
