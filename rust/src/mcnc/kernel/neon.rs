//! NEON microkernels (aarch64): 8×8 register tile (16 of 32 q-register
//! accumulators + 2 B vectors + 1 broadcast), packed A panels, and
//! vectorized quantizer scans. Same ascending-K reduction order as the
//! scalar reference with fused multiply-adds; parity is bounded by the
//! properties in `rust/tests/prop_generator_gemm.rs`.
//!
//! NEON is architecturally mandatory on aarch64, so `dispatch` enables
//! this path unconditionally there.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::*;

/// Micro-tile rows; A is repacked into MR-row panels (zero-padded).
const MR: usize = 8;
/// Micro-tile columns = two q vectors; packing granularity.
pub(super) const NR: usize = 8;
/// Row block kept hot while a B panel streams.
const MC: usize = 64;
/// Column block.
const NC: usize = 512;

// the driver's `(i / MR)` tile lookup and `(j / NR)` panel lookup are only
// exact because every MC/NC block boundary lands on a tile boundary
const _: () = assert!(MC % MR == 0 && NC % NR == 0);

/// Pack row-major `b [k, n]` into NR=8 column panels (k-major inside a
/// panel, last panel zero-padded). Row copies are `copy_from_slice`
/// (memcpy lowers to q-register moves on aarch64).
pub(super) fn pack(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    super::pack_panels(b, k, n, NR)
}

/// `C[M, N] = A[M, K] · B-panels` over the NR=8 layout from [`pack`];
/// A goes through the shared `super::pack_a` MR-row repack first.
pub(super) fn gemm(a: &[f32], m: usize, k: usize, n: usize, panels: &[f32], c: &mut [f32]) {
    super::APACK.with(|cell| {
        let mut buf = cell.borrow_mut();
        super::pack_a(a, m, k, MR, &mut buf);
        // SAFETY: NEON is architecturally mandatory on aarch64, where
        // this module is compiled; sizes are checked by the safe callers.
        unsafe { gemm_inner(&buf, m, k, n, panels, c) };
    });
}

// SAFETY: callers pass `ap` as ⌈m/MR⌉ zero-padded MR-row tiles and
// `panels` as ⌈n/NR⌉ NR-wide panels, so the tile/panel pointers below
// always address a full k·MR / k·NR block; `micro` masks its stores to
// the mr×nr live region of `c`. NEON itself is baseline on aarch64.
#[target_feature(enable = "neon")]
unsafe fn gemm_inner(ap: &[f32], m: usize, k: usize, n: usize, panels: &[f32], c: &mut [f32]) {
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for ic in (0..m).step_by(MC) {
            let mc = MC.min(m - ic);
            for jr in (0..nc).step_by(NR) {
                let j = jc + jr;
                let nr = NR.min(n - j);
                let panel = panels.as_ptr().add((j / NR) * k * NR);
                for ir in (0..mc).step_by(MR) {
                    let i = ic + ir;
                    let mr = MR.min(m - i);
                    let tile = ap.as_ptr().add((i / MR) * k * MR);
                    micro(tile, panel, k, c.as_mut_ptr().add(i * n + j), n, mr, nr);
                }
            }
        }
    }
}

/// One 8×8 tile: `c[r, j] = Σ_p ap[p, r] · panel[p, j]`, p ascending,
/// each term fused. Padded rows/columns are computed but never stored.
// SAFETY: callers pass `ap`/`bp` pointing at full k·MR / k·NR blocks;
// stores are masked to the mr×nr live region of `c`.
#[target_feature(enable = "neon")]
unsafe fn micro(
    ap: *const f32,
    bp: *const f32,
    k: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let z = vdupq_n_f32(0.0);
    let mut acc = [[z; 2]; MR];
    for p in 0..k {
        let b0 = vld1q_f32(bp.add(p * NR));
        let b1 = vld1q_f32(bp.add(p * NR + 4));
        let arow = ap.add(p * MR);
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = vdupq_n_f32(*arow.add(r));
            accr[0] = vfmaq_f32(accr[0], av, b0);
            accr[1] = vfmaq_f32(accr[1], av, b1);
        }
    }
    if mr == MR && nr == NR {
        for (r, accr) in acc.iter().enumerate() {
            vst1q_f32(c.add(r * ldc), accr[0]);
            vst1q_f32(c.add(r * ldc + 4), accr[1]);
        }
    } else {
        let mut buf = [0.0f32; NR];
        for (r, accr) in acc.iter().enumerate().take(mr) {
            vst1q_f32(buf.as_mut_ptr(), accr[0]);
            vst1q_f32(buf.as_mut_ptr().add(4), accr[1]);
            std::ptr::copy_nonoverlapping(buf.as_ptr(), c.add(r * ldc), nr);
        }
    }
}

/// Fused row-streaming GEMV: `out[N] = x[K] · b[K, N]`, 16 columns of
/// register accumulators at a time, ascending-K per output.
pub(super) fn gemv(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    // SAFETY: NEON is baseline on aarch64; slice lengths (x=k, b=k·n,
    // out=n) are the dispatched API contract.
    unsafe { gemv_inner(x, b, k, n, out) };
}

// SAFETY: callers pass x of len k, b of len k·n, out of len n; every
// unchecked access below is bounded by those. NEON is baseline on aarch64.
#[target_feature(enable = "neon")]
unsafe fn gemv_inner(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    let mut j = 0usize;
    while j + 16 <= n {
        let z = vdupq_n_f32(0.0);
        let mut acc = [z; 4];
        for p in 0..k {
            let xv = vdupq_n_f32(*x.get_unchecked(p));
            let base = b.as_ptr().add(p * n + j);
            for (q, accq) in acc.iter_mut().enumerate() {
                *accq = vfmaq_f32(*accq, xv, vld1q_f32(base.add(q * 4)));
            }
        }
        for (q, accq) in acc.iter().enumerate() {
            vst1q_f32(out.as_mut_ptr().add(j + q * 4), *accq);
        }
        j += 16;
    }
    while j + 4 <= n {
        let mut acc = vdupq_n_f32(0.0);
        for p in 0..k {
            let xv = vdupq_n_f32(*x.get_unchecked(p));
            acc = vfmaq_f32(acc, xv, vld1q_f32(b.as_ptr().add(p * n + j)));
        }
        vst1q_f32(out.as_mut_ptr().add(j), acc);
        j += 4;
    }
    for jj in j..n {
        let mut acc = 0.0f32;
        for p in 0..k {
            acc = x[p].mul_add(b[p * n + jj], acc);
        }
        out[jj] = acc;
    }
}

/// Vectorized NaN-ignoring absmax scan — `FMAXNM` implements IEEE maxNum
/// (returns the non-NaN operand), matching the scalar `f32::max` fold
/// bit-for-bit.
pub(super) fn absmax(xs: &[f32]) -> f32 {
    // SAFETY: NEON is baseline on aarch64.
    unsafe { absmax_inner(xs) }
}

// SAFETY: vector loads stop at i + 4 ≤ len and the tail is read through
// the slice. NEON is baseline on aarch64.
#[target_feature(enable = "neon")]
unsafe fn absmax_inner(xs: &[f32]) -> f32 {
    let mut acc = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 4 <= xs.len() {
        let v = vld1q_f32(xs.as_ptr().add(i));
        acc = vmaxnmq_f32(acc, vabsq_f32(v));
        i += 4;
    }
    let mut lanes = [0.0f32; 4];
    vst1q_f32(lanes.as_mut_ptr(), acc);
    let mut m = lanes.iter().fold(0.0f32, |m, &v| m.max(v));
    for v in &xs[i..] {
        m = m.max(v.abs());
    }
    m
}

/// Vectorized quantizer encode scan, bit-identical to the scalar formula:
/// `FCVTAS` (`vcvtaq_s32_f32`) natively rounds to nearest with ties away
/// from zero — exactly `f32::round` — converts NaN to 0 (matching
/// `NaN as i32`) and saturates ±inf, which the integer clamp then maps to
/// the same bounds the scalar float clamp produces.
pub(super) fn quantize_block(chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    // SAFETY: NEON is baseline on aarch64.
    unsafe { quantize_inner(chunk, scale, bits, out) };
}

// SAFETY: vector loads stop at i + 4 ≤ len and the scalar tail handles
// the rest. NEON is baseline on aarch64.
#[target_feature(enable = "neon")]
unsafe fn quantize_inner(chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    let qmax = (1i32 << (bits - 1)) - 1;
    let bias = 1i32 << (bits - 1);
    let sv = vdupq_n_f32(scale);
    let lov = vdupq_n_s32(-qmax - 1);
    let hiv = vdupq_n_s32(qmax);
    let biasv = vdupq_n_s32(bias);
    let mut qs = [0i32; 4];
    let mut i = 0usize;
    while i + 4 <= chunk.len() {
        let x = vdivq_f32(vld1q_f32(chunk.as_ptr().add(i)), sv);
        let q = vminq_s32(vmaxq_s32(vcvtaq_s32_f32(x), lov), hiv);
        vst1q_s32(qs.as_mut_ptr(), vaddq_s32(q, biasv));
        for &qv in &qs {
            out.push(qv as u8);
        }
        i += 4;
    }
    super::scalar::quantize_block(&chunk[i..], scale, bits, out);
}
