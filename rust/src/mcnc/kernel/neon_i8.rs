//! NEON int8 GEMM microkernel (aarch64): widening-multiply accumulation
//! over quantized panels, bit-identical to the scalar int8 reference.
//!
//! The `sdot` byte-dot instruction needs the optional `dotprod` extension,
//! so this kernel uses the baseline widening pipeline instead:
//! `vmull_s8` multiplies signed bytes into exact i16 products (|qa·qb| ≤
//! 127·128 = 16256, well inside i16), and `vpadalq_s16` folds adjacent
//! pairs into i32 accumulators — the pairwise add happens *after*
//! widening, so nothing ever saturates and the per-group sums are exact.
//!
//! One 16-byte q-register load covers 8 columns × 2 consecutive k's
//! ([`KU`] = 2); the matching A pair broadcasts as a single i16. Two i32
//! accumulators (columns 0–3 / 4–7) per row make the micro-tile. The f32
//! rescale at each scale-group edge replays the scalar oracle's exact
//! instruction sequence — `scvtf` convert, multiply, add; never a fused
//! `vfmaq` — so the kernel is bit-identical to `scalar::gemm_q`, pinned by
//! `rust/tests/prop_int8_gemm.rs`.
//!
//! NEON is architecturally mandatory on aarch64, so `dispatch` enables
//! this path unconditionally there.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::*;

use super::{PackedBQ, QuantA};

/// k-rows per interleave step: one q-register load covers 8 columns × 2
/// consecutive k's (`[b(kk..kk+2, j) for j in 0..8]`).
pub(super) const KU: usize = 2;

/// Micro-tile rows: 8 i32 + 8 f32 q-register accumulators plus the B
/// halves and per-row temporaries fit easily in 32 registers.
const MR: usize = 4;

/// `C[M, N] = A · B-panels` over the KU = 2 interleaved layout. Caller
/// (the `gemm_q` dispatcher) guarantees the group length is a KU multiple
/// or there is a single group, so every group span covers whole pairs.
pub(super) fn gemm_q(qa: &QuantA, b: &PackedBQ, c: &mut [f32]) {
    // SAFETY: NEON is architecturally mandatory on aarch64, where this
    // module is compiled; struct consistency is the constructors' contract.
    unsafe { gemm_q_inner(qa, b, c) };
}

// SAFETY: callers pass structurally consistent `qa`/`b` (the public
// constructors are the only way to build them): panels hold ⌈n/8⌉ panels
// of kpad×8 bytes with kpad a KU multiple, so every 16-byte load at pair
// `kk/2` stays inside its panel; A rows are m × qa.kpad with qa.kpad
// (k rounded up to 4) ≥ b.kpad (k rounded up to 2), so every 2-byte pair
// read at `kk` stays inside the row. Stores are masked to the live mr×w
// region of `c` (len ≥ m·n, checked by the dispatcher). NEON itself is
// baseline on aarch64.
#[target_feature(enable = "neon")]
unsafe fn gemm_q_inner(qa: &QuantA, b: &PackedBQ, c: &mut [f32]) {
    let (m, n) = (qa.m, b.n);
    let (nr, kpad, kg, ng) = (b.nr, b.kpad, b.kg, b.n_groups);
    debug_assert!(nr == super::NR_Q && b.ku == KU && kpad <= qa.kpad);
    let np = n.div_ceil(nr);
    for p in 0..np {
        let j0 = p * nr;
        let w = nr.min(n - j0);
        let panel = b.panels.as_ptr().add(p * kpad * nr);
        let mut i = 0usize;
        while i < m {
            let mr = MR.min(m - i);
            let zf = vdupq_n_f32(0.0);
            let mut accf = [[zf; 2]; MR];
            let mut k0 = 0usize;
            for g in 0..ng {
                // the dispatcher's alignment rule makes every boundary a
                // KU multiple; the last group runs through the zero pads
                // (0 symbols on both sides — they add 0 to the exact sum)
                let k1 = if g + 1 == ng { kpad } else { k0 + kg };
                let zi = vdupq_n_s32(0);
                let mut acci = [[zi; 2]; MR];
                let mut kk = k0;
                while kk < k1 {
                    let bv = vld1q_s8(panel.add((kk / KU) * (nr * KU)));
                    let blo = vget_low_s8(bv);
                    let bhi = vget_high_s8(bv);
                    for (r, acc) in acci.iter_mut().enumerate().take(mr) {
                        let ap = qa.syms.as_ptr().add((i + r) * qa.kpad + kk) as *const i16;
                        let av = vreinterpret_s8_s16(vdup_n_s16(ap.read_unaligned()));
                        acc[0] = vpadalq_s16(acc[0], vmull_s8(blo, av));
                        acc[1] = vpadalq_s16(acc[1], vmull_s8(bhi, av));
                    }
                    kk += KU;
                }
                for (r, acc) in accf.iter_mut().enumerate().take(mr) {
                    let t = qa.scales[(i + r) * qa.n_groups + g] * b.scales[g];
                    acc[0] = vaddq_f32(acc[0], vmulq_n_f32(vcvtq_f32_s32(acci[r][0]), t));
                    acc[1] = vaddq_f32(acc[1], vmulq_n_f32(vcvtq_f32_s32(acci[r][1]), t));
                }
                k0 = k1;
            }
            let mut buf = [0.0f32; 8];
            for (r, acc) in accf.iter().enumerate().take(mr) {
                vst1q_f32(buf.as_mut_ptr(), acc[0]);
                vst1q_f32(buf.as_mut_ptr().add(4), acc[1]);
                let dst = c.as_mut_ptr().add((i + r) * n + j0);
                std::ptr::copy_nonoverlapping(buf.as_ptr(), dst, w);
            }
            i += mr;
        }
    }
}
