//! AVX2 + FMA microkernels (x86-64): 6×16 register tile (12 ymm
//! accumulators + 2 B vectors + 1 broadcast = 15 of 16 registers), packed
//! A panels so edge tiles never need a masked kernel, and vectorized
//! quantizer scans. Reduction order per output element is the same
//! ascending-K walk as the scalar reference; the only numeric difference
//! is the fused multiply-add (one rounding per term instead of two), which
//! the parity properties in `rust/tests/prop_generator_gemm.rs` bound.
//!
//! Everything here is only reachable through `dispatch` after
//! `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")`
//! passed, so the `#[target_feature]` functions are sound to call.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;

/// Micro-tile rows; A is repacked into MR-row panels (zero-padded).
const MR: usize = 6;
/// Micro-tile columns = two ymm vectors; packing granularity.
pub(super) const NR: usize = 16;
/// Row block kept hot while a B panel streams.
const MC: usize = 96;
/// Column block.
const NC: usize = 512;

// the driver's `(i / MR)` tile lookup and `(j / NR)` panel lookup are only
// exact because every MC/NC block boundary lands on a tile boundary
const _: () = assert!(MC % MR == 0 && NC % NR == 0);

/// Pack row-major `b [k, n]` into NR=16 column panels (k-major inside a
/// panel, last panel zero-padded) — same layout contract as the scalar
/// packer, two ymm copies per full row.
pub(super) fn pack(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    let np = n.div_ceil(NR).max(1);
    let mut panels = vec![0.0f32; np * k * NR];
    // SAFETY: only reachable via dispatch after the avx2 probe passed.
    unsafe { pack_inner(b, k, n, &mut panels) };
    panels
}

// SAFETY: callers must have verified avx2. Every load stays inside `b`
// (j0 + 16 ≤ n for each full panel) and every store inside `panels`
// (sized np·k·NR by the safe wrapper).
#[target_feature(enable = "avx2")]
unsafe fn pack_inner(b: &[f32], k: usize, n: usize, panels: &mut [f32]) {
    let full = n / NR;
    for p in 0..full {
        let j0 = p * NR;
        let dst = panels.as_mut_ptr().add(p * k * NR);
        for kk in 0..k {
            let src = b.as_ptr().add(kk * n + j0);
            _mm256_storeu_ps(dst.add(kk * NR), _mm256_loadu_ps(src));
            _mm256_storeu_ps(dst.add(kk * NR + 8), _mm256_loadu_ps(src.add(8)));
        }
    }
    let w = n - full * NR;
    if w > 0 {
        let j0 = full * NR;
        let dst = &mut panels[full * k * NR..(full + 1) * k * NR];
        for kk in 0..k {
            dst[kk * NR..kk * NR + w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
        }
    }
}

/// `C[M, N] = A[M, K] · B-panels` over the NR=16 layout from [`pack`];
/// A goes through the shared `super::pack_a` MR-row repack first.
pub(super) fn gemm(a: &[f32], m: usize, k: usize, n: usize, panels: &[f32], c: &mut [f32]) {
    super::APACK.with(|cell| {
        let mut buf = cell.borrow_mut();
        super::pack_a(a, m, k, MR, &mut buf);
        // SAFETY: only reachable via dispatch after the avx2+fma probe.
        unsafe { gemm_inner(&buf, m, k, n, panels, c) };
    });
}

// SAFETY: callers must have verified avx2+fma and pass `ap` as ⌈m/MR⌉
// zero-padded MR-row tiles and `panels` as ⌈n/NR⌉ NR-wide panels, so the
// tile/panel pointers below always address a full k·MR / k·NR block;
// `micro` masks its stores to the mr×nr live region of `c`.
#[target_feature(enable = "avx2,fma")]
unsafe fn gemm_inner(ap: &[f32], m: usize, k: usize, n: usize, panels: &[f32], c: &mut [f32]) {
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for ic in (0..m).step_by(MC) {
            let mc = MC.min(m - ic);
            for jr in (0..nc).step_by(NR) {
                let j = jc + jr;
                let nr = NR.min(n - j);
                let panel = panels.as_ptr().add((j / NR) * k * NR);
                for ir in (0..mc).step_by(MR) {
                    let i = ic + ir;
                    let mr = MR.min(m - i);
                    let tile = ap.as_ptr().add((i / MR) * k * MR);
                    micro(tile, panel, k, c.as_mut_ptr().add(i * n + j), n, mr, nr);
                }
            }
        }
    }
}

/// One 6×16 tile: `c[r, j] = Σ_p ap[p, r] · panel[p, j]`, p ascending,
/// each term fused. Padded rows/columns are computed but never stored.
// SAFETY: callers must have verified avx2+fma and pass `ap`/`bp` pointing
// at full k·MR / k·NR blocks; stores are masked to the mr×nr live region.
#[target_feature(enable = "avx2,fma")]
unsafe fn micro(
    ap: *const f32,
    bp: *const f32,
    k: usize,
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
) {
    let z = _mm256_setzero_ps();
    let mut acc = [[z; 2]; MR];
    for p in 0..k {
        let b0 = _mm256_loadu_ps(bp.add(p * NR));
        let b1 = _mm256_loadu_ps(bp.add(p * NR + 8));
        let arow = ap.add(p * MR);
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*arow.add(r));
            accr[0] = _mm256_fmadd_ps(av, b0, accr[0]);
            accr[1] = _mm256_fmadd_ps(av, b1, accr[1]);
        }
    }
    if mr == MR && nr == NR {
        for (r, accr) in acc.iter().enumerate() {
            _mm256_storeu_ps(c.add(r * ldc), accr[0]);
            _mm256_storeu_ps(c.add(r * ldc + 8), accr[1]);
        }
    } else {
        let mut buf = [0.0f32; NR];
        for (r, accr) in acc.iter().enumerate().take(mr) {
            _mm256_storeu_ps(buf.as_mut_ptr(), accr[0]);
            _mm256_storeu_ps(buf.as_mut_ptr().add(8), accr[1]);
            std::ptr::copy_nonoverlapping(buf.as_ptr(), c.add(r * ldc), nr);
        }
    }
}

/// Fused row-streaming GEMV: `out[N] = x[K] · b[K, N]`, 32 columns of
/// register accumulators at a time, ascending-K per output.
pub(super) fn gemv(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    // SAFETY: only reachable via dispatch after the avx2+fma probe.
    unsafe { gemv_inner(x, b, k, n, out) };
}

// SAFETY: callers must have verified avx2+fma and pass x of len k, b of
// len k·n, out of len n; every unchecked access below is bounded by those.
#[target_feature(enable = "avx2,fma")]
unsafe fn gemv_inner(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    let mut j = 0usize;
    while j + 32 <= n {
        let z = _mm256_setzero_ps();
        let mut acc = [z; 4];
        for p in 0..k {
            let xv = _mm256_set1_ps(*x.get_unchecked(p));
            let base = b.as_ptr().add(p * n + j);
            for (q, accq) in acc.iter_mut().enumerate() {
                *accq = _mm256_fmadd_ps(xv, _mm256_loadu_ps(base.add(q * 8)), *accq);
            }
        }
        for (q, accq) in acc.iter().enumerate() {
            _mm256_storeu_ps(out.as_mut_ptr().add(j + q * 8), *accq);
        }
        j += 32;
    }
    while j + 8 <= n {
        let mut acc = _mm256_setzero_ps();
        for p in 0..k {
            let xv = _mm256_set1_ps(*x.get_unchecked(p));
            acc = _mm256_fmadd_ps(xv, _mm256_loadu_ps(b.as_ptr().add(p * n + j)), acc);
        }
        _mm256_storeu_ps(out.as_mut_ptr().add(j), acc);
        j += 8;
    }
    for jj in j..n {
        let mut acc = 0.0f32;
        for p in 0..k {
            acc = x[p].mul_add(b[p * n + jj], acc);
        }
        out[jj] = acc;
    }
}

/// Vectorized NaN-ignoring absmax scan — bit-identical to the scalar fold
/// (max never rounds; `max_ps(|v|, acc)` returns `acc` when `|v|` is NaN,
/// same as `f32::max`).
pub(super) fn absmax(xs: &[f32]) -> f32 {
    // SAFETY: only reachable via dispatch after the avx2 probe.
    unsafe { absmax_inner(xs) }
}

// SAFETY: callers must have verified avx2; vector loads stop at
// i + 8 ≤ len and the tail is read through the slice.
#[target_feature(enable = "avx2")]
unsafe fn absmax_inner(xs: &[f32]) -> f32 {
    let sign = _mm256_set1_ps(-0.0);
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= xs.len() {
        let v = _mm256_loadu_ps(xs.as_ptr().add(i));
        acc = _mm256_max_ps(_mm256_andnot_ps(sign, v), acc);
        i += 8;
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut m = lanes.iter().fold(0.0f32, |m, &v| m.max(v));
    for v in &xs[i..] {
        m = m.max(v.abs());
    }
    m
}

/// Vectorized quantizer encode scan, bit-identical to the scalar formula
/// `(v/scale).round().clamp(-qmax-1, qmax) as i32 + bias`:
/// * division is IEEE correctly-rounded in both paths;
/// * `round` (ties away from zero) is rebuilt from the RTE `roundps` plus
///   an exact tie fixup — RTE disagrees with ties-away only when
///   `x - rte(x)` equals ±0.5 exactly, and that subtraction is exact for
///   every float (the difference is a multiple of ulp(x) no larger than
///   0.5, or zero once ulp(x) > 0.5);
/// * NaN lanes are zeroed before the clamp to match `NaN as i32 == 0`.
pub(super) fn quantize_block(chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    // SAFETY: only reachable via dispatch after the avx2 probe.
    unsafe { quantize_inner(chunk, scale, bits, out) };
}

// SAFETY: callers must have verified avx2; vector loads stop at
// i + 8 ≤ len and the scalar tail handles the rest.
#[target_feature(enable = "avx2")]
unsafe fn quantize_inner(chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let bias = 1i32 << (bits - 1);
    let sv = _mm256_set1_ps(scale);
    let sign = _mm256_set1_ps(-0.0);
    let halfv = _mm256_set1_ps(0.5);
    let onev = _mm256_set1_ps(1.0);
    let lov = _mm256_set1_ps(-qmax - 1.0);
    let hiv = _mm256_set1_ps(qmax);
    let biasv = _mm256_set1_epi32(bias);
    let mut qs = [0i32; 8];
    let mut i = 0usize;
    while i + 8 <= chunk.len() {
        let x = _mm256_div_ps(_mm256_loadu_ps(chunk.as_ptr().add(i)), sv);
        let sx = _mm256_and_ps(x, sign);
        let r0 = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(x);
        let tie = _mm256_cmp_ps::<_CMP_EQ_OQ>(_mm256_sub_ps(x, r0), _mm256_or_ps(halfv, sx));
        let r = _mm256_add_ps(r0, _mm256_and_ps(tie, _mm256_or_ps(onev, sx)));
        let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(x, x);
        let r = _mm256_blendv_ps(r, _mm256_setzero_ps(), nan);
        let r = _mm256_min_ps(_mm256_max_ps(r, lov), hiv);
        let q = _mm256_add_epi32(_mm256_cvtps_epi32(r), biasv);
        _mm256_storeu_si256(qs.as_mut_ptr() as *mut __m256i, q);
        for &qv in &qs {
            out.push(qv as u8);
        }
        i += 8;
    }
    super::scalar::quantize_block(&chunk[i..], scale, bits, out);
}
