//! AVX2 int8 GEMM microkernel (x86-64): `maddubs`-style u8×i8 → i16 → i32
//! accumulation over quantized panels, bit-identical to the scalar int8
//! reference.
//!
//! AVX2 has no signed-×-signed byte multiply; `vpmaddubsw` multiplies an
//! *unsigned* byte vector by a signed one and saturates the adjacent-pair
//! i16 sums. Both problems dissolve with one identity:
//!
//! ```text
//! a·b = |b| · (sign(b)·a)      (vpabsb on b, vpsignb a by b)
//! ```
//!
//! * `vpabsb(-128)` wraps to `0x80`, which `maddubs` reads as *unsigned*
//!   128 — exactly `|-128|`, so the wire's most negative symbol (produced
//!   only by −inf source values) is handled exactly;
//! * `vpsignb` applies b's sign to the A symbol, which [`super::quantize_a`]
//!   confines to `[-127, 127]` — negation can never wrap, and b = 0 zeroes
//!   the lane (product 0, correct);
//! * every |product| ≤ 128·127 = 16256, so an adjacent pair ≤ 32512 <
//!   32767 — the i16 saturation in `maddubs` is unreachable and the pair
//!   sums are exact.
//!
//! `vpmaddwd` against ones then folds the i16 pairs into exact i32 quad
//! sums, one lane per panel column. Integer sums per scale group are
//! order-free, and the f32 rescale at the group edge replays the scalar
//! oracle's exact instruction sequence (convert, multiply, add — no FMA),
//! so the whole kernel is bit-identical to `scalar::gemm_q` — pinned by
//! `rust/tests/prop_int8_gemm.rs`.
//!
//! Only reachable through `dispatch` after the avx2 probe passed, so the
//! `#[target_feature]` functions are sound to call.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;

use super::{PackedBQ, QuantA};

/// k-rows per interleave step: one 32-byte ymm load covers 8 columns × 4
/// consecutive k's (`[b(kk..kk+4, j) for j in 0..8]`), and the matching A
/// quad broadcasts as a single i32.
pub(super) const KU: usize = 4;

/// Micro-tile rows: 4 i32 + 4 f32 ymm accumulators, plus |b|, b and the
/// per-row sign/product temporaries, stay inside 16 registers.
const MR: usize = 4;

/// `C[M, N] = A · B-panels` over the KU = 4 interleaved layout. Caller
/// (the `gemm_q` dispatcher) guarantees the group length is a KU multiple
/// or there is a single group, so every group span covers whole quads.
pub(super) fn gemm_q(qa: &QuantA, b: &PackedBQ, c: &mut [f32]) {
    // SAFETY: only reachable via dispatch after the avx2 probe passed.
    unsafe { gemm_q_inner(qa, b, c) };
}

// SAFETY: callers must have verified avx2 and pass structurally consistent
// `qa`/`b` (the public constructors are the only way to build them):
// panels hold ⌈n/8⌉ panels of kpad×8 bytes with kpad a KU multiple, so
// every 32-byte load at quad `kk/4` stays inside its panel; A rows are
// m × qa.kpad with qa.kpad ≥ b.kpad (both round k up, A to 4 — equal to
// AVX2's KU), so every 4-byte quad read at `kk` stays inside the row.
// Stores are masked to the live mr×w region of `c` (len ≥ m·n, checked by
// the dispatcher).
#[target_feature(enable = "avx2")]
unsafe fn gemm_q_inner(qa: &QuantA, b: &PackedBQ, c: &mut [f32]) {
    let (m, n) = (qa.m, b.n);
    let (nr, kpad, kg, ng) = (b.nr, b.kpad, b.kg, b.n_groups);
    debug_assert!(nr == super::NR_Q && b.ku == KU && kpad <= qa.kpad);
    let ones = _mm256_set1_epi16(1);
    let np = n.div_ceil(nr);
    for p in 0..np {
        let j0 = p * nr;
        let w = nr.min(n - j0);
        let panel = b.panels.as_ptr().add(p * kpad * nr);
        let mut i = 0usize;
        while i < m {
            let mr = MR.min(m - i);
            let mut accf = [_mm256_setzero_ps(); MR];
            let mut k0 = 0usize;
            for g in 0..ng {
                // the dispatcher's alignment rule makes every boundary a
                // KU multiple; the last group runs through the zero pads
                // (0 symbols on both sides — they add 0 to the exact sum)
                let k1 = if g + 1 == ng { kpad } else { k0 + kg };
                let mut acci = [_mm256_setzero_si256(); MR];
                let mut kk = k0;
                while kk < k1 {
                    let bv = _mm256_loadu_si256(panel.add((kk / KU) * (nr * KU)) as *const _);
                    let babs = _mm256_abs_epi8(bv);
                    for (r, acc) in acci.iter_mut().enumerate().take(mr) {
                        let aq = qa.syms.as_ptr().add((i + r) * qa.kpad + kk) as *const i32;
                        let av = _mm256_set1_epi32(aq.read_unaligned());
                        let prod = _mm256_maddubs_epi16(babs, _mm256_sign_epi8(av, bv));
                        *acc = _mm256_add_epi32(*acc, _mm256_madd_epi16(prod, ones));
                    }
                    kk += KU;
                }
                for (r, acc) in accf.iter_mut().enumerate().take(mr) {
                    let t = qa.scales[(i + r) * qa.n_groups + g] * b.scales[g];
                    let sumf = _mm256_cvtepi32_ps(acci[r]);
                    *acc = _mm256_add_ps(*acc, _mm256_mul_ps(sumf, _mm256_set1_ps(t)));
                }
                k0 = k1;
            }
            let mut buf = [0.0f32; 8];
            for (r, acc) in accf.iter().enumerate().take(mr) {
                _mm256_storeu_ps(buf.as_mut_ptr(), *acc);
                let dst = c.as_mut_ptr().add((i + r) * n + j0);
                std::ptr::copy_nonoverlapping(buf.as_ptr(), dst, w);
            }
            i += mr;
        }
    }
}
