//! Register-tiled, cache-blocked f32 GEMM with runtime ISA dispatch — the
//! native reconstruction microkernel layer behind `Generator::forward_into`,
//! the NOLA baseline, the coordinator's Merged-mode cold fills, and the
//! MCNC2 quantizer scans.
//!
//! Layout follows the classic GotoBLAS decomposition: B (the frozen layer
//! weights, `[K, N]` row-major) is packed once per `Generator` into
//! NR-wide column panels; the driver loops NC → MC → NR-panel → MR-tile and
//! the microkernel keeps an `MR × NR` accumulator block in registers.
//!
//! The microkernel itself is selected once per process by [`dispatch`]:
//!
//! * `scalar` — the portable MR=4 × NR=8 reference, byte-for-byte the
//!   PR-1 kernel and the bit-exactness oracle for the naive matvec path;
//! * `x86` — AVX2+FMA, MR=6 × NR=16 (two ymm columns per row);
//! * `neon` — aarch64 NEON, MR=8 × NR=8 (two q columns per row).
//!
//! Because the panel width NR differs per ISA, a [`PackedB`] remembers the
//! layout it was packed with and [`gemm`] always runs the matching kernel —
//! packing and compute can never disagree. `MCNC_SIMD=scalar|avx2|neon`
//! pins the process-wide choice (unavailable ISAs degrade to scalar); the
//! `*_for` entry points pin it per call, which is how tests compare both
//! paths inside one process. GEMM/GEMV dispatches are counted per ISA in
//! the obs registry (`mcnc_kernel_gemm_total{isa}` — see
//! docs/OBSERVABILITY.md).
//!
//! **Reduction-order contract.** Every output element is accumulated over
//! the *full* K dimension in ascending order, exactly like the per-chunk
//! `matvec` reference (`Generator::forward_naive`); there is no KC split.
//! The scalar path is bit-identical to that reference. The SIMD paths keep
//! the same order but fuse each multiply-add (one rounding per term), so
//! they agree with scalar to a K-scaled ulp bound — pinned by the parity
//! properties in `rust/tests/prop_generator_gemm.rs`.
//!
//! **Compressed-domain path.** int8/int4 artifacts can skip f32
//! materialization entirely: [`PackedBQ`] keeps the rANS-decoded symbols as
//! centered-i8 panels (per-ISA `ku` k-interleave, 8 columns per panel),
//! [`quantize_a`] maps activations per (row, k-group) to symmetric
//! `[-127, 127]` symbols, and [`gemm_q`] multiplies in integers: an exact
//! i32 dot product per scale group, rescaled to f32 once at the group edge
//! as `acc += (Σ qa·qb) as f32 * (sa·sb)` — convert, multiply, add, never
//! fused. The integer part is order-free and the float edge sequence is
//! fixed, so *every* ISA is bit-identical on this path: the scalar int8
//! kernel is the cross-ISA oracle, with AVX2 (`maddubs` over
//! `|b|`/`sign(b)·a`) and NEON (`vmull_s8` + `vpadalq_s16`) kernels pinned
//! to it by `rust/tests/prop_int8_gemm.rs`, which also pins the analytic
//! error bound of the whole path against the f32 oracle.

pub mod dispatch;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "aarch64")]
mod neon_i8;
mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;
#[cfg(target_arch = "x86_64")]
mod x86_i8;

pub use dispatch::{active, available, Isa};

/// Per-ISA dispatch counters — `mcnc_kernel_gemm_total{isa}`,
/// `mcnc_kernel_gemv_total{isa}` and `mcnc_kernel_gemm_q_total{isa}` —
/// bound lazily in the obs registry the first time a kernel dispatches.
/// After binding, each dispatch costs one relaxed atomic add; the counters
/// live here (not in `dispatch`) so the increment sits next to the `match`
/// that actually picks the kernel.
fn dispatch_counters() -> &'static [[std::sync::Arc<crate::obs::Counter>; 3]; 3] {
    static COUNTERS: std::sync::OnceLock<[[std::sync::Arc<crate::obs::Counter>; 3]; 3]> =
        std::sync::OnceLock::new();
    COUNTERS.get_or_init(|| {
        let r = crate::obs::registry();
        let bind = |name: &'static str| {
            [Isa::Scalar, Isa::Avx2, Isa::Neon]
                .map(|isa| r.counter(name, &[("isa", isa.name())]))
        };
        [
            bind("mcnc_kernel_gemm_total"),
            bind("mcnc_kernel_gemv_total"),
            bind("mcnc_kernel_gemm_q_total"),
        ]
    })
}

const OP_GEMM: usize = 0;
const OP_GEMV: usize = 1;
const OP_GEMM_Q: usize = 2;

fn count_dispatch(op: usize, isa: Isa) {
    let ix = match isa {
        Isa::Scalar => 0,
        Isa::Avx2 => 1,
        Isa::Neon => 2,
    };
    dispatch_counters()[op][ix].inc();
}

/// `B [K, N]` packed into ⌈N/NR⌉ panels of `K × NR` (k-major inside a
/// panel); the last panel is zero-padded to NR columns. NR is the packing
/// ISA's microtile width, so the struct pins which kernel consumes it.
#[derive(Debug, Clone)]
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    nr: usize,
    isa: Isa,
    panels: Vec<f32>,
}

impl PackedB {
    /// The ISA whose panel layout (and therefore kernel) this B uses.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Panel width (microtile NR) of the packing ISA.
    pub fn nr(&self) -> usize {
        self.nr
    }

    #[cfg(test)]
    fn panel(&self, idx: usize) -> &[f32] {
        &self.panels[idx * self.k * self.nr..(idx + 1) * self.k * self.nr]
    }

    /// The raw panel storage (⌈n/NR⌉ panels of `k × NR`, k-major inside a
    /// panel). Exposed so consumers that built a `PackedB` two ways (e.g.
    /// the codec's fused decode→pack path vs [`pack_b_for`]) can assert the
    /// layouts agree.
    pub fn panels(&self) -> &[f32] {
        &self.panels
    }

    pub fn size_bytes(&self) -> usize {
        self.panels.len() * std::mem::size_of::<f32>()
    }
}

/// Incremental [`PackedB`] construction for producers that generate B's
/// values as a row-major *stream* rather than a materialized buffer — the
/// substrate of the codec's fused decode→pack path, where dequantized
/// weights go straight into panel layout and the intermediate row-major
/// `Vec<f32>` is never allocated.
///
/// [`PackedBBuilder::push`] must be called exactly `k * n` times in
/// row-major order; [`PackedBBuilder::finish`] checks the count. The result
/// is identical to [`pack_b_for`] on the equivalent row-major buffer: every
/// ISA's pack layout matches the generic panel packer bit-for-bit (pinned
/// by the `dispatched_pack_layout_matches_generic_packer` test), so the
/// builder writes the one true layout directly.
pub struct PackedBBuilder {
    k: usize,
    n: usize,
    nr: usize,
    isa: Isa,
    panels: Vec<f32>,
    filled: usize,
    // running write cursor — push is the fused decode→pack hot path, so
    // the panel slot `(j/nr)·k·nr + kk·nr + j%nr` is tracked incrementally
    // (adds + compares) instead of recomputed with div/mod per element
    col: usize,
    lane: usize,
    at: usize,
}

impl PackedBBuilder {
    /// Builder targeting the process-wide ISA's panel layout.
    pub fn new(k: usize, n: usize) -> PackedBBuilder {
        PackedBBuilder::new_for(dispatch::active(), k, n)
    }

    /// Builder for an explicit ISA (degrades to scalar if unavailable,
    /// exactly like [`pack_b_for`]). Panels start zero-filled, so the
    /// NR-padding of the last panel needs no separate pass.
    pub fn new_for(isa: Isa, k: usize, n: usize) -> PackedBBuilder {
        let isa = dispatch::clamp(isa);
        let nr = nr_of(isa);
        let np = n.div_ceil(nr).max(1);
        PackedBBuilder {
            k,
            n,
            nr,
            isa,
            panels: vec![0.0f32; np * k * nr],
            filled: 0,
            col: 0,
            lane: 0,
            at: 0,
        }
    }

    /// Append the next row-major element of B (row `i/n`, column `i%n` for
    /// the `i`-th call), writing it straight into its panel slot.
    pub fn push(&mut self, v: f32) {
        assert!(
            self.filled < self.k * self.n,
            "PackedBBuilder overfilled past {}x{}",
            self.k,
            self.n
        );
        self.panels[self.at + self.lane] = v;
        self.filled += 1;
        self.col += 1;
        self.lane += 1;
        if self.col == self.n {
            // next row of B: back to panel 0, one k-row down
            self.col = 0;
            self.lane = 0;
            self.at = (self.filled / self.n) * self.nr;
        } else if self.lane == self.nr {
            // same k-row, next NR-wide panel
            self.lane = 0;
            self.at += self.k * self.nr;
        }
    }

    /// Number of elements pushed so far (of the `k * n` required).
    pub fn filled(&self) -> usize {
        self.filled
    }

    /// Seal the builder into a [`PackedB`]; errors if the element count is
    /// short (a truncated producer must surface as `Err`, not a silently
    /// zero-padded weight panel).
    pub fn finish(self) -> anyhow::Result<PackedB> {
        if self.filled != self.k * self.n {
            anyhow::bail!(
                "PackedBBuilder got {} of {} elements for {}x{}",
                self.filled,
                self.k * self.n,
                self.k,
                self.n
            );
        }
        Ok(PackedB { k: self.k, n: self.n, nr: self.nr, isa: self.isa, panels: self.panels })
    }
}

/// Microtile panel width NR of a (host-available) ISA.
fn nr_of(isa: Isa) -> usize {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::NR,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::NR,
        _ => scalar::NR,
    }
}

/// Pack row-major `b [k, n]` into column panels for the process-wide ISA.
pub fn pack_b(b: &[f32], k: usize, n: usize) -> PackedB {
    pack_b_for(dispatch::active(), b, k, n)
}

/// Pack for an explicit ISA (the dispatch override hook used by tests and
/// benches). Unavailable ISAs degrade to scalar — check `.isa()` on the
/// result to see what was actually used.
pub fn pack_b_for(isa: Isa, b: &[f32], k: usize, n: usize) -> PackedB {
    assert!(b.len() >= k * n, "B smaller than {k}x{n}");
    let isa = dispatch::clamp(isa);
    let (nr, panels) = match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => (x86::NR, x86::pack(b, k, n)),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => (neon::NR, neon::pack(b, k, n)),
        _ => (scalar::NR, pack_panels(b, k, n, scalar::NR)),
    };
    PackedB { k, n, nr, isa, panels }
}

// Per-thread packed-A scratch for the SIMD drivers, grown on demand and
// reused across calls so the serving hot path never allocates (mirrors
// `Generator`'s SCRATCH).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
thread_local! {
    static APACK: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Repack `a [m, k]` into ⌈m/MR⌉ panels of `MR × k` (k-major inside a
/// panel, missing rows zero-filled) — shared by the SIMD drivers, whose
/// microkernels compute padded rows but never store them.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn pack_a(a: &[f32], m: usize, k: usize, mr: usize, buf: &mut Vec<f32>) {
    let tiles = m.div_ceil(mr).max(1);
    buf.clear();
    buf.resize(tiles * k * mr, 0.0);
    for t in 0..tiles {
        let i0 = t * mr;
        let rows = mr.min(m - i0.min(m));
        let dst = &mut buf[t * k * mr..(t + 1) * k * mr];
        for r in 0..rows {
            let src = &a[(i0 + r) * k..(i0 + r) * k + k];
            for (p, &v) in src.iter().enumerate() {
                dst[p * mr + r] = v;
            }
        }
    }
}

/// Generic panel packer (the scalar layout routine, parameterized by NR).
fn pack_panels(b: &[f32], k: usize, n: usize, nr: usize) -> Vec<f32> {
    let np = n.div_ceil(nr).max(1);
    let mut panels = vec![0.0f32; np * k * nr];
    for p in 0..np {
        let j0 = p * nr;
        let w = nr.min(n - j0.min(n));
        let dst = &mut panels[p * k * nr..(p + 1) * k * nr];
        for kk in 0..k {
            dst[kk * nr..kk * nr + w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
        }
    }
    panels
}

/// `C[M, N] = A[M, K] · B` (C overwritten, all row-major), on the kernel
/// matching `b`'s packed layout. Scalar-packed B is bit-identical to the
/// ascending-K naive product; SIMD-packed B matches it to the fused-term
/// bound documented in the module header.
pub fn gemm(a: &[f32], m: usize, b: &PackedB, c: &mut [f32]) {
    let (k, n) = (b.k, b.n);
    assert!(a.len() >= m * k, "A smaller than {m}x{k}");
    assert!(c.len() >= m * n, "C smaller than {m}x{n}");
    if m == 0 || n == 0 {
        return;
    }
    count_dispatch(OP_GEMM, b.isa);
    match b.isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::gemm(a, m, k, n, &b.panels, c),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::gemm(a, m, k, n, &b.panels, c),
        _ => scalar::gemm(a, m, k, n, &b.panels, c),
    }
}

/// Row-streaming GEMV: `out[N] = x[K] · b[K, N]` (row-major, unpacked).
/// The M = 1 shape NOLA's basis combination needs — packing would double
/// the memory traffic, so B streams directly; per-output accumulation is
/// still ascending-K. Dispatched to the process-wide ISA.
pub fn gemv(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    gemv_for(dispatch::active(), x, b, k, n, out);
}

/// [`gemv`] pinned to an explicit ISA (degrades to scalar if unavailable).
pub fn gemv_for(isa: Isa, x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    assert!(x.len() >= k, "x smaller than {k}");
    assert!(b.len() >= k * n, "basis smaller than {k}x{n}");
    assert!(out.len() >= n, "out smaller than {n}");
    let isa = dispatch::clamp(isa);
    count_dispatch(OP_GEMV, isa);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::gemv(x, b, k, n, out),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::gemv(x, b, k, n, out),
        _ => scalar::gemv(x, b, k, n, out),
    }
}

/// Largest `|x|` in the slice, NaN-ignoring — the quantizer's block scan.
/// All ISAs return bit-identical results (max never rounds), so encodings
/// are reproducible across hosts.
pub fn absmax(xs: &[f32]) -> f32 {
    absmax_for(dispatch::active(), xs)
}

/// [`absmax`] pinned to an explicit ISA (degrades to scalar if unavailable).
pub fn absmax_for(isa: Isa, xs: &[f32]) -> f32 {
    match dispatch::clamp(isa) {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::absmax(xs),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::absmax(xs),
        _ => scalar::absmax(xs),
    }
}

/// Absmax-quantize one block: `round(v/scale)` (ties away from zero)
/// clamped to `[-2^(bits-1), 2^(bits-1)-1]`, biased to unsigned, appended
/// to `out`. All ISAs are bit-identical (the SIMD paths reconstruct the
/// scalar formula exactly, including tie, NaN and ±inf handling), so wire
/// encodings do not depend on the encoding host.
pub fn quantize_block(chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    quantize_block_for(dispatch::active(), chunk, scale, bits, out);
}

/// [`quantize_block`] pinned to an explicit ISA (degrades to scalar if
/// unavailable).
pub fn quantize_block_for(isa: Isa, chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    match dispatch::clamp(isa) {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::quantize_block(chunk, scale, bits, out),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::quantize_block(chunk, scale, bits, out),
        _ => scalar::quantize_block(chunk, scale, bits, out),
    }
}

/// Panel width of every int8 kernel. Unlike f32 (where AVX2 widens to
/// NR = 16), eight i32 lanes fill a whole ymm/q pair, so the quantized
/// layout shares one panel width across ISAs; only the k-interleave
/// ([`PackedBQ::ku`]) differs.
const NR_Q: usize = 8;

/// k-rows interleaved per step in an ISA's quantized panel layout — the
/// unit one SIMD load covers (AVX2 reads 8 columns × 4 k's per ymm, NEON
/// 8 columns × 2 k's per q-register). The scalar kernel can read *any*
/// interleave; its own canonical layout uses the NEON-shaped ku = 2.
fn ku_of(isa: Isa) -> usize {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86_i8::KU,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon_i8::KU,
        _ => scalar::KU_Q,
    }
}

/// Rows-per-scale-group admission rule for the fused quantized-panel path.
///
/// MCNC2 scale blocks cover `block` consecutive elements of the flattened
/// row-major `[k, n]` weight. Integer accumulation needs one scalar scale
/// per (k-range × all columns) group, so the fused path admits exactly the
/// shapes where blocks tile whole rows: `block % n == 0` (`block/n` rows
/// per group) or a single block covering the whole tensor. Anything else
/// errors — callers fall back to dequantize + [`pack_b_for`].
fn qgroup_rows(k: usize, n: usize, block: usize) -> anyhow::Result<usize> {
    if k == 0 || n == 0 {
        return Ok(1);
    }
    anyhow::ensure!(block > 0, "scale block size 0 for a {k}x{n} weight");
    if block % n == 0 {
        Ok(block / n)
    } else if k * n <= block {
        Ok(k)
    } else {
        anyhow::bail!(
            "scale block {block} straddles rows of a {k}x{n} weight; the \
             quantized-panel path needs block % n == 0 or one block covering \
             the whole tensor"
        )
    }
}

/// Can a `[k, n]` weight whose scale blocks cover `block` flattened
/// elements be packed into [`PackedBQ`]'s row-group layout? Exactly the
/// `qgroup_rows` admission rule above, exposed so a cold-fill consumer can
/// peek a frame's shape + block and pick the compressed-domain path or
/// the f32 fallback *before* committing to either decode.
pub fn quant_panels_admissible(k: usize, n: usize, block: usize) -> bool {
    qgroup_rows(k, n, block).is_ok()
}

/// `B [K, N]` as *quantized* panels: the wire's biased symbols, centered to
/// i8, in ⌈N/8⌉ panels of `kpad × 8` with a per-ISA `ku` k-interleave
/// (slot `(kk/ku)·8·ku + (j%8)·ku + kk%ku` inside a panel), plus the
/// per-group f32 scales. `k` is zero-padded to a `ku` multiple — a 0
/// symbol is exactly 0 after centering, so pads add nothing to any integer
/// sum. Like [`PackedB`], the struct records the layout ISA so packing and
/// compute can never disagree.
#[derive(Debug, Clone)]
pub struct PackedBQ {
    /// Rows of the logical `[k, n]` weight.
    pub k: usize,
    /// Columns of the logical `[k, n]` weight.
    pub n: usize,
    nr: usize,
    ku: usize,
    kpad: usize,
    isa: Isa,
    bits: u32,
    kg: usize,
    n_groups: usize,
    scales: Vec<f32>,
    panels: Vec<i8>,
}

impl PackedBQ {
    /// The ISA whose panel layout (and preferred kernel) this B uses.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Panel width (always 8 — shared across ISAs on the int8 path).
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// k-interleave of the layout (AVX2 4, NEON/scalar 2).
    pub fn ku(&self) -> usize {
        self.ku
    }

    /// Symbol width in bits of the source quantization (2..=8).
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// k-rows per scale group: `scales()[g]` covers rows
    /// `g·group_rows() ..` of the weight, across all columns.
    pub fn group_rows(&self) -> usize {
        self.kg
    }

    /// Per-group dequantization scales (`k.div_ceil(group_rows())` of
    /// them; 0.0 marks an all-zero group).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Raw panel storage (centered i8 symbols in the interleaved layout).
    /// Exposed so consumers that built a `PackedBQ` two ways (fused
    /// decode→pack vs [`pack_bq_for`]) can assert the layouts agree.
    pub fn panels(&self) -> &[i8] {
        &self.panels
    }

    /// Bytes held (symbol panels + scales) — the compressed-domain
    /// footprint, ~4× smaller than the equivalent [`PackedB`].
    pub fn size_bytes(&self) -> usize {
        self.panels.len() + self.scales.len() * std::mem::size_of::<f32>()
    }
}

/// Incremental [`PackedBQ`] construction from a row-major *symbol* stream —
/// the fused decode→pack path for quantized frames, mirroring
/// [`PackedBBuilder`] but skipping dequantization entirely: rANS-decoded
/// wire symbols go straight into i8 panel slots and no f32 weight buffer
/// ever exists.
///
/// Scales arrive up front (the MCNC2 payload stores them before the symbol
/// section); [`PackedBQBuilder::push`] must then be called exactly `k * n`
/// times in row-major order and [`PackedBQBuilder::finish`] checks the
/// count. Construction errors when the scale blocks straddle rows (see
/// [`PackedBQ`]'s layout rule) — callers fall back to the f32 path.
pub struct PackedBQBuilder {
    k: usize,
    n: usize,
    ku: usize,
    kpad: usize,
    isa: Isa,
    bits: u32,
    kg: usize,
    n_groups: usize,
    bias: i32,
    scales: Vec<f32>,
    panels: Vec<i8>,
    filled: usize,
}

impl PackedBQBuilder {
    /// Builder targeting the process-wide ISA's quantized panel layout.
    pub fn new(
        k: usize,
        n: usize,
        bits: u32,
        block: usize,
        scales: Vec<f32>,
    ) -> anyhow::Result<PackedBQBuilder> {
        PackedBQBuilder::new_for(dispatch::active(), k, n, bits, block, scales)
    }

    /// Builder for an explicit ISA (degrades to scalar if unavailable,
    /// exactly like [`pack_b_for`]). `block` is the wire quantizer's
    /// flattened block size; `scales` its per-block scales. Panels start
    /// zero-filled, so neither the ku-padding of k nor the 8-padding of
    /// the last panel needs a separate pass.
    pub fn new_for(
        isa: Isa,
        k: usize,
        n: usize,
        bits: u32,
        block: usize,
        scales: Vec<f32>,
    ) -> anyhow::Result<PackedBQBuilder> {
        anyhow::ensure!((2..=8).contains(&bits), "symbol width {bits} outside 2..=8 bits");
        let isa = dispatch::clamp(isa);
        let ku = ku_of(isa);
        let kg = qgroup_rows(k, n, block)?;
        let n_groups = if k * n == 0 { 0 } else { k.div_ceil(kg) };
        anyhow::ensure!(
            scales.len() == n_groups,
            "{} scales for {n_groups} row groups of a {k}x{n} weight (block {block})",
            scales.len()
        );
        let kpad = k.div_ceil(ku) * ku;
        let np = n.div_ceil(NR_Q).max(1);
        Ok(PackedBQBuilder {
            k,
            n,
            ku,
            kpad,
            isa,
            bits,
            kg,
            n_groups,
            bias: 1i32 << (bits - 1),
            scales,
            panels: vec![0i8; np * kpad * NR_Q],
            filled: 0,
        })
    }

    /// Append the next row-major *biased* symbol of B (row `i/n`, column
    /// `i%n` for the `i`-th call), centering it and writing it straight
    /// into its interleaved panel slot.
    pub fn push(&mut self, sym: u8) {
        assert!(
            self.filled < self.k * self.n,
            "PackedBQBuilder overfilled past {}x{}",
            self.k,
            self.n
        );
        debug_assert!((sym as i32) < (1i32 << self.bits), "symbol {sym} outside the alphabet");
        let (kk, j) = (self.filled / self.n, self.filled % self.n);
        let slot = (j / NR_Q) * self.kpad * NR_Q
            + (kk / self.ku) * (NR_Q * self.ku)
            + (j % NR_Q) * self.ku
            + (kk % self.ku);
        self.panels[slot] = (sym as i32 - self.bias) as i8;
        self.filled += 1;
    }

    /// Number of symbols pushed so far (of the `k * n` required).
    pub fn filled(&self) -> usize {
        self.filled
    }

    /// Seal the builder into a [`PackedBQ`]; errors if the symbol count is
    /// short (a truncated producer must surface as `Err`, not a silently
    /// zero-padded weight panel).
    pub fn finish(self) -> anyhow::Result<PackedBQ> {
        if self.filled != self.k * self.n {
            anyhow::bail!(
                "PackedBQBuilder got {} of {} symbols for {}x{}",
                self.filled,
                self.k * self.n,
                self.k,
                self.n
            );
        }
        Ok(PackedBQ {
            k: self.k,
            n: self.n,
            nr: NR_Q,
            ku: self.ku,
            kpad: self.kpad,
            isa: self.isa,
            bits: self.bits,
            kg: self.kg,
            n_groups: self.n_groups,
            scales: self.scales,
            panels: self.panels,
        })
    }
}

/// Pack the quantized form of row-major `B [k, n]` — per-block `scales`
/// plus biased `symbols`, exactly as `codec::quantizer::Quantized` stores
/// them — into quantized panels for the process-wide ISA.
pub fn pack_bq(
    k: usize,
    n: usize,
    bits: u32,
    block: usize,
    scales: &[f32],
    symbols: &[u8],
) -> anyhow::Result<PackedBQ> {
    pack_bq_for(dispatch::active(), k, n, bits, block, scales, symbols)
}

/// [`pack_bq`] for an explicit ISA (degrades to scalar if unavailable —
/// check `.isa()` on the result). Errors when the scale blocks straddle
/// rows of the weight; see the layout rule on [`PackedBQ`].
pub fn pack_bq_for(
    isa: Isa,
    k: usize,
    n: usize,
    bits: u32,
    block: usize,
    scales: &[f32],
    symbols: &[u8],
) -> anyhow::Result<PackedBQ> {
    anyhow::ensure!(symbols.len() == k * n, "{} symbols for a {k}x{n} weight", symbols.len());
    let mut b = PackedBQBuilder::new_for(isa, k, n, bits, block, scales.to_vec())?;
    for &s in symbols {
        b.push(s);
    }
    b.finish()
}

/// Activations quantized for [`gemm_q`]: per (row, k-group) symmetric
/// absmax int8. Symbols stay in `[-127, 127]` — never −128, so the AVX2
/// sign trick cannot overflow — with one f32 scale `sa = absmax/127` per
/// group, and rows zero-padded to a multiple of 4 so every ISA's
/// interleave can over-read. The scan is deliberately scalar shared code,
/// identical on every host, which is one half of what keeps
/// dispatched-vs-scalar [`gemm_q`] bit-exact.
#[derive(Debug, Clone)]
pub struct QuantA {
    /// Rows (batch dimension).
    pub m: usize,
    /// Reduction length (must equal the consumed panel's `k`).
    pub k: usize,
    kpad: usize,
    kg: usize,
    n_groups: usize,
    syms: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantA {
    /// k-rows per scale group (must match the consumed panel's).
    pub fn group_rows(&self) -> usize {
        self.kg
    }

    /// Bytes held (symbols + scales).
    pub fn size_bytes(&self) -> usize {
        self.syms.len() + self.scales.len() * std::mem::size_of::<f32>()
    }
}

/// Quantize row-major `a [m, k]` per (row, `kg`-row k-group) for
/// [`gemm_q`]. `kg` must be the consuming panel's [`PackedBQ::group_rows`]
/// so A-group and B-group boundaries coincide. NaN quantizes to symbol 0;
/// an all-zero (or all-NaN, or underflowing-denormal) group gets scale 0.0
/// and contributes an exact 0. A group containing ±inf gets an inf scale,
/// which surfaces as NaN/inf output downstream — same contract as the f32
/// path, where non-finite inputs produce non-finite outputs.
pub fn quantize_a(a: &[f32], m: usize, k: usize, kg: usize) -> QuantA {
    assert!(a.len() >= m * k, "A smaller than {m}x{k}");
    let kg = kg.max(1);
    let n_groups = if k == 0 { 0 } else { k.div_ceil(kg) };
    let kpad = k.div_ceil(4) * 4;
    let mut syms = vec![0i8; m * kpad];
    let mut scales = vec![0.0f32; m * n_groups];
    for i in 0..m {
        let row = &a[i * k..i * k + k];
        for g in 0..n_groups {
            let k0 = g * kg;
            let k1 = (k0 + kg).min(k);
            let am = scalar::absmax(&row[k0..k1]);
            let sa = am / 127.0;
            if sa == 0.0 {
                // absmax 0 (or a denormal that underflowed the division):
                // scale stays 0.0 and the symbols stay 0 — the group is an
                // exact zero contribution
                continue;
            }
            scales[i * n_groups + g] = sa;
            for (kk, &v) in row[k0..k1].iter().enumerate() {
                let q = (v / sa).round().clamp(-127.0, 127.0) as i32;
                syms[i * kpad + k0 + kk] = q as i8;
            }
        }
    }
    QuantA { m, k, kpad, kg, n_groups, syms, scales }
}

/// `C[M, N] = A · B` computed in the compressed domain (C overwritten):
/// per scale group an exact i32 dot product of int8 symbols, rescaled to
/// f32 once at the group edge — `acc += (Σ qa·qb) as f32 * (sa·sb)`,
/// convert / multiply / add, never fused. The integer sums are order-free
/// and the float edge sequence is fixed, so the result is bit-identical on
/// every ISA; the scalar kernel is the oracle (`rust/tests/
/// prop_int8_gemm.rs` pins parity and the analytic bound vs the f32 path).
///
/// `qa` must come from [`quantize_a`] with `kg == b.group_rows()` and the
/// same `k`. SIMD kernels additionally need the group length to be a `ku`
/// multiple; other admitted shapes silently run the scalar kernel on the
/// same panels (still bit-identical — it reads any interleave).
pub fn gemm_q(qa: &QuantA, b: &PackedBQ, c: &mut [f32]) {
    assert_eq!(qa.k, b.k, "A quantized for k={} but panels have k={}", qa.k, b.k);
    assert_eq!(
        qa.kg, b.kg,
        "A has {} rows per scale group but the panels have {}",
        qa.kg, b.kg
    );
    assert!(c.len() >= qa.m * b.n, "C smaller than {}x{}", qa.m, b.n);
    // exact i32 accumulation: |qa·qb| ≤ 127·128 per term, so the longest
    // group span must stay under i32::MAX/16256 ≈ 132k terms — far above
    // any real reduction length; reject loudly rather than overflow
    let span = if b.n_groups <= 1 { b.kpad } else { b.kg + b.ku };
    assert!(
        span <= (i32::MAX as usize) / (127 * 128),
        "scale group of {span} k-rows would overflow i32 accumulation"
    );
    if qa.m == 0 || b.n == 0 {
        return;
    }
    count_dispatch(OP_GEMM_Q, b.isa);
    match b.isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if b.n_groups <= 1 || b.kg % b.ku == 0 => x86_i8::gemm_q(qa, b, c),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon if b.n_groups <= 1 || b.kg % b.ku == 0 => neon_i8::gemm_q(qa, b, c),
        _ => scalar::gemm_q(qa, b, c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    /// Ascending-K reference product (the contract every path honors).
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
        c
    }

    /// SIMD-vs-scalar closeness: fused accumulation differs from unfused
    /// by at most ~1 ulp of the running magnitude per term, so bound the
    /// difference by `2(K+1)·eps·Σ|a·b|` plus denormal slop. NaN/inf
    /// classification must agree exactly.
    fn assert_gemm_close(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        got: &[f32],
        want: &[f32],
    ) {
        let eps = f32::EPSILON as f64;
        for i in 0..m {
            for j in 0..n {
                let (g, w) = (got[i * n + j], want[i * n + j]);
                if w.is_nan() {
                    assert!(g.is_nan(), "({m},{k},{n})[{i},{j}]: {g} vs NaN");
                    continue;
                }
                if w.is_infinite() {
                    assert_eq!(g, w, "({m},{k},{n})[{i},{j}]");
                    continue;
                }
                let mag: f64 = (0..k)
                    .map(|p| (a[i * k + p] as f64 * b[p * n + j] as f64).abs())
                    .sum();
                let tol = 2.0 * (k + 1) as f64 * eps * mag + 2.0 * f32::MIN_POSITIVE as f64;
                let diff = (g as f64 - w as f64).abs();
                assert!(
                    diff <= tol,
                    "({m},{k},{n})[{i},{j}]: {g} vs {w} (diff {diff:e} > tol {tol:e})"
                );
            }
        }
    }

    #[test]
    fn scalar_gemm_bit_identical_to_naive_across_shapes() {
        // edge coverage: m {<,=,>} MR multiples, n {<,=,>} NR multiples,
        // plus blocks larger than MC/NC.
        for &(m, k, n) in
            &[(1, 1, 1), (3, 9, 8), (4, 16, 7), (5, 13, 17), (54, 9, 256), (70, 33, 523)]
        {
            let a = Stream::new(1).uniform_f32(m * k, -1.0, 1.0);
            let b = Stream::new(2).uniform_f32(k * n, -0.5, 0.5);
            let pb = pack_b_for(Isa::Scalar, &b, k, n);
            let mut c = vec![f32::NAN; m * n];
            gemm(&a, m, &pb, &mut c);
            let want = naive(&a, &b, m, k, n);
            for (i, (&got, &w)) in c.iter().zip(&want).enumerate() {
                assert!(
                    got.to_bits() == w.to_bits(),
                    "({m},{k},{n})[{i}]: {got} vs {w}"
                );
            }
        }
    }

    #[test]
    fn dispatched_gemm_matches_scalar_within_bound() {
        // remainder-tile sweep for every microtile in the tree (MR ∈
        // {4, 6, 8}, NR ∈ {8, 16}) plus shapes beyond one MC/NC block.
        for &(m, k, n) in
            &[(5, 7, 15), (6, 9, 16), (7, 16, 17), (8, 13, 31), (13, 40, 50), (97, 33, 523)]
        {
            let a = Stream::new(3).uniform_f32(m * k, -2.0, 2.0);
            let b = Stream::new(4).uniform_f32(k * n, -1.0, 1.0);
            let ps = pack_b_for(Isa::Scalar, &b, k, n);
            let pd = pack_b(&b, k, n);
            let mut cs = vec![f32::NAN; m * n];
            let mut cd = vec![f32::NAN; m * n];
            gemm(&a, m, &ps, &mut cs);
            gemm(&a, m, &pd, &mut cd);
            assert_gemm_close(&a, &b, m, k, n, &cd, &cs);
            if active() == Isa::Scalar {
                assert!(cs.iter().zip(&cd).all(|(x, y)| x.to_bits() == y.to_bits()));
            }
        }
    }

    #[test]
    fn gemm_dispatch_is_counted_per_isa() {
        // registry is process-wide and shared across tests: assert
        // monotone growth, not exact values
        let c = crate::obs::registry()
            .counter("mcnc_kernel_gemm_total", &[("isa", Isa::Scalar.name())]);
        let before = c.get();
        let b = pack_b_for(Isa::Scalar, &[1.0; 6], 2, 3);
        let mut out = [0.0f32; 3];
        gemm(&[1.0, 1.0], 1, &b, &mut out);
        assert!(c.get() >= before + 1, "scalar gemm dispatch not counted");
    }

    #[test]
    fn dispatched_pack_layout_matches_generic_packer() {
        for &(k, n) in &[(1, 1), (3, 15), (4, 16), (5, 17), (7, 40), (2, 523)] {
            let b = Stream::new(5).uniform_f32(k * n, -1.0, 1.0);
            let pb = pack_b(&b, k, n);
            assert_eq!(pb.panels, pack_panels(&b, k, n, pb.nr()), "k={k} n={n}");
        }
    }

    #[test]
    fn scalar_gemm_with_exact_zero_inputs_matches_skip_reference() {
        // the naive matvec path skips x == 0 terms; ascending-K accumulation
        // from +0.0 must agree bit-for-bit anyway.
        let (m, k, n) = (6, 10, 12);
        let mut a = Stream::new(3).uniform_f32(m * k, -1.0, 1.0);
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let b = Stream::new(4).uniform_f32(k * n, -1.0, 1.0);
        let mut skip = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    skip[i * n + j] += av * b[p * n + j];
                }
            }
        }
        let mut c = vec![0.0f32; m * n];
        gemm(&a, m, &pack_b_for(Isa::Scalar, &b, k, n), &mut c);
        assert!(c.iter().zip(&skip).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn gemv_scalar_matches_naive_row_and_dispatch_is_close() {
        let (k, n) = (7, 29);
        let x = Stream::new(5).uniform_f32(k, -2.0, 2.0);
        let b = Stream::new(6).uniform_f32(k * n, -1.0, 1.0);
        let mut out = vec![f32::NAN; n];
        gemv_for(Isa::Scalar, &x, &b, k, n, &mut out);
        let want = naive(&x, &b, 1, k, n);
        assert!(out.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));

        let mut disp = vec![f32::NAN; n];
        gemv(&x, &b, k, n, &mut disp);
        assert_gemm_close(&x, &b, 1, k, n, &disp, &out);
    }

    #[test]
    fn builder_matches_pack_b_for_every_isa_and_shape() {
        for isa in [Isa::Scalar, active()] {
            for &(k, n) in &[(1usize, 1usize), (3, 15), (4, 16), (5, 17), (7, 40), (2, 523)] {
                let b = Stream::new(6).uniform_f32(k * n, -1.0, 1.0);
                let want = pack_b_for(isa, &b, k, n);
                let mut builder = PackedBBuilder::new_for(isa, k, n);
                for &v in &b {
                    builder.push(v);
                }
                assert_eq!(builder.filled(), k * n);
                let got = builder.finish().unwrap();
                assert_eq!(got.isa(), want.isa(), "{isa:?} k={k} n={n}");
                assert_eq!(got.nr(), want.nr(), "{isa:?} k={k} n={n}");
                assert_eq!((got.k, got.n), (want.k, want.n));
                assert_eq!(got.panels(), want.panels(), "{isa:?} k={k} n={n}");
            }
        }
    }

    #[test]
    fn builder_result_computes_like_packed_b() {
        let (m, k, n) = (5, 7, 19);
        let a = Stream::new(8).uniform_f32(m * k, -1.0, 1.0);
        let b = Stream::new(9).uniform_f32(k * n, -1.0, 1.0);
        let mut builder = PackedBBuilder::new(k, n);
        for &v in &b {
            builder.push(v);
        }
        let pb = builder.finish().unwrap();
        let mut c1 = vec![f32::NAN; m * n];
        let mut c2 = vec![f32::NAN; m * n];
        gemm(&a, m, &pb, &mut c1);
        gemm(&a, m, &pack_b(&b, k, n), &mut c2);
        assert!(c1.iter().zip(&c2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn builder_short_fill_errors_and_empty_is_fine() {
        let mut builder = PackedBBuilder::new_for(Isa::Scalar, 2, 3);
        builder.push(1.0);
        let err = builder.finish().unwrap_err();
        assert!(format!("{err:#}").contains("1 of 6"), "{err:#}");

        let empty = PackedBBuilder::new_for(Isa::Scalar, 0, 0).finish().unwrap();
        assert_eq!((empty.k, empty.n), (0, 0));
    }

    #[test]
    #[should_panic(expected = "overfilled")]
    fn builder_overfill_panics() {
        let mut builder = PackedBBuilder::new_for(Isa::Scalar, 1, 1);
        builder.push(1.0);
        builder.push(2.0);
    }

    #[test]
    fn pack_pads_last_panel_with_zeros() {
        // scalar layout (NR = 8): one full panel + a 2-wide tail
        let (k, n) = (3, 10);
        let b: Vec<f32> = (0..k * n).map(|i| i as f32 + 1.0).collect();
        let pb = pack_b_for(Isa::Scalar, &b, k, n);
        assert_eq!(pb.nr(), 8);
        assert_eq!(pb.size_bytes(), 2 * k * 8 * 4);
        let tail = pb.panel(1);
        for kk in 0..k {
            assert_eq!(tail[kk * 8], b[kk * n + 8]);
            assert_eq!(tail[kk * 8 + 1], b[kk * n + 9]);
            assert!(tail[kk * 8 + 2..(kk + 1) * 8].iter().all(|&v| v == 0.0));
        }
        // dispatched layout: tail panel is padded to its own NR too
        let pd = pack_b(&b, k, n);
        let nr = pd.nr();
        let last = pd.panel(n.div_ceil(nr) - 1);
        let w = n % nr;
        if w > 0 {
            for kk in 0..k {
                assert!(last[kk * nr + w..(kk + 1) * nr].iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn degenerate_shapes_are_safe_on_every_path() {
        for isa in [Isa::Scalar, active()] {
            let pb = pack_b_for(isa, &[], 0, 0);
            gemm(&[], 0, &pb, &mut []);
            let pb = pack_b_for(isa, &[1.0, 2.0], 2, 1);
            let mut c = [0.0f32];
            gemm(&[3.0, 4.0], 1, &pb, &mut c);
            // exact: tiny integer-valued inputs round identically fused
            assert_eq!(c[0], 3.0 * 1.0 + 4.0 * 2.0, "{isa:?}");
        }
    }

    #[test]
    fn absmax_is_bit_identical_across_paths() {
        let mut xs = Stream::new(7).normal_f32(1027, 0.3);
        xs[13] = f32::NAN; // NaN is ignored, not propagated
        xs[100] = -4.5;
        xs[1020] = 1.0e-41; // denormal
        let want = xs.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for isa in [Isa::Scalar, active()] {
            assert_eq!(absmax_for(isa, &xs).to_bits(), want.to_bits(), "{isa:?}");
        }
        assert_eq!(absmax(&xs).to_bits(), want.to_bits());
        assert_eq!(absmax(&[]), 0.0);
        assert_eq!(absmax(&[f32::NAN]), 0.0);
    }

    /// Reference for the quantized-path semantics, written directly from
    /// the formula in the `gemm_q` docs (row-major symbol arrays, no
    /// panels): per group an i32 dot product, then
    /// `acc += sum as f32 * (sa·sb)`.
    fn naive_q(qa: &QuantA, bsyms: &[u8], bscales: &[f32], b: &PackedBQ) -> Vec<f32> {
        let (m, k, n) = (qa.m, qa.k, b.n);
        let bias = 1i32 << (b.bits() - 1);
        let (kg, ng) = (b.group_rows(), b.n_groups);
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for g in 0..ng {
                    let (k0, k1) = (g * kg, ((g + 1) * kg).min(k));
                    let mut sum = 0i32;
                    for kk in k0..k1 {
                        let bs = bsyms[kk * n + j] as i32 - bias;
                        sum += qa.syms[i * qa.kpad + kk] as i32 * bs;
                    }
                    let t = qa.scales[i * qa.n_groups + g] * bscales[g];
                    acc += sum as f32 * t;
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn scalar_gemm_q_matches_reference_formula_bit_for_bit() {
        // block = n (one row per group), 2n, and whole-tensor single group
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 9, 8), (5, 13, 17), (7, 40, 33)] {
            let a = Stream::new(11).uniform_f32(m * k, -2.0, 2.0);
            let w = Stream::new(12).uniform_f32(k * n, -0.7, 0.7);
            for block in [n, 2 * n, k * n] {
                let q = crate::codec::quantizer::quantize_with(Isa::Scalar, &w, 8, block);
                let pb =
                    pack_bq_for(Isa::Scalar, k, n, 8, block, &q.scales, &q.symbols).unwrap();
                let qa = quantize_a(&a, m, k, pb.group_rows());
                let mut c = vec![f32::NAN; m * n];
                gemm_q(&qa, &pb, &mut c);
                let want = naive_q(&qa, &q.symbols, &q.scales, &pb);
                for (x, y) in c.iter().zip(&want) {
                    assert!(x.to_bits() == y.to_bits(), "({m},{k},{n}) blk {block}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn dispatched_gemm_q_bit_identical_to_scalar() {
        // every admitted group shape, incl. one that forces the SIMD
        // kernels' misaligned-group fallback (kg = 1 with several groups)
        for &(m, k, n) in &[(1usize, 7usize, 5usize), (4, 16, 16), (6, 33, 23), (13, 40, 50)] {
            let a = Stream::new(13).uniform_f32(m * k, -3.0, 3.0);
            let w = Stream::new(14).uniform_f32(k * n, -1.0, 1.0);
            for (bits, block) in [(8u32, n), (8, 4 * n), (4, 2 * n), (8, k * n)] {
                let q = crate::codec::quantizer::quantize_with(Isa::Scalar, &w, bits, block);
                let ps = pack_bq_for(Isa::Scalar, k, n, bits, block, &q.scales, &q.symbols)
                    .unwrap();
                let pd = pack_bq(k, n, bits, block, &q.scales, &q.symbols).unwrap();
                let qa = quantize_a(&a, m, k, ps.group_rows());
                let mut cs = vec![f32::NAN; m * n];
                let mut cd = vec![f32::NAN; m * n];
                gemm_q(&qa, &ps, &mut cs);
                gemm_q(&qa, &pd, &mut cd);
                assert_eq!(pd.isa(), active());
                for (ix, (x, y)) in cs.iter().zip(&cd).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "({m},{k},{n}) bits {bits} blk {block} [{ix}]: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn pack_bq_rejects_row_straddling_blocks_and_bad_counts() {
        let q = crate::codec::quantizer::quantize(&[0.5f32; 15], 8, 4);
        // 3x5 weight, block 4: blocks straddle rows → admission error
        let err = pack_bq_for(Isa::Scalar, 3, 5, 8, 4, &q.scales, &q.symbols).unwrap_err();
        assert!(format!("{err:#}").contains("straddles"), "{err:#}");
        // wrong scale count
        let err = PackedBQBuilder::new_for(Isa::Scalar, 3, 5, 8, 5, vec![1.0]).unwrap_err();
        assert!(format!("{err:#}").contains("row groups"), "{err:#}");
        // short fill
        let mut b = PackedBQBuilder::new_for(Isa::Scalar, 3, 5, 8, 5, vec![1.0; 3]).unwrap();
        b.push(128);
        let err = b.finish().unwrap_err();
        assert!(format!("{err:#}").contains("1 of 15"), "{err:#}");
    }

    #[test]
    fn packed_bq_layout_and_degenerate_shapes() {
        // hand-checked slots for a 3x10 weight on the scalar ku=2 layout:
        // kpad = 4, two panels; symbol at (kk, j) lands at
        // (j/8)·32 + (kk/2)·16 + (j%8)·2 + kk%2
        let (k, n) = (3usize, 10usize);
        let syms: Vec<u8> = (0..k * n).map(|i| (i % 251) as u8).collect();
        let scales = vec![1.0f32; 3];
        let pb = pack_bq_for(Isa::Scalar, k, n, 8, n, &scales, &syms).unwrap();
        assert_eq!((pb.nr(), pb.ku(), pb.bits()), (8, 2, 8));
        assert_eq!(pb.panels().len(), 2 * 4 * 8);
        for kk in 0..k {
            for j in 0..n {
                let slot = (j / 8) * 32 + (kk / 2) * 16 + (j % 8) * 2 + kk % 2;
                let want = syms[kk * n + j] as i32 - 128;
                assert_eq!(pb.panels()[slot] as i32, want, "({kk},{j})");
            }
        }
        // ku-pad row and last-panel pad columns are zero symbols
        for j in 0..8 {
            assert_eq!(pb.panels()[16 + j * 2 + 1], 0, "k-pad at col {j}");
        }
        // degenerate shapes are safe end to end
        for isa in [Isa::Scalar, active()] {
            let pb = pack_bq_for(isa, 0, 0, 8, 64, &[], &[]).unwrap();
            gemm_q(&quantize_a(&[], 0, 0, pb.group_rows()), &pb, &mut []);
            let pb = pack_bq_for(isa, 2, 1, 8, 2, &[0.5], &[130, 126]).unwrap();
            let qa = quantize_a(&[3.0, 4.0], 1, 2, pb.group_rows());
            let mut c = [f32::NAN];
            gemm_q(&qa, &pb, &mut c);
            // (3·2 + 4·(−2))·(sa·0.5) with sa = 4/127 — small integers,
            // exact in every path
            let sa = 4.0f32 / 127.0;
            let qs = (3.0f32 / sa).round() as i32;
            let want = ((qs * 2 - 127 * 2) as f32) * (sa * 0.5);
            assert_eq!(c[0].to_bits(), want.to_bits(), "{isa:?}");
        }
    }

    #[test]
    fn gemm_q_dispatch_is_counted_per_isa() {
        let ctr = crate::obs::registry()
            .counter("mcnc_kernel_gemm_q_total", &[("isa", Isa::Scalar.name())]);
        let before = ctr.get();
        let pb = pack_bq_for(Isa::Scalar, 2, 1, 8, 2, &[0.1], &[129, 127]).unwrap();
        let qa = quantize_a(&[1.0, 1.0], 1, 2, pb.group_rows());
        let mut c = [0.0f32];
        gemm_q(&qa, &pb, &mut c);
        assert!(ctr.get() >= before + 1, "scalar gemm_q dispatch not counted");
    }

    #[test]
    fn quantize_block_is_bit_identical_across_paths() {
        // adversarial lane values: exact .5 ties in both directions (RTE
        // disagrees with ties-away on half of these), NaN, ±inf, denormals,
        // near-tie neighbors, and the clamp boundaries.
        let mut chunk = vec![0.5f32, -0.5, 2.5, -2.5, 3.5, -3.5, 126.5, -127.5];
        chunk.extend([f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0e-42, -1.0e-42]);
        chunk.extend([0.499_999_97, -0.499_999_97, 127.499_99, -128.6, 0.0, -0.0]);
        chunk.extend(Stream::new(8).normal_f32(211, 17.0));
        for bits in [2u32, 4, 8] {
            for scale in [1.0f32, 0.3, 7.5e-3, 1.0e-40] {
                let mut want = Vec::new();
                quantize_block_for(Isa::Scalar, &chunk, scale, bits, &mut want);
                let mut got = Vec::new();
                quantize_block(&chunk, scale, bits, &mut got);
                assert_eq!(got, want, "bits={bits} scale={scale:e}");
            }
        }
    }
}
