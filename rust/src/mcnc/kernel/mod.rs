//! Register-tiled, cache-blocked f32 GEMM with runtime ISA dispatch — the
//! native reconstruction microkernel layer behind `Generator::forward_into`,
//! the NOLA baseline, the coordinator's Merged-mode cold fills, and the
//! MCNC2 quantizer scans.
//!
//! Layout follows the classic GotoBLAS decomposition: B (the frozen layer
//! weights, `[K, N]` row-major) is packed once per `Generator` into
//! NR-wide column panels; the driver loops NC → MC → NR-panel → MR-tile and
//! the microkernel keeps an `MR × NR` accumulator block in registers.
//!
//! The microkernel itself is selected once per process by [`dispatch`]:
//!
//! * `scalar` — the portable MR=4 × NR=8 reference, byte-for-byte the
//!   PR-1 kernel and the bit-exactness oracle for the naive matvec path;
//! * `x86` — AVX2+FMA, MR=6 × NR=16 (two ymm columns per row);
//! * `neon` — aarch64 NEON, MR=8 × NR=8 (two q columns per row).
//!
//! Because the panel width NR differs per ISA, a [`PackedB`] remembers the
//! layout it was packed with and [`gemm`] always runs the matching kernel —
//! packing and compute can never disagree. `MCNC_SIMD=scalar|avx2|neon`
//! pins the process-wide choice (unavailable ISAs degrade to scalar); the
//! `*_for` entry points pin it per call, which is how tests compare both
//! paths inside one process. GEMM/GEMV dispatches are counted per ISA in
//! the obs registry (`mcnc_kernel_gemm_total{isa}` — see
//! docs/OBSERVABILITY.md).
//!
//! **Reduction-order contract.** Every output element is accumulated over
//! the *full* K dimension in ascending order, exactly like the per-chunk
//! `matvec` reference (`Generator::forward_naive`); there is no KC split.
//! The scalar path is bit-identical to that reference. The SIMD paths keep
//! the same order but fuse each multiply-add (one rounding per term), so
//! they agree with scalar to a K-scaled ulp bound — pinned by the parity
//! properties in `rust/tests/prop_generator_gemm.rs`.

pub mod dispatch;
#[cfg(target_arch = "aarch64")]
mod neon;
mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

pub use dispatch::{active, available, Isa};

/// Per-ISA dispatch counters — `mcnc_kernel_gemm_total{isa}` and
/// `mcnc_kernel_gemv_total{isa}` — bound lazily in the obs registry the
/// first time a kernel dispatches. After binding, each dispatch costs one
/// relaxed atomic add; the counters live here (not in `dispatch`) so the
/// increment sits next to the `match` that actually picks the kernel.
fn dispatch_counters() -> &'static [[std::sync::Arc<crate::obs::Counter>; 3]; 2] {
    static COUNTERS: std::sync::OnceLock<[[std::sync::Arc<crate::obs::Counter>; 3]; 2]> =
        std::sync::OnceLock::new();
    COUNTERS.get_or_init(|| {
        let r = crate::obs::registry();
        let bind = |name: &'static str| {
            [Isa::Scalar, Isa::Avx2, Isa::Neon]
                .map(|isa| r.counter(name, &[("isa", isa.name())]))
        };
        [bind("mcnc_kernel_gemm_total"), bind("mcnc_kernel_gemv_total")]
    })
}

const OP_GEMM: usize = 0;
const OP_GEMV: usize = 1;

fn count_dispatch(op: usize, isa: Isa) {
    let ix = match isa {
        Isa::Scalar => 0,
        Isa::Avx2 => 1,
        Isa::Neon => 2,
    };
    dispatch_counters()[op][ix].inc();
}

/// `B [K, N]` packed into ⌈N/NR⌉ panels of `K × NR` (k-major inside a
/// panel); the last panel is zero-padded to NR columns. NR is the packing
/// ISA's microtile width, so the struct pins which kernel consumes it.
#[derive(Debug, Clone)]
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    nr: usize,
    isa: Isa,
    panels: Vec<f32>,
}

impl PackedB {
    /// The ISA whose panel layout (and therefore kernel) this B uses.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Panel width (microtile NR) of the packing ISA.
    pub fn nr(&self) -> usize {
        self.nr
    }

    #[cfg(test)]
    fn panel(&self, idx: usize) -> &[f32] {
        &self.panels[idx * self.k * self.nr..(idx + 1) * self.k * self.nr]
    }

    /// The raw panel storage (⌈n/NR⌉ panels of `k × NR`, k-major inside a
    /// panel). Exposed so consumers that built a `PackedB` two ways (e.g.
    /// the codec's fused decode→pack path vs [`pack_b_for`]) can assert the
    /// layouts agree.
    pub fn panels(&self) -> &[f32] {
        &self.panels
    }

    pub fn size_bytes(&self) -> usize {
        self.panels.len() * std::mem::size_of::<f32>()
    }
}

/// Incremental [`PackedB`] construction for producers that generate B's
/// values as a row-major *stream* rather than a materialized buffer — the
/// substrate of the codec's fused decode→pack path, where dequantized
/// weights go straight into panel layout and the intermediate row-major
/// `Vec<f32>` is never allocated.
///
/// [`PackedBBuilder::push`] must be called exactly `k * n` times in
/// row-major order; [`PackedBBuilder::finish`] checks the count. The result
/// is identical to [`pack_b_for`] on the equivalent row-major buffer: every
/// ISA's pack layout matches the generic panel packer bit-for-bit (pinned
/// by the `dispatched_pack_layout_matches_generic_packer` test), so the
/// builder writes the one true layout directly.
pub struct PackedBBuilder {
    k: usize,
    n: usize,
    nr: usize,
    isa: Isa,
    panels: Vec<f32>,
    filled: usize,
    // running write cursor — push is the fused decode→pack hot path, so
    // the panel slot `(j/nr)·k·nr + kk·nr + j%nr` is tracked incrementally
    // (adds + compares) instead of recomputed with div/mod per element
    col: usize,
    lane: usize,
    at: usize,
}

impl PackedBBuilder {
    /// Builder targeting the process-wide ISA's panel layout.
    pub fn new(k: usize, n: usize) -> PackedBBuilder {
        PackedBBuilder::new_for(dispatch::active(), k, n)
    }

    /// Builder for an explicit ISA (degrades to scalar if unavailable,
    /// exactly like [`pack_b_for`]). Panels start zero-filled, so the
    /// NR-padding of the last panel needs no separate pass.
    pub fn new_for(isa: Isa, k: usize, n: usize) -> PackedBBuilder {
        let isa = dispatch::clamp(isa);
        let nr = nr_of(isa);
        let np = n.div_ceil(nr).max(1);
        PackedBBuilder {
            k,
            n,
            nr,
            isa,
            panels: vec![0.0f32; np * k * nr],
            filled: 0,
            col: 0,
            lane: 0,
            at: 0,
        }
    }

    /// Append the next row-major element of B (row `i/n`, column `i%n` for
    /// the `i`-th call), writing it straight into its panel slot.
    pub fn push(&mut self, v: f32) {
        assert!(
            self.filled < self.k * self.n,
            "PackedBBuilder overfilled past {}x{}",
            self.k,
            self.n
        );
        self.panels[self.at + self.lane] = v;
        self.filled += 1;
        self.col += 1;
        self.lane += 1;
        if self.col == self.n {
            // next row of B: back to panel 0, one k-row down
            self.col = 0;
            self.lane = 0;
            self.at = (self.filled / self.n) * self.nr;
        } else if self.lane == self.nr {
            // same k-row, next NR-wide panel
            self.lane = 0;
            self.at += self.k * self.nr;
        }
    }

    /// Number of elements pushed so far (of the `k * n` required).
    pub fn filled(&self) -> usize {
        self.filled
    }

    /// Seal the builder into a [`PackedB`]; errors if the element count is
    /// short (a truncated producer must surface as `Err`, not a silently
    /// zero-padded weight panel).
    pub fn finish(self) -> anyhow::Result<PackedB> {
        if self.filled != self.k * self.n {
            anyhow::bail!(
                "PackedBBuilder got {} of {} elements for {}x{}",
                self.filled,
                self.k * self.n,
                self.k,
                self.n
            );
        }
        Ok(PackedB { k: self.k, n: self.n, nr: self.nr, isa: self.isa, panels: self.panels })
    }
}

/// Microtile panel width NR of a (host-available) ISA.
fn nr_of(isa: Isa) -> usize {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::NR,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::NR,
        _ => scalar::NR,
    }
}

/// Pack row-major `b [k, n]` into column panels for the process-wide ISA.
pub fn pack_b(b: &[f32], k: usize, n: usize) -> PackedB {
    pack_b_for(dispatch::active(), b, k, n)
}

/// Pack for an explicit ISA (the dispatch override hook used by tests and
/// benches). Unavailable ISAs degrade to scalar — check `.isa()` on the
/// result to see what was actually used.
pub fn pack_b_for(isa: Isa, b: &[f32], k: usize, n: usize) -> PackedB {
    assert!(b.len() >= k * n, "B smaller than {k}x{n}");
    let isa = dispatch::clamp(isa);
    let (nr, panels) = match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => (x86::NR, x86::pack(b, k, n)),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => (neon::NR, neon::pack(b, k, n)),
        _ => (scalar::NR, pack_panels(b, k, n, scalar::NR)),
    };
    PackedB { k, n, nr, isa, panels }
}

// Per-thread packed-A scratch for the SIMD drivers, grown on demand and
// reused across calls so the serving hot path never allocates (mirrors
// `Generator`'s SCRATCH).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
thread_local! {
    static APACK: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Repack `a [m, k]` into ⌈m/MR⌉ panels of `MR × k` (k-major inside a
/// panel, missing rows zero-filled) — shared by the SIMD drivers, whose
/// microkernels compute padded rows but never store them.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn pack_a(a: &[f32], m: usize, k: usize, mr: usize, buf: &mut Vec<f32>) {
    let tiles = m.div_ceil(mr).max(1);
    buf.clear();
    buf.resize(tiles * k * mr, 0.0);
    for t in 0..tiles {
        let i0 = t * mr;
        let rows = mr.min(m - i0.min(m));
        let dst = &mut buf[t * k * mr..(t + 1) * k * mr];
        for r in 0..rows {
            let src = &a[(i0 + r) * k..(i0 + r) * k + k];
            for (p, &v) in src.iter().enumerate() {
                dst[p * mr + r] = v;
            }
        }
    }
}

/// Generic panel packer (the scalar layout routine, parameterized by NR).
fn pack_panels(b: &[f32], k: usize, n: usize, nr: usize) -> Vec<f32> {
    let np = n.div_ceil(nr).max(1);
    let mut panels = vec![0.0f32; np * k * nr];
    for p in 0..np {
        let j0 = p * nr;
        let w = nr.min(n - j0.min(n));
        let dst = &mut panels[p * k * nr..(p + 1) * k * nr];
        for kk in 0..k {
            dst[kk * nr..kk * nr + w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
        }
    }
    panels
}

/// `C[M, N] = A[M, K] · B` (C overwritten, all row-major), on the kernel
/// matching `b`'s packed layout. Scalar-packed B is bit-identical to the
/// ascending-K naive product; SIMD-packed B matches it to the fused-term
/// bound documented in the module header.
pub fn gemm(a: &[f32], m: usize, b: &PackedB, c: &mut [f32]) {
    let (k, n) = (b.k, b.n);
    assert!(a.len() >= m * k, "A smaller than {m}x{k}");
    assert!(c.len() >= m * n, "C smaller than {m}x{n}");
    if m == 0 || n == 0 {
        return;
    }
    count_dispatch(OP_GEMM, b.isa);
    match b.isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::gemm(a, m, k, n, &b.panels, c),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::gemm(a, m, k, n, &b.panels, c),
        _ => scalar::gemm(a, m, k, n, &b.panels, c),
    }
}

/// Row-streaming GEMV: `out[N] = x[K] · b[K, N]` (row-major, unpacked).
/// The M = 1 shape NOLA's basis combination needs — packing would double
/// the memory traffic, so B streams directly; per-output accumulation is
/// still ascending-K. Dispatched to the process-wide ISA.
pub fn gemv(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    gemv_for(dispatch::active(), x, b, k, n, out);
}

/// [`gemv`] pinned to an explicit ISA (degrades to scalar if unavailable).
pub fn gemv_for(isa: Isa, x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    assert!(x.len() >= k, "x smaller than {k}");
    assert!(b.len() >= k * n, "basis smaller than {k}x{n}");
    assert!(out.len() >= n, "out smaller than {n}");
    let isa = dispatch::clamp(isa);
    count_dispatch(OP_GEMV, isa);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::gemv(x, b, k, n, out),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::gemv(x, b, k, n, out),
        _ => scalar::gemv(x, b, k, n, out),
    }
}

/// Largest `|x|` in the slice, NaN-ignoring — the quantizer's block scan.
/// All ISAs return bit-identical results (max never rounds), so encodings
/// are reproducible across hosts.
pub fn absmax(xs: &[f32]) -> f32 {
    absmax_for(dispatch::active(), xs)
}

/// [`absmax`] pinned to an explicit ISA (degrades to scalar if unavailable).
pub fn absmax_for(isa: Isa, xs: &[f32]) -> f32 {
    match dispatch::clamp(isa) {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::absmax(xs),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::absmax(xs),
        _ => scalar::absmax(xs),
    }
}

/// Absmax-quantize one block: `round(v/scale)` (ties away from zero)
/// clamped to `[-2^(bits-1), 2^(bits-1)-1]`, biased to unsigned, appended
/// to `out`. All ISAs are bit-identical (the SIMD paths reconstruct the
/// scalar formula exactly, including tie, NaN and ±inf handling), so wire
/// encodings do not depend on the encoding host.
pub fn quantize_block(chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    quantize_block_for(dispatch::active(), chunk, scale, bits, out);
}

/// [`quantize_block`] pinned to an explicit ISA (degrades to scalar if
/// unavailable).
pub fn quantize_block_for(isa: Isa, chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    match dispatch::clamp(isa) {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::quantize_block(chunk, scale, bits, out),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::quantize_block(chunk, scale, bits, out),
        _ => scalar::quantize_block(chunk, scale, bits, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    /// Ascending-K reference product (the contract every path honors).
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
        c
    }

    /// SIMD-vs-scalar closeness: fused accumulation differs from unfused
    /// by at most ~1 ulp of the running magnitude per term, so bound the
    /// difference by `2(K+1)·eps·Σ|a·b|` plus denormal slop. NaN/inf
    /// classification must agree exactly.
    fn assert_gemm_close(
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        got: &[f32],
        want: &[f32],
    ) {
        let eps = f32::EPSILON as f64;
        for i in 0..m {
            for j in 0..n {
                let (g, w) = (got[i * n + j], want[i * n + j]);
                if w.is_nan() {
                    assert!(g.is_nan(), "({m},{k},{n})[{i},{j}]: {g} vs NaN");
                    continue;
                }
                if w.is_infinite() {
                    assert_eq!(g, w, "({m},{k},{n})[{i},{j}]");
                    continue;
                }
                let mag: f64 = (0..k)
                    .map(|p| (a[i * k + p] as f64 * b[p * n + j] as f64).abs())
                    .sum();
                let tol = 2.0 * (k + 1) as f64 * eps * mag + 2.0 * f32::MIN_POSITIVE as f64;
                let diff = (g as f64 - w as f64).abs();
                assert!(
                    diff <= tol,
                    "({m},{k},{n})[{i},{j}]: {g} vs {w} (diff {diff:e} > tol {tol:e})"
                );
            }
        }
    }

    #[test]
    fn scalar_gemm_bit_identical_to_naive_across_shapes() {
        // edge coverage: m {<,=,>} MR multiples, n {<,=,>} NR multiples,
        // plus blocks larger than MC/NC.
        for &(m, k, n) in
            &[(1, 1, 1), (3, 9, 8), (4, 16, 7), (5, 13, 17), (54, 9, 256), (70, 33, 523)]
        {
            let a = Stream::new(1).uniform_f32(m * k, -1.0, 1.0);
            let b = Stream::new(2).uniform_f32(k * n, -0.5, 0.5);
            let pb = pack_b_for(Isa::Scalar, &b, k, n);
            let mut c = vec![f32::NAN; m * n];
            gemm(&a, m, &pb, &mut c);
            let want = naive(&a, &b, m, k, n);
            for (i, (&got, &w)) in c.iter().zip(&want).enumerate() {
                assert!(
                    got.to_bits() == w.to_bits(),
                    "({m},{k},{n})[{i}]: {got} vs {w}"
                );
            }
        }
    }

    #[test]
    fn dispatched_gemm_matches_scalar_within_bound() {
        // remainder-tile sweep for every microtile in the tree (MR ∈
        // {4, 6, 8}, NR ∈ {8, 16}) plus shapes beyond one MC/NC block.
        for &(m, k, n) in
            &[(5, 7, 15), (6, 9, 16), (7, 16, 17), (8, 13, 31), (13, 40, 50), (97, 33, 523)]
        {
            let a = Stream::new(3).uniform_f32(m * k, -2.0, 2.0);
            let b = Stream::new(4).uniform_f32(k * n, -1.0, 1.0);
            let ps = pack_b_for(Isa::Scalar, &b, k, n);
            let pd = pack_b(&b, k, n);
            let mut cs = vec![f32::NAN; m * n];
            let mut cd = vec![f32::NAN; m * n];
            gemm(&a, m, &ps, &mut cs);
            gemm(&a, m, &pd, &mut cd);
            assert_gemm_close(&a, &b, m, k, n, &cd, &cs);
            if active() == Isa::Scalar {
                assert!(cs.iter().zip(&cd).all(|(x, y)| x.to_bits() == y.to_bits()));
            }
        }
    }

    #[test]
    fn gemm_dispatch_is_counted_per_isa() {
        // registry is process-wide and shared across tests: assert
        // monotone growth, not exact values
        let c = crate::obs::registry()
            .counter("mcnc_kernel_gemm_total", &[("isa", Isa::Scalar.name())]);
        let before = c.get();
        let b = pack_b_for(Isa::Scalar, &[1.0; 6], 2, 3);
        let mut out = [0.0f32; 3];
        gemm(&[1.0, 1.0], 1, &b, &mut out);
        assert!(c.get() >= before + 1, "scalar gemm dispatch not counted");
    }

    #[test]
    fn dispatched_pack_layout_matches_generic_packer() {
        for &(k, n) in &[(1, 1), (3, 15), (4, 16), (5, 17), (7, 40), (2, 523)] {
            let b = Stream::new(5).uniform_f32(k * n, -1.0, 1.0);
            let pb = pack_b(&b, k, n);
            assert_eq!(pb.panels, pack_panels(&b, k, n, pb.nr()), "k={k} n={n}");
        }
    }

    #[test]
    fn scalar_gemm_with_exact_zero_inputs_matches_skip_reference() {
        // the naive matvec path skips x == 0 terms; ascending-K accumulation
        // from +0.0 must agree bit-for-bit anyway.
        let (m, k, n) = (6, 10, 12);
        let mut a = Stream::new(3).uniform_f32(m * k, -1.0, 1.0);
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let b = Stream::new(4).uniform_f32(k * n, -1.0, 1.0);
        let mut skip = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    skip[i * n + j] += av * b[p * n + j];
                }
            }
        }
        let mut c = vec![0.0f32; m * n];
        gemm(&a, m, &pack_b_for(Isa::Scalar, &b, k, n), &mut c);
        assert!(c.iter().zip(&skip).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn gemv_scalar_matches_naive_row_and_dispatch_is_close() {
        let (k, n) = (7, 29);
        let x = Stream::new(5).uniform_f32(k, -2.0, 2.0);
        let b = Stream::new(6).uniform_f32(k * n, -1.0, 1.0);
        let mut out = vec![f32::NAN; n];
        gemv_for(Isa::Scalar, &x, &b, k, n, &mut out);
        let want = naive(&x, &b, 1, k, n);
        assert!(out.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));

        let mut disp = vec![f32::NAN; n];
        gemv(&x, &b, k, n, &mut disp);
        assert_gemm_close(&x, &b, 1, k, n, &disp, &out);
    }

    #[test]
    fn builder_matches_pack_b_for_every_isa_and_shape() {
        for isa in [Isa::Scalar, active()] {
            for &(k, n) in &[(1usize, 1usize), (3, 15), (4, 16), (5, 17), (7, 40), (2, 523)] {
                let b = Stream::new(6).uniform_f32(k * n, -1.0, 1.0);
                let want = pack_b_for(isa, &b, k, n);
                let mut builder = PackedBBuilder::new_for(isa, k, n);
                for &v in &b {
                    builder.push(v);
                }
                assert_eq!(builder.filled(), k * n);
                let got = builder.finish().unwrap();
                assert_eq!(got.isa(), want.isa(), "{isa:?} k={k} n={n}");
                assert_eq!(got.nr(), want.nr(), "{isa:?} k={k} n={n}");
                assert_eq!((got.k, got.n), (want.k, want.n));
                assert_eq!(got.panels(), want.panels(), "{isa:?} k={k} n={n}");
            }
        }
    }

    #[test]
    fn builder_result_computes_like_packed_b() {
        let (m, k, n) = (5, 7, 19);
        let a = Stream::new(8).uniform_f32(m * k, -1.0, 1.0);
        let b = Stream::new(9).uniform_f32(k * n, -1.0, 1.0);
        let mut builder = PackedBBuilder::new(k, n);
        for &v in &b {
            builder.push(v);
        }
        let pb = builder.finish().unwrap();
        let mut c1 = vec![f32::NAN; m * n];
        let mut c2 = vec![f32::NAN; m * n];
        gemm(&a, m, &pb, &mut c1);
        gemm(&a, m, &pack_b(&b, k, n), &mut c2);
        assert!(c1.iter().zip(&c2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn builder_short_fill_errors_and_empty_is_fine() {
        let mut builder = PackedBBuilder::new_for(Isa::Scalar, 2, 3);
        builder.push(1.0);
        let err = builder.finish().unwrap_err();
        assert!(format!("{err:#}").contains("1 of 6"), "{err:#}");

        let empty = PackedBBuilder::new_for(Isa::Scalar, 0, 0).finish().unwrap();
        assert_eq!((empty.k, empty.n), (0, 0));
    }

    #[test]
    #[should_panic(expected = "overfilled")]
    fn builder_overfill_panics() {
        let mut builder = PackedBBuilder::new_for(Isa::Scalar, 1, 1);
        builder.push(1.0);
        builder.push(2.0);
    }

    #[test]
    fn pack_pads_last_panel_with_zeros() {
        // scalar layout (NR = 8): one full panel + a 2-wide tail
        let (k, n) = (3, 10);
        let b: Vec<f32> = (0..k * n).map(|i| i as f32 + 1.0).collect();
        let pb = pack_b_for(Isa::Scalar, &b, k, n);
        assert_eq!(pb.nr(), 8);
        assert_eq!(pb.size_bytes(), 2 * k * 8 * 4);
        let tail = pb.panel(1);
        for kk in 0..k {
            assert_eq!(tail[kk * 8], b[kk * n + 8]);
            assert_eq!(tail[kk * 8 + 1], b[kk * n + 9]);
            assert!(tail[kk * 8 + 2..(kk + 1) * 8].iter().all(|&v| v == 0.0));
        }
        // dispatched layout: tail panel is padded to its own NR too
        let pd = pack_b(&b, k, n);
        let nr = pd.nr();
        let last = pd.panel(n.div_ceil(nr) - 1);
        let w = n % nr;
        if w > 0 {
            for kk in 0..k {
                assert!(last[kk * nr + w..(kk + 1) * nr].iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn degenerate_shapes_are_safe_on_every_path() {
        for isa in [Isa::Scalar, active()] {
            let pb = pack_b_for(isa, &[], 0, 0);
            gemm(&[], 0, &pb, &mut []);
            let pb = pack_b_for(isa, &[1.0, 2.0], 2, 1);
            let mut c = [0.0f32];
            gemm(&[3.0, 4.0], 1, &pb, &mut c);
            // exact: tiny integer-valued inputs round identically fused
            assert_eq!(c[0], 3.0 * 1.0 + 4.0 * 2.0, "{isa:?}");
        }
    }

    #[test]
    fn absmax_is_bit_identical_across_paths() {
        let mut xs = Stream::new(7).normal_f32(1027, 0.3);
        xs[13] = f32::NAN; // NaN is ignored, not propagated
        xs[100] = -4.5;
        xs[1020] = 1.0e-41; // denormal
        let want = xs.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for isa in [Isa::Scalar, active()] {
            assert_eq!(absmax_for(isa, &xs).to_bits(), want.to_bits(), "{isa:?}");
        }
        assert_eq!(absmax(&xs).to_bits(), want.to_bits());
        assert_eq!(absmax(&[]), 0.0);
        assert_eq!(absmax(&[f32::NAN]), 0.0);
    }

    #[test]
    fn quantize_block_is_bit_identical_across_paths() {
        // adversarial lane values: exact .5 ties in both directions (RTE
        // disagrees with ties-away on half of these), NaN, ±inf, denormals,
        // near-tie neighbors, and the clamp boundaries.
        let mut chunk = vec![0.5f32, -0.5, 2.5, -2.5, 3.5, -3.5, 126.5, -127.5];
        chunk.extend([f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0e-42, -1.0e-42]);
        chunk.extend([0.499_999_97, -0.499_999_97, 127.499_99, -128.6, 0.0, -0.0]);
        chunk.extend(Stream::new(8).normal_f32(211, 17.0));
        for bits in [2u32, 4, 8] {
            for scale in [1.0f32, 0.3, 7.5e-3, 1.0e-40] {
                let mut want = Vec::new();
                quantize_block_for(Isa::Scalar, &chunk, scale, bits, &mut want);
                let mut got = Vec::new();
                quantize_block(&chunk, scale, bits, &mut got);
                assert_eq!(got, want, "bits={bits} scale={scale:e}");
            }
        }
    }
}
