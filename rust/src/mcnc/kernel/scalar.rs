//! Portable reference microkernels — byte-for-byte the PR-1 register-tiled
//! scalar GEMM. This path is the bit-exactness oracle: every SIMD kernel is
//! property-tested against it (`rust/tests/prop_generator_gemm.rs`), and it
//! is what `dispatch` falls back to on hosts without AVX2/NEON.
//!
//! **Reduction-order contract.** Every output element is accumulated over
//! the *full* K dimension in ascending order, exactly like the per-chunk
//! `matvec` reference (`Generator::forward_naive`). That is why there is no
//! KC blocking: splitting K would reorder the f32 sums and break the
//! bit-exactness the property tests pin (fan-in is at most `width`, ≤ ~1k
//! floats per A-row, so the A panel rows fit L1 comfortably anyway). With
//! ascending-K accumulation from a `+0.0` accumulator, skipping exact-zero
//! terms (as the naive path does) cannot change any result bit, so the two
//! paths agree bit-for-bit. The SIMD kernels keep the same ascending-K
//! order but fuse each multiply-add (FMA, one rounding instead of two), so
//! they match this path to a K-scaled ulp bound rather than exactly.

/// Micro-tile rows (batch/chunk dimension).
pub(super) const MR: usize = 4;
/// Micro-tile columns (output-feature dimension); packing granularity.
pub(super) const NR: usize = 8;
/// Row block: A panel of MC×K f32 stays in L2 while a B panel streams L1.
const MC: usize = 64;
/// Column block, a multiple of NR.
const NC: usize = 512;

/// `C[M, N] = A[M, K] · B-panels` (C overwritten, all row-major). `panels`
/// is the NR=8 layout from `super::pack_panels`. Bit-identical to the
/// ascending-K naive product per the reduction-order contract above.
pub(super) fn gemm(a: &[f32], m: usize, k: usize, n: usize, panels: &[f32], c: &mut [f32]) {
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for ic in (0..m).step_by(MC) {
            let mc = MC.min(m - ic);
            for jr in (0..nc).step_by(NR) {
                let j = jc + jr;
                let nr = NR.min(n - j);
                let panel = &panels[(j / NR) * k * NR..(j / NR + 1) * k * NR];
                for ir in (0..mc).step_by(MR) {
                    let i = ic + ir;
                    let mr = MR.min(m - i);
                    micro(&a[i * k..], k, mr, panel, &mut c[i * n + j..], n, nr);
                }
            }
        }
    }
}

/// One MR×NR tile: `c[r, j] = Σ_p a[r, p] · panel[p, j]`, p ascending.
/// Padded panel columns are computed but never stored.
#[inline]
fn micro(a: &[f32], k: usize, mr: usize, panel: &[f32], c: &mut [f32], ldc: usize, nr: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    if mr == MR {
        for p in 0..k {
            let brow: &[f32; NR] = panel[p * NR..p * NR + NR].try_into().unwrap();
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = a[r * k + p];
                for (x, &bv) in accr.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
    } else {
        for p in 0..k {
            let brow: &[f32; NR] = panel[p * NR..p * NR + NR].try_into().unwrap();
            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                let av = a[r * k + p];
                for (x, &bv) in accr.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(mr) {
        c[r * ldc..r * ldc + nr].copy_from_slice(&accr[..nr]);
    }
}

/// Row-streaming GEMV: `out[N] = x[K] · b[K, N]` (row-major, unpacked).
/// The M = 1 shape NOLA's basis combination needs — packing would double
/// the memory traffic, so B streams directly; per-output accumulation is
/// still ascending-K.
pub(super) fn gemv(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    out[..n].fill(0.0);
    for (p, &xv) in x[..k].iter().enumerate() {
        let row = &b[p * n..(p + 1) * n];
        for (o, &bv) in out[..n].iter_mut().zip(row) {
            *o += xv * bv;
        }
    }
}

/// Largest `|x|` in the slice, ignoring NaN (the fold `m.max(v.abs())`
/// the quantizer has always used). Every SIMD variant must reproduce this
/// bit-for-bit — max never rounds, so that is achievable and enforced.
pub(super) fn absmax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Absmax-quantize one block: `q = round(v/scale)` (ties away from zero),
/// clamped to `[-qmax-1, qmax]`, stored biased by `2^(bits-1)`. This is
/// the exact per-element formula `codec::quantizer` shipped with; SIMD
/// variants are property-tested to match it bit-for-bit, including the
/// tie, NaN (→ bias symbol) and ±inf (→ clamp) edge cases.
pub(super) fn quantize_block(chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let bias = 1i32 << (bits - 1);
    for v in chunk {
        let q = (*v / scale).round().clamp(-qmax - 1.0, qmax) as i32;
        out.push((q + bias) as u8);
    }
}
