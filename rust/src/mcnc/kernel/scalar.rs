//! Portable reference microkernels — byte-for-byte the PR-1 register-tiled
//! scalar GEMM. This path is the bit-exactness oracle: every SIMD kernel is
//! property-tested against it (`rust/tests/prop_generator_gemm.rs`), and it
//! is what `dispatch` falls back to on hosts without AVX2/NEON.
//!
//! **Reduction-order contract.** Every output element is accumulated over
//! the *full* K dimension in ascending order, exactly like the per-chunk
//! `matvec` reference (`Generator::forward_naive`). That is why there is no
//! KC blocking: splitting K would reorder the f32 sums and break the
//! bit-exactness the property tests pin (fan-in is at most `width`, ≤ ~1k
//! floats per A-row, so the A panel rows fit L1 comfortably anyway). With
//! ascending-K accumulation from a `+0.0` accumulator, skipping exact-zero
//! terms (as the naive path does) cannot change any result bit, so the two
//! paths agree bit-for-bit. The SIMD kernels keep the same ascending-K
//! order but fuse each multiply-add (FMA, one rounding instead of two), so
//! they match this path to a K-scaled ulp bound rather than exactly.

/// Micro-tile rows (batch/chunk dimension).
pub(super) const MR: usize = 4;
/// Micro-tile columns (output-feature dimension); packing granularity.
pub(super) const NR: usize = 8;
/// Row block: A panel of MC×K f32 stays in L2 while a B panel streams L1.
const MC: usize = 64;
/// Column block, a multiple of NR.
const NC: usize = 512;

/// `C[M, N] = A[M, K] · B-panels` (C overwritten, all row-major). `panels`
/// is the NR=8 layout from `super::pack_panels`. Bit-identical to the
/// ascending-K naive product per the reduction-order contract above.
pub(super) fn gemm(a: &[f32], m: usize, k: usize, n: usize, panels: &[f32], c: &mut [f32]) {
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for ic in (0..m).step_by(MC) {
            let mc = MC.min(m - ic);
            for jr in (0..nc).step_by(NR) {
                let j = jc + jr;
                let nr = NR.min(n - j);
                let panel = &panels[(j / NR) * k * NR..(j / NR + 1) * k * NR];
                for ir in (0..mc).step_by(MR) {
                    let i = ic + ir;
                    let mr = MR.min(m - i);
                    micro(&a[i * k..], k, mr, panel, &mut c[i * n + j..], n, nr);
                }
            }
        }
    }
}

/// One MR×NR tile: `c[r, j] = Σ_p a[r, p] · panel[p, j]`, p ascending.
/// Padded panel columns are computed but never stored.
#[inline]
fn micro(a: &[f32], k: usize, mr: usize, panel: &[f32], c: &mut [f32], ldc: usize, nr: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    if mr == MR {
        for p in 0..k {
            let brow: &[f32; NR] = panel[p * NR..p * NR + NR].try_into().unwrap();
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = a[r * k + p];
                for (x, &bv) in accr.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
    } else {
        for p in 0..k {
            let brow: &[f32; NR] = panel[p * NR..p * NR + NR].try_into().unwrap();
            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                let av = a[r * k + p];
                for (x, &bv) in accr.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(mr) {
        c[r * ldc..r * ldc + nr].copy_from_slice(&accr[..nr]);
    }
}

/// Row-streaming GEMV: `out[N] = x[K] · b[K, N]` (row-major, unpacked).
/// The M = 1 shape NOLA's basis combination needs — packing would double
/// the memory traffic, so B streams directly; per-output accumulation is
/// still ascending-K.
pub(super) fn gemv(x: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
    out[..n].fill(0.0);
    for (p, &xv) in x[..k].iter().enumerate() {
        let row = &b[p * n..(p + 1) * n];
        for (o, &bv) in out[..n].iter_mut().zip(row) {
            *o += xv * bv;
        }
    }
}

/// Largest `|x|` in the slice, ignoring NaN (the fold `m.max(v.abs())`
/// the quantizer has always used). Every SIMD variant must reproduce this
/// bit-for-bit — max never rounds, so that is achievable and enforced.
pub(super) fn absmax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Absmax-quantize one block: `q = round(v/scale)` (ties away from zero),
/// clamped to `[-qmax-1, qmax]`, stored biased by `2^(bits-1)`. This is
/// the exact per-element formula `codec::quantizer` shipped with; SIMD
/// variants are property-tested to match it bit-for-bit, including the
/// tie, NaN (→ bias symbol) and ±inf (→ clamp) edge cases.
pub(super) fn quantize_block(chunk: &[f32], scale: f32, bits: u32, out: &mut Vec<u8>) {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let bias = 1i32 << (bits - 1);
    for v in chunk {
        let q = (*v / scale).round().clamp(-qmax - 1.0, qmax) as i32;
        out.push((q + bias) as u8);
    }
}

/// k-interleave of the scalar canonical quantized-panel layout (shared
/// with NEON; the scalar kernel itself reads *any* interleave).
pub(super) const KU_Q: usize = 2;

/// Int8 GEMM reference — the bit-exactness oracle for the whole
/// compressed-domain path. Per scale group g it forms the exact i32 dot
/// product `Σ_{kk∈g} qa[i,kk]·qb[kk,j]`, then rescales at the group edge
/// with a *fixed* f32 sequence the SIMD kernels reproduce instruction for
/// instruction: `t = sa·sb` (one f32 multiply), `sumf = sum as f32`
/// (round-to-nearest, same as `cvtepi32_ps`/`scvtf`), `acc += sumf * t`
/// (separate multiply then add — never an FMA, which would round once
/// instead of twice and break cross-ISA bit-identity).
///
/// Reads the panel through the generic slot formula
/// `(kk/ku)·nr·ku + (j%nr)·ku + kk%ku`, so it consumes any ISA's layout —
/// which is how misaligned-group shapes fall back without repacking.
/// Only real k-rows (`kk < k`) are visited; the ku-pads hold 0 symbols
/// and would add 0 to every sum, so SIMD kernels that do read them agree
/// exactly.
pub(super) fn gemm_q(qa: &super::QuantA, b: &super::PackedBQ, c: &mut [f32]) {
    let (m, k, n) = (qa.m, qa.k, b.n);
    let (nr, ku, kpad, kg, ng) = (b.nr, b.ku, b.kpad, b.kg, b.n_groups);
    for i in 0..m {
        let asy = &qa.syms[i * qa.kpad..i * qa.kpad + qa.kpad];
        let asc = &qa.scales[i * qa.n_groups..i * qa.n_groups + qa.n_groups];
        for j in 0..n {
            let panel = &b.panels[(j / nr) * kpad * nr..];
            let lane = (j % nr) * ku;
            let mut acc = 0.0f32;
            for g in 0..ng {
                let k0 = g * kg;
                let k1 = (k0 + kg).min(k);
                let mut sum = 0i32;
                for kk in k0..k1 {
                    let bs = panel[(kk / ku) * (nr * ku) + lane + (kk % ku)] as i32;
                    sum += asy[kk] as i32 * bs;
                }
                let t = asc[g] * b.scales[g];
                acc += sum as f32 * t;
            }
            c[i * n + j] = acc;
        }
    }
}
