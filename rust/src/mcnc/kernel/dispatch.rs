//! One-time ISA probe and kernel selection.
//!
//! The decision is made once per process (first kernel call) and cached:
//! `MCNC_SIMD=auto` (the default) probes the host — AVX2+FMA on x86-64 via
//! `is_x86_feature_detected!`, NEON on aarch64 (architecturally always
//! present) — and anything else falls back to the scalar reference path.
//! `MCNC_SIMD=scalar|avx2|neon` pins the choice; a pinned ISA the host
//! cannot run degrades to scalar instead of faulting, so the variable is
//! safe to export unconditionally in CI matrices.
//!
//! Tests and benches that need *both* paths in one process bypass the
//! cached probe through the explicit `*_for` entry points in the parent
//! module (`pack_b_for`, `pack_bq_for`, `gemv_for`, …) — that is the
//! dispatch override hook, and it keeps the seam exercised even on
//! scalar-only hosts.
//!
//! One probe covers both kernel families: the int8 compressed-domain
//! kernels (`x86_i8`/`neon_i8`) need no features beyond what the f32
//! probe already established (AVX2's `maddubs`/`madd`, baseline NEON
//! widening multiplies — deliberately not the optional `dotprod`
//! extension), so an `Isa` means the same thing on either path.

use std::sync::OnceLock;

/// Which microkernel family executes a call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable reference path — byte-for-byte the PR-1 register-tiled
    /// kernel, and the bit-exactness oracle for everything else.
    Scalar,
    /// AVX2 + FMA (x86-64), 6×16 micro-tile.
    Avx2,
    /// NEON (aarch64), 8×8 micro-tile.
    Neon,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse one ISA name; `None` means the string is not a known ISA.
    /// Callers decide what that means — [`active`] treats `auto` as
    /// "probe the host" and anything else unknown as "warn and pin
    /// scalar", so a typo of a pin request can never silently select a
    /// SIMD kernel the user tried to opt out of.
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" | "off" | "none" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// Can this host actually execute `isa`'s kernels?
pub fn available(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => true,
        #[cfg(not(target_arch = "x86_64"))]
        Isa::Avx2 => false,
        #[cfg(not(target_arch = "aarch64"))]
        Isa::Neon => false,
    }
}

/// Degrade a requested ISA to one the host can run (scalar if not).
pub fn clamp(isa: Isa) -> Isa {
    if available(isa) {
        isa
    } else {
        Isa::Scalar
    }
}

fn probe() -> Isa {
    if available(Isa::Avx2) {
        return Isa::Avx2;
    }
    if available(Isa::Neon) {
        return Isa::Neon;
    }
    Isa::Scalar
}

static ACTIVE: OnceLock<Isa> = OnceLock::new();

/// The process-wide kernel choice: `MCNC_SIMD` override (clamped to what
/// the host supports), else the probe. Resolved once, then a plain load.
/// An unrecognized `MCNC_SIMD` value warns and pins scalar — the
/// conservative reading of "the user tried to pin something".
pub fn active() -> Isa {
    *ACTIVE.get_or_init(|| {
        let var = std::env::var("MCNC_SIMD").unwrap_or_default();
        let req = var.trim().to_ascii_lowercase();
        match req.as_str() {
            "" | "auto" => probe(),
            other => match Isa::parse(other) {
                Some(isa) => clamp(isa),
                None => {
                    eprintln!(
                        "warning: unknown MCNC_SIMD={other:?}; using the scalar kernel \
                         (valid: scalar|avx2|neon|auto)"
                    );
                    Isa::Scalar
                }
            },
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_isas_and_rejects_unknown() {
        assert_eq!(Isa::parse("scalar"), Some(Isa::Scalar));
        assert_eq!(Isa::parse("AVX2"), Some(Isa::Avx2));
        assert_eq!(Isa::parse(" neon "), Some(Isa::Neon));
        assert_eq!(Isa::parse("auto"), None);
        assert_eq!(Isa::parse("avx512"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn active_is_stable_and_available() {
        let a = active();
        assert_eq!(a, active(), "probe must be cached");
        assert!(available(a), "active ISA must be executable");
    }

    #[test]
    fn clamp_never_returns_an_unavailable_isa() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert!(available(clamp(isa)), "{:?} clamped to unavailable", isa);
        }
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(available(Isa::Scalar));
        assert_eq!(clamp(Isa::Scalar), Isa::Scalar);
    }
}
