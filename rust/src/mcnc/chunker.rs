//! Chunk partition math (paper §3.3): the flattened compressed parameter
//! vector (length Dc) is tiled by chunks of size d; the last chunk's
//! overflow is generated and discarded. Each chunk owns (α ∈ R^k, β ∈ R),
//! so the trainable budget is n·(k+1) and the rate ≈ (k+1)/d.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkSpec {
    pub dc: usize,
    pub d: usize,
    pub k: usize,
}

impl ChunkSpec {
    pub fn new(dc: usize, d: usize, k: usize) -> ChunkSpec {
        assert!(d > 0 && dc > 0);
        ChunkSpec { dc, d, k }
    }

    /// Number of chunks (covers Dc, last one possibly partial).
    pub fn n_chunks(&self) -> usize {
        self.dc.div_ceil(self.d)
    }

    pub fn trainable_params(&self) -> usize {
        self.n_chunks() * (self.k + 1)
    }

    pub fn rate(&self) -> f64 {
        self.trainable_params() as f64 / self.dc as f64
    }

    /// Elements generated but discarded from the tail chunk.
    pub fn waste(&self) -> usize {
        self.n_chunks() * self.d - self.dc
    }

    /// Chunk index + inner offset for a flat position.
    pub fn locate(&self, pos: usize) -> (usize, usize) {
        assert!(pos < self.dc);
        (pos / self.d, pos % self.d)
    }

    /// [start, end) range of chunk i within the flat vector.
    pub fn range(&self, i: usize) -> (usize, usize) {
        let start = i * self.d;
        (start, ((i + 1) * self.d).min(self.dc))
    }

    /// Pick d for a target compression rate (twin of methods.chunk_for_rate).
    pub fn for_rate(dc: usize, rate: f64, k: usize) -> ChunkSpec {
        let d = (((k + 1) as f64 / rate).ceil() as usize).max(k + 1);
        ChunkSpec::new(dc, d, k)
    }

    /// Pick d for a target trainable budget (twin of specs.gen_for_budget).
    pub fn for_budget(dc: usize, budget: usize, k: usize) -> ChunkSpec {
        let n = (budget / (k + 1)).max(1);
        let d = dc.div_ceil(n);
        ChunkSpec::new(dc, d, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    #[test]
    fn paper_mlp_ablation_numbers() {
        // Paper A.4: MLP 784-256-256-10 compressed to ~0.2%: 54 chunks of
        // d=5000 with k=9 → 540 trainable params over Dc=268800.
        let c = ChunkSpec::new(268_800, 5000, 9);
        assert_eq!(c.n_chunks(), 54);
        assert_eq!(c.trainable_params(), 540);
        assert!((c.rate() - 0.00200892).abs() < 1e-6);
    }

    #[test]
    fn ranges_tile_exactly_once() {
        run_prop("chunks_tile", 200, |g| {
            let dc = g.usize(1, 100_000);
            let d = g.usize(1, 9_000);
            let c = ChunkSpec::new(dc, d, 3);
            let mut pos = 0usize;
            for i in 0..c.n_chunks() {
                let (s, e) = c.range(i);
                prop_assert!(s == pos, "gap before chunk {i}");
                prop_assert!(e > s, "empty chunk {i}");
                pos = e;
            }
            prop_assert!(pos == dc, "cover ends at {pos}, want {dc}");
            prop_assert!(c.waste() < d, "waste {} >= d {}", c.waste(), d);
            Ok(())
        });
    }

    #[test]
    fn locate_is_inverse_of_range() {
        run_prop("locate_inverse", 200, |g| {
            let dc = g.usize(10, 50_000);
            let d = g.usize(2, 5_000);
            let c = ChunkSpec::new(dc, d, 9);
            let pos = g.usize(0, dc - 1);
            let (ci, off) = c.locate(pos);
            let (s, e) = c.range(ci);
            prop_assert!(s + off == pos && pos < e, "bad locate");
            Ok(())
        });
    }

    #[test]
    fn for_rate_respects_budget() {
        run_prop("for_rate", 100, |g| {
            let dc = g.usize(1_000, 10_000_000);
            let k = g.usize(1, 64);
            let rate = g.f32(0.001, 0.9) as f64;
            let c = ChunkSpec::for_rate(dc, rate, k);
            prop_assert!(c.d >= k + 1, "d too small");
            // achieved rate is bounded by request (+ tail graininess)
            let ach = c.rate();
            prop_assert!(
                ach <= rate * 2.0 + (k + 1) as f64 / dc as f64,
                "rate {ach} vs requested {rate}"
            );
            Ok(())
        });
    }

    #[test]
    fn for_budget_close() {
        let c = ChunkSpec::for_budget(268_800, 5000, 9);
        let got = c.trainable_params();
        assert!((4500..=5500).contains(&got), "budget 5000 → {got}");
    }
}
