//! The MCNC generator φ : R^k → R^d, native Rust mirror of the Pallas
//! kernel / jnp reference. Used for (a) cross-layer verification against
//! the PJRT path, (b) CPU-only reconstruction fallback in the serving
//! engine, (c) the Fig-2 sphere-coverage analysis, and (d) FLOPs
//! accounting. Weights come from the same SplitMix64 streams as the
//! Python twin (`compile/genutil.py`), so a scalar seed fully determines φ.

use std::cell::RefCell;

use anyhow::{bail, Result};

use crate::mcnc::kernel::{self, PackedB};
use crate::util::json::Json;
use crate::util::prng::{tag, Stream};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Sine,
    Sigmoid,
    Relu,
    LeakyRelu,
    Elu,
    Linear,
}

impl Act {
    pub fn parse(s: &str) -> Result<Act> {
        Ok(match s {
            "sine" => Act::Sine,
            "sigmoid" => Act::Sigmoid,
            "relu" => Act::Relu,
            "lrelu" => Act::LeakyRelu,
            "elu" => Act::Elu,
            "linear" => Act::Linear,
            _ => bail!("unknown activation {s:?}"),
        })
    }

    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Act::Sine => x.sin(),
            Act::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            Act::Relu => x.max(0.0),
            Act::LeakyRelu => {
                if x >= 0.0 {
                    x
                } else {
                    0.01 * x
                }
            }
            Act::Elu => {
                if x >= 0.0 {
                    x
                } else {
                    x.exp() - 1.0
                }
            }
            Act::Linear => x,
        }
    }
}

/// Twin of `python/compile/genutil.GenCfg` (paper Table 10 defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct GenCfg {
    pub k: usize,
    pub d: usize,
    pub width: usize,
    pub depth: usize,
    pub freq: f32,
    pub act: Act,
    pub normalize: bool,
    pub residual: bool,
    pub init: String,      // "uniform" | "normal"
    pub init_scale: f32,
}

impl Default for GenCfg {
    fn default() -> Self {
        GenCfg {
            k: 9,
            d: 5000,
            width: 1000,
            depth: 3,
            freq: 4.5,
            act: Act::Sine,
            normalize: false,
            residual: false,
            init: "uniform".into(),
            init_scale: 1.0,
        }
    }
}

impl GenCfg {
    /// Parse the `gen` object embedded in manifest metadata / init laws.
    pub fn from_json(j: &Json) -> Result<GenCfg> {
        Ok(GenCfg {
            k: j.get("k").and_then(Json::as_usize).unwrap_or(9),
            d: j.get("d").and_then(Json::as_usize).unwrap_or(5000),
            width: j.get("width").and_then(Json::as_usize).unwrap_or(1000),
            depth: j.get("depth").and_then(Json::as_usize).unwrap_or(3),
            freq: j.get("freq").and_then(Json::as_f64).unwrap_or(4.5) as f32,
            act: Act::parse(j.get("act").and_then(Json::as_str).unwrap_or("sine"))?,
            normalize: j.get("normalize").and_then(Json::as_bool).unwrap_or(false),
            residual: j.get("residual").and_then(Json::as_bool).unwrap_or(false),
            init: j.get("init").and_then(Json::as_str).unwrap_or("uniform").to_string(),
            init_scale: j.get("init_scale").and_then(Json::as_f64).unwrap_or(1.0) as f32,
        })
    }

    pub fn layer_shapes(&self) -> Vec<(usize, usize)> {
        assert!(self.depth >= 2, "generator depth must be >= 2");
        let mut dims = vec![self.k];
        dims.extend(std::iter::repeat(self.width).take(self.depth - 1));
        dims.push(self.d);
        (0..self.depth).map(|i| (dims[i], dims[i + 1])).collect()
    }

    pub fn n_weights(&self) -> usize {
        self.layer_shapes().iter().map(|(a, b)| a * b).sum()
    }

    /// FLOPs to reconstruct one d-chunk — paper Appendix A.6 convention
    /// (2·Σ fan_in·fan_out matmul FLOPs + d for the β scale).
    pub fn flops_per_chunk(&self) -> usize {
        2 * self.n_weights() + self.d
    }

    /// Frozen weights from a scalar seed; bit-identical to the Python twin.
    pub fn make_weights(&self, seed: u64) -> Vec<Vec<f32>> {
        self.layer_shapes()
            .iter()
            .enumerate()
            .map(|(i, &(fan_in, fan_out))| {
                let mut s = Stream::sub(seed, tag::GEN_LAYER + i as u64);
                let n = fan_in * fan_out;
                if self.init == "normal" {
                    let std = self.init_scale / (3.0f32.sqrt() * fan_in as f32);
                    s.normal_f32(n, std)
                } else {
                    let bound = self.init_scale / fan_in as f32;
                    s.symmetric_f32(n, bound)
                }
            })
            .collect()
    }
}

/// A frozen generator instance: cfg + materialized weights, plus the
/// per-layer GEMM panels packed once at construction (`mcnc::kernel`).
#[derive(Debug, Clone)]
pub struct Generator {
    pub cfg: GenCfg,
    pub ws: Vec<Vec<f32>>, // row-major [fan_in, fan_out]
    packed: Vec<PackedB>,
}

// Per-thread layer activations for the batched engine: two ping-pong
// buffers sized n_rows × max(width, d), grown on demand and reused across
// calls so the serving hot path never allocates.
thread_local! {
    static SCRATCH: RefCell<(Vec<f32>, Vec<f32>)> = const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Raw output pointer that may cross the pool boundary; each worker writes
/// a disjoint `[start·d, end·d)` row range, so the aliasing is sound.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: the pointer targets the caller's `out` buffer, which outlives
// the blocking `parallel_for` call, and every worker writes only its own
// disjoint row range.
unsafe impl Send for SendPtr {}
// SAFETY: shared access is read-only pointer arithmetic; writes through
// the pointer are partitioned by row range (see Send above).
unsafe impl Sync for SendPtr {}

impl Generator {
    pub fn from_seed(cfg: GenCfg, seed: u64) -> Generator {
        let ws = cfg.make_weights(seed);
        let packed = pack_layers(&cfg, &ws);
        Generator { cfg, ws, packed }
    }

    pub fn with_weights(cfg: GenCfg, ws: Vec<Vec<f32>>) -> Result<Generator> {
        let shapes = cfg.layer_shapes();
        if ws.len() != shapes.len() {
            bail!("expected {} weight tensors, got {}", shapes.len(), ws.len());
        }
        for (w, &(a, b)) in ws.iter().zip(&shapes) {
            if w.len() != a * b {
                bail!("weight size {} != {}x{}", w.len(), a, b);
            }
        }
        let packed = pack_layers(&cfg, &ws);
        Ok(Generator { cfg, ws, packed })
    }

    /// φ for a batch: `alpha [n, k]` (row-major), `beta [n]` → `out [n, d]`.
    pub fn forward(&self, alpha: &[f32], beta: &[f32]) -> Vec<f32> {
        let n = beta.len();
        let mut out = vec![0.0f32; n * self.cfg.d];
        self.forward_into(alpha, beta, &mut out);
        out
    }

    /// Allocation-free variant for the serving hot path. The batch runs as
    /// layer-level blocked GEMMs (`[n,k]·[k,w]` → act → … → `[n,d]`) split
    /// over disjoint row blocks on the persistent `util::threadpool` pool
    /// (no per-call thread spawn; packed weight panels are shared
    /// read-only, so the old bandwidth cap on re-reading W_depth is gone —
    /// before/after numbers live in EXPERIMENTS.md §Perf+§Kernels /
    /// `benches/perf_micro.rs`). The GEMMs run on the microkernel
    /// `mcnc::kernel` dispatched at startup (AVX2+FMA / NEON / scalar, see
    /// `kernel::dispatch`); the layers were packed for that same ISA at
    /// construction. Chunks are independent, so any row split is
    /// bit-identical for a fixed kernel.
    pub fn forward_into(&self, alpha: &[f32], beta: &[f32], out: &mut [f32]) {
        let n = beta.len();
        let k = self.cfg.k;
        let d = self.cfg.d;
        assert_eq!(alpha.len(), n * k, "alpha shape mismatch");
        assert_eq!(out.len(), n * d, "out shape mismatch");
        // don't split below ~128k reconstructed FLOPs per block: dispatch
        // latency would dominate (tiny generators, e.g. the Fig-2 S² ones,
        // get large blocks; mlp02-sized ones split per chunk)
        let min_rows = (131_072 / self.cfg.flops_per_chunk().max(1)).max(1);
        let ptr = SendPtr(out.as_mut_ptr());
        crate::util::threadpool::global().parallel_for(n, min_rows, &|s, e| {
            // SAFETY: `out` is n·d long and outlives this blocking call;
            // parallel_for hands each worker a disjoint [s, e) row range,
            // so the reborrowed sub-slices never overlap.
            let rows = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(s * d), (e - s) * d) };
            self.forward_chunks(&alpha[s * k..e * k], &beta[s..e], rows);
        });
    }

    /// Single-threaded batched engine over a contiguous run of chunks:
    /// one blocked GEMM per layer, activations fused per element.
    fn forward_chunks(&self, alpha: &[f32], beta: &[f32], out: &mut [f32]) {
        let cfg = &self.cfg;
        let n = beta.len();
        assert_eq!(alpha.len(), n * cfg.k, "alpha shape mismatch");
        assert_eq!(out.len(), n * cfg.d, "out shape mismatch");
        let shapes = cfg.layer_shapes();
        let maxw = cfg.width.max(cfg.d);
        SCRATCH.with(|cell| {
            let mut buf = cell.borrow_mut();
            if buf.0.len() < n * maxw {
                buf.0.resize(n * maxw, 0.0);
                buf.1.resize(n * maxw, 0.0);
            }
            let (a, b) = &mut *buf;
            let mut cur: &mut [f32] = &mut a[..n * maxw];
            let mut nxt: &mut [f32] = &mut b[..n * maxw];

            // layer 0: [n, k] -> [n, w0], input scaled by freq inside act
            let (_, fo0) = shapes[0];
            kernel::gemm(alpha, n, &self.packed[0], cur);
            for v in cur[..n * fo0].iter_mut() {
                *v = cfg.act.apply(cfg.freq * *v);
            }
            let mut width = fo0;
            // hidden + output layers
            for (li, &(fi, fo)) in shapes.iter().enumerate().skip(1) {
                debug_assert_eq!(fi, width);
                kernel::gemm(&cur[..n * fi], n, &self.packed[li], nxt);
                let last = li == shapes.len() - 1;
                if cfg.residual && !last {
                    // hidden layers are width→width, so rows align
                    for r in 0..n {
                        let prev = &cur[r * width..r * width + fo];
                        for (x, &p) in nxt[r * fo..r * fo + fo].iter_mut().zip(prev) {
                            let mut v = cfg.act.apply(*x);
                            v += p;
                            *x = v;
                        }
                    }
                } else {
                    for v in nxt[..n * fo].iter_mut() {
                        *v = cfg.act.apply(*v);
                    }
                }
                std::mem::swap(&mut cur, &mut nxt);
                width = fo;
            }
            // normalize + β scale into the output rows (width == d here)
            for i in 0..n {
                let vrow = &cur[i * width..i * width + cfg.d];
                let scale = if cfg.normalize {
                    let nrm = vrow
                        .iter()
                        .map(|v| (*v as f64) * (*v as f64))
                        .sum::<f64>()
                        .sqrt() as f32;
                    beta[i] / (nrm + 1e-8)
                } else {
                    beta[i]
                };
                for (o, v) in out[i * cfg.d..(i + 1) * cfg.d].iter_mut().zip(vrow) {
                    *o = v * scale;
                }
            }
        });
    }

    /// Reference implementation: one chunk at a time via naive matvecs —
    /// the seed's original hot path, retained as the oracle for the
    /// blocked-GEMM engine (bit-exact against the scalar kernel,
    /// ulp-bounded against the SIMD kernels — see
    /// `tests/prop_generator_gemm.rs`) and as the perf baseline in
    /// `benches/perf_micro.rs`.
    pub fn forward_naive(&self, alpha: &[f32], beta: &[f32], out: &mut [f32]) {
        let cfg = &self.cfg;
        let n = beta.len();
        assert_eq!(alpha.len(), n * cfg.k, "alpha shape mismatch");
        assert_eq!(out.len(), n * cfg.d, "out shape mismatch");
        let shapes = cfg.layer_shapes();

        // One chunk at a time keeps the working set in L1/L2.
        let mut cur = vec![0.0f32; cfg.width.max(cfg.d)];
        let mut nxt = vec![0.0f32; cfg.width.max(cfg.d)];
        for i in 0..n {
            // layer 0: [k] -> [w0], input scaled by freq inside the sin
            let a = &alpha[i * cfg.k..(i + 1) * cfg.k];
            let (fi, fo) = shapes[0];
            matvec_in(a, &self.ws[0], fi, fo, &mut cur);
            for v in cur[..fo].iter_mut() {
                *v = cfg.act.apply(cfg.freq * *v);
            }
            let mut width = fo;
            // hidden layers
            for (li, &(fi, fo)) in shapes.iter().enumerate().skip(1) {
                matvec_in(&cur[..width], &self.ws[li], fi, fo, &mut nxt);
                let last = li == shapes.len() - 1;
                for j in 0..fo {
                    let mut v = cfg.act.apply(nxt[j]);
                    if cfg.residual && !last {
                        v += cur[j];
                    }
                    nxt[j] = v;
                }
                std::mem::swap(&mut cur, &mut nxt);
                width = fo;
            }
            // normalize + β scale into the output row
            let row = &mut out[i * cfg.d..(i + 1) * cfg.d];
            let scale = if cfg.normalize {
                let nrm = cur[..cfg.d]
                    .iter()
                    .map(|v| (*v as f64) * (*v as f64))
                    .sum::<f64>()
                    .sqrt() as f32;
                beta[i] / (nrm + 1e-8)
            } else {
                beta[i]
            };
            for (o, v) in row.iter_mut().zip(&cur[..cfg.d]) {
                *o = v * scale;
            }
        }
    }

    /// Reconstruct a Dc-length flat delta (chunks concatenated, tail cut).
    /// Only the ⌈dc/d⌉ chunks that contribute are generated — the seed
    /// version built all n chunks and truncated, wasting a full generator
    /// pass whenever the caller's dc ended before the last chunk.
    pub fn reconstruct_delta(&self, alpha: &[f32], beta: &[f32], dc: usize) -> Vec<f32> {
        let d = self.cfg.d;
        let k = self.cfg.k;
        let need = dc.div_ceil(d).min(beta.len());
        let mut out = vec![0.0f32; need * d];
        self.forward_into(&alpha[..need * k], &beta[..need], &mut out);
        out.truncate(dc.min(out.len()));
        out
    }
}

fn pack_layers(cfg: &GenCfg, ws: &[Vec<f32>]) -> Vec<PackedB> {
    cfg.layer_shapes()
        .iter()
        .zip(ws)
        .map(|(&(a, b), w)| kernel::pack_b(w, a, b))
        .collect()
}

/// out[..fo] = x[..fi] @ w[fi, fo] (row-major w). Reference kernel only.
#[inline]
fn matvec_in(x: &[f32], w: &[f32], fi: usize, fo: usize, out: &mut [f32]) {
    out[..fo].fill(0.0);
    for (i, &xi) in x[..fi].iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * fo..(i + 1) * fo];
        for (o, &wv) in out[..fo].iter_mut().zip(row) {
            *o += xi * wv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> GenCfg {
        GenCfg { k: 3, d: 8, width: 4, depth: 3, ..GenCfg::default() }
    }

    #[test]
    fn layer_shapes_and_flops() {
        let c = GenCfg { k: 5, d: 5000, width: 32, depth: 3, ..GenCfg::default() };
        assert_eq!(c.layer_shapes(), vec![(5, 32), (32, 32), (32, 5000)]);
        // paper A.6: 2*(5*32+32*32+32*5000) + 5000
        assert_eq!(c.flops_per_chunk(), 2 * (5 * 32 + 32 * 32 + 32 * 5000) + 5000);
    }

    #[test]
    fn weights_deterministic_and_bounded() {
        let c = tiny_cfg();
        let w1 = c.make_weights(7);
        let w2 = c.make_weights(7);
        let w3 = c.make_weights(8);
        assert_eq!(w1, w2);
        assert_ne!(w1, w3);
        for (w, (fi, _)) in w1.iter().zip(c.layer_shapes()) {
            let bound = 1.0 / fi as f32;
            assert!(w.iter().all(|v| v.abs() <= bound + 1e-7));
        }
    }

    #[test]
    fn zero_alpha_is_zero_output() {
        let g = Generator::from_seed(tiny_cfg(), 1);
        let out = g.forward(&[0.0; 6], &[1.0, 1.0]);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn beta_scales_linearly() {
        let g = Generator::from_seed(tiny_cfg(), 2);
        let alpha: Vec<f32> = (0..6).map(|i| 0.1 * i as f32).collect();
        let one = g.forward(&alpha, &[1.0, 1.0]);
        let three = g.forward(&alpha, &[3.0, 3.0]);
        for (a, b) in one.iter().zip(&three) {
            assert!((3.0 * a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn normalized_rows_unit() {
        let mut cfg = tiny_cfg();
        cfg.normalize = true;
        let g = Generator::from_seed(cfg, 3);
        let alpha: Vec<f32> = (0..6).map(|i| 0.3 * (i as f32) - 0.5).collect();
        let out = g.forward(&alpha, &[2.0, 0.5]);
        for (i, b) in [2.0f32, 0.5].iter().enumerate() {
            let nrm: f32 = out[i * 8..(i + 1) * 8].iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((nrm - b.abs()).abs() < 1e-3, "row {i}: {nrm} vs {b}");
        }
    }

    #[test]
    fn residual_and_depths() {
        for depth in [2, 3, 4, 5] {
            for residual in [false, true] {
                let cfg = GenCfg { depth, residual, ..tiny_cfg() };
                let g = Generator::from_seed(cfg, 4);
                let out = g.forward(&[0.5, -0.5, 0.25], &[1.0]);
                assert_eq!(out.len(), 8);
                assert!(out.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn all_activations_finite() {
        for act in ["sine", "sigmoid", "relu", "lrelu", "elu", "linear"] {
            let cfg = GenCfg { act: Act::parse(act).unwrap(), ..tiny_cfg() };
            let g = Generator::from_seed(cfg, 5);
            let out = g.forward(&[1.0, -2.0, 0.5], &[1.5]);
            assert!(out.iter().all(|v| v.is_finite()), "{act}");
        }
    }

    #[test]
    fn gemm_engine_matches_naive_reference() {
        // odd batch sizes exercise the MR/NR edge tiles; every config knob
        // is flipped at least once (the randomized sweep lives in
        // tests/prop_generator_gemm.rs). With the scalar kernel active the
        // engine is bit-identical to the matvec reference; with a SIMD
        // kernel each GEMM term is fused, so last-ulp noise (amplified
        // through the depth-bounded layer stack) is tolerated instead.
        let scalar = kernel::active() == kernel::Isa::Scalar;
        for (residual, normalize, depth, n) in
            [(false, false, 3, 13), (true, false, 4, 7), (false, true, 2, 5), (true, true, 3, 1)]
        {
            let cfg = GenCfg {
                k: 3,
                d: 19,
                width: 11,
                depth,
                residual,
                normalize,
                ..GenCfg::default()
            };
            let g = Generator::from_seed(cfg.clone(), 9);
            let alpha: Vec<f32> = (0..n * 3).map(|i| 0.17 * (i as f32) - 1.0).collect();
            let beta: Vec<f32> = (0..n).map(|i| 0.5 + 0.25 * i as f32).collect();
            let fast = g.forward(&alpha, &beta);
            let mut slow = vec![0.0f32; n * 19];
            g.forward_naive(&alpha, &beta, &mut slow);
            for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                let ok = if scalar {
                    a.to_bits() == b.to_bits()
                } else {
                    (a - b).abs() <= 2e-3 * (1.0 + b.abs())
                };
                assert!(
                    ok,
                    "res={residual} norm={normalize} depth={depth} n={n} [{i}]: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn reconstruct_truncates_tail() {
        let g = Generator::from_seed(tiny_cfg(), 6);
        let alpha = vec![0.1; 9]; // 3 chunks
        let beta = vec![1.0; 3];
        let d = g.reconstruct_delta(&alpha, &beta, 20); // 3*8=24 -> cut to 20
        assert_eq!(d.len(), 20);
        let full = g.forward(&alpha, &beta);
        assert_eq!(&d[..], &full[..20]);
    }

    #[test]
    fn reconstruct_skips_untouched_chunks() {
        // dc = 9 needs ⌈9/8⌉ = 2 of the 3 chunks; the third must not
        // change the result (and is not generated at all)
        let g = Generator::from_seed(tiny_cfg(), 6);
        let alpha = vec![0.1; 9];
        let beta = vec![1.0; 3];
        let d = g.reconstruct_delta(&alpha, &beta, 9);
        let full = g.forward(&alpha, &beta);
        assert_eq!(&d[..], &full[..9]);
        // dc beyond the available chunks clamps instead of panicking
        let all = g.reconstruct_delta(&alpha, &beta, 100);
        assert_eq!(all.len(), 24);
    }

    #[test]
    fn cfg_json_roundtrip() {
        let j = crate::util::json::parse(
            r#"{"k":5,"d":512,"width":64,"depth":3,"freq":4.5,"act":"sine",
                "normalize":false,"residual":false,"init":"uniform","init_scale":1.0}"#,
        )
        .unwrap();
        let c = GenCfg::from_json(&j).unwrap();
        assert_eq!(c.k, 5);
        assert_eq!(c.d, 512);
        assert_eq!(c.act, Act::Sine);
        assert!(!c.normalize);
    }
}
