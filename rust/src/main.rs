//! `mcnc` — the leader binary: train, evaluate, serve and inspect
//! compressed models. Python never runs here; everything executes through
//! AOT artifacts (`make artifacts`).

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use mcnc::codec::Codec;
use mcnc::coordinator::workload::{open_loop, replay, replay_socket, Zipf};
use mcnc::coordinator::{
    BatchPolicy, BreakerCfg, Mode, RestartPolicy, RetryPolicy, Server, ServerCfg,
};
use mcnc::data::{Dataset, MarkovLm, SynthVision};
use mcnc::mcnc::{Act, GenCfg, Generator};
use mcnc::runtime::{artifacts_dir, Session};
use mcnc::train::{self, Checkpoint, LrSchedule, TrainCfg, TrainState};
use mcnc::util::cli::Args;
use mcnc::util::config::Config;
use mcnc::util::prng::Stream;
use mcnc::util::threadpool;

fn main() {
    mcnc::util::logging::init_from_env();
    mcnc::obs::init_from_env();
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    if let Some(t) = args.get("threads") {
        let n: usize = t
            .parse()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| anyhow!("--threads expects a positive integer, got {t:?}"))?;
        // must win the race with the first reconstruction call; at the top
        // of run() nothing has touched the pool yet
        if !threadpool::configure_global(n) {
            eprintln!("warning: --threads {n} ignored (pool already started)");
        }
    }
    match cmd {
        "info" => info(args),
        "train" => train_cmd(args),
        "eval" => eval_cmd(args),
        "serve" => serve_cmd(args),
        "replay" => replay_cmd(args),
        "sphere" => sphere_cmd(args),
        "config" => config_cmd(args),
        "pack" => pack_cmd(args),
        "warm" => warm_cmd(args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "mcnc — Manifold-Constrained Neural Compression (ICLR'25 reproduction)

  info    [--group G]            list artifact executables (+ meta)
  train   --exec NAME [--steps N --lr F --batch B --seed S --out CK --codec lossless|int8|int4 --block N --data synth|c10|c100|lm]
  eval    --ckpt FILE [--seed S]
  serve   [--kind K --tasks N --shards N --rate HZ --secs S --merged BOOL --native-recon BOOL --zipf S --queue-cap N --preload FILE
           --deadline-ms MS --max-restarts N --retry N --breaker K
           --metrics-file F --metrics-interval-ms N --trace-out F
           --listen ADDR --max-conns N]
  replay  --connect ADDR [--conns C --rate HZ --secs S --tasks N --zipf S
           --deadline-ms MS --seed S --collect-secs N]
                                 drive a remote `serve --listen` server over
                                 C concurrent MCNP1 connections (loopback or
                                 LAN) and report end-to-end p50/p99
  sphere  [--acts sine,sigmoid,relu --l 1,5,10,100 --width 256]
  config  --file cfg.toml        config-driven training job
  pack    --ckpt FILE --out FILE [--codec lossless|int8|int4 --block N]
                                 re-encode a checkpoint as an MCNC2 container
  warm    --out FILE [--kind K --tasks N --seed S --codec lossless|int8|int4 --block N]
                                 write a multi-task warm-start artifact
                                 (task{t}/{slot} frames; docs/FORMAT.md)

Global flags / env:
  --threads N     pin the reconstruction + decode thread pool (same as
                  MCNC_THREADS=N); makes bench and serve runs reproducible
                  across hosts — parallel decode is bit-identical at every
                  thread count
  --preload FILE  (serve) warm-start every shard from FILE before traffic:
                  adapters install and, with --merged --native-recon, each
                  task's full θ is pre-reconstructed into the merged LRU;
                  restarted shards re-warm from the same artifact
  --deadline-ms N (serve) per-request deadline: requests not batched within
                  N ms are shed with a deadline-exceeded error (0 = none)
  --max-restarts N (serve) consecutive unproductive engine restarts before a
                  crashed shard is declared permanently dead (default 3)
  --retry N       (serve) dispatcher re-attempts (with backoff + jitter) on
                  a full admission queue before surfacing Rejected (default 0)
  --breaker K     (serve) open a shard's circuit breaker after K consecutive
                  batch failures; 0 disables (default)
  --metrics-file F (serve) write a metrics-registry snapshot to F every
                  --metrics-interval-ms N (default 1000), plus a final one on
                  stop; `.prom`/`.txt` extension → Prometheus text exposition,
                  anything else → JSON (docs/OBSERVABILITY.md)
  --listen ADDR   (serve) serve the MCNP1 framed socket protocol on ADDR
                  (e.g. 127.0.0.1:7433; port 0 = ephemeral, printed at bind)
                  instead of generating local load; runs for --secs seconds
                  (0 = until killed), then drains every connection. Remote
                  clients use `mcnc replay --connect ADDR`; byte-level spec
                  in docs/PROTOCOL.md
  --max-conns N   (serve --listen) connection cap; accepts beyond it are
                  refused with a typed connection error (default 1024)
  --trace-out F   (serve) record request/shard spans and write a Chrome
                  trace-event JSON to F on stop (load in Perfetto or
                  chrome://tracing); forces MCNC_TRACE=all unless MCNC_TRACE
                  is already set
  MCNC_TRACE=x    request tracing: off (default) | all | sampled:N (trace
                  every Nth request id)
  MCNC_LOG=x      stderr log level: debug|info|warn|off (default info)
  MCNC_SIMD=x     pin the reconstruction microkernel ISA: scalar|avx2|neon|auto
                  (default auto probes the host; unavailable ISAs fall back
                  to scalar)

Artifacts come from `make artifacts`; set MCNC_ARTIFACTS to relocate.";

fn info(args: &Args) -> Result<()> {
    let manifest = mcnc::runtime::Manifest::load(&artifacts_dir())?;
    let group = args.get("group");
    let mut names: Vec<_> = manifest.entries.values().collect();
    names.sort_by(|a, b| (&a.group, &a.name).cmp(&(&b.group, &b.name)));
    println!("{:<12} {:<34} {:>9} {:>8} {:>12}", "GROUP", "NAME", "RATE", "PARAMS", "RECON-FLOPs");
    for e in names {
        if let Some(g) = group {
            if e.group != g {
                continue;
            }
        }
        let rate = if e.rate().is_nan() { "-".into() } else { format!("{:.3}%", e.rate() * 100.0) };
        println!(
            "{:<12} {:<34} {:>9} {:>8} {:>12}",
            e.group, e.name, rate, e.trainable_comp(), e.recon_flops()
        );
    }
    Ok(())
}

fn dataset_for(entry_model: &str, data_flag: &str, seed: u64) -> Arc<dyn Dataset> {
    match data_flag {
        "c100" => Arc::new(SynthVision::cifar_like(seed, 100)),
        "c10" => Arc::new(SynthVision::cifar_like(seed, 10)),
        "lm" => Arc::new(MarkovLm::base(seed, 128, 32)),
        _ => {
            // infer from the model name
            if entry_model.starts_with("lm") {
                Arc::new(MarkovLm::base(seed, 128, 32))
            } else if entry_model.contains("c100") {
                Arc::new(SynthVision::cifar_like(seed, 100))
            } else if entry_model.starts_with("resnet") || entry_model.starts_with("vit") {
                Arc::new(SynthVision::cifar_like(seed, 10))
            } else {
                Arc::new(SynthVision::new(seed, 10, 28, 28, 1))
            }
        }
    }
}

fn train_cmd(args: &Args) -> Result<()> {
    let exec = args.require("exec")?;
    let train_name =
        if exec.ends_with("_train") { exec.to_string() } else { format!("{exec}_train") };
    let sess = Session::open(&artifacts_dir())?;
    let seed = args.u64_or("seed", 1);
    let mut state = TrainState::new(&sess, &train_name, seed)?;
    let entry = state.entry.clone();
    let model = entry.meta.get("model").and_then(|j| j.as_str()).unwrap_or("mlp");
    let batch = entry.meta.get("batch").and_then(|j| j.as_usize()).unwrap_or(128);
    let data = dataset_for(model, &args.str_or("data", "auto"), seed.wrapping_add(1000));

    let steps = args.usize_or("steps", 300);
    let cfg = TrainCfg {
        steps,
        batch: args.usize_or("batch", batch),
        schedule: LrSchedule::Cosine {
            base: args.f32_or("lr", 0.05),
            total: steps,
            floor_frac: 0.05,
        },
        eval_every: args.usize_or("eval-every", (steps / 4).max(1)),
        eval_batches: args.usize_or("eval-batches", 4),
        log_every: args.usize_or("log-every", 20),
        verbose: true,
    };
    println!(
        "training {train_name}: {} compressed params ({:.3}% of model), {} steps",
        state.compressed_params(),
        entry.rate() * 100.0,
        cfg.steps
    );
    let hist = train::run(&mut state, data, &cfg)?;
    println!(
        "final: val_loss {:.4} val_acc {:.4}",
        hist.final_val_loss(),
        hist.final_val_acc()
    );
    if let Some(out) = args.get("out") {
        let ck = Checkpoint::from_state(&state);
        let bytes = if let Some(codec) = args.get("codec") {
            // MCNC2: compressed container (auto-detected by `eval`/`load`)
            let codec = Codec::parse(codec, args.usize_or("block", 64))?;
            ck.save_v2(std::path::Path::new(out), codec)?
        } else {
            ck.save(std::path::Path::new(out))?;
            ck.stored_bytes()
        };
        println!("checkpoint: {} ({} bytes, {} params)", out, bytes, ck.stored_params());
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let path = args.require("ckpt")?;
    let ck = Checkpoint::load(std::path::Path::new(path))?;
    let sess = Session::open(&artifacts_dir())?;
    let mut state = TrainState::new(&sess, &ck.entry, ck.seed)?;
    ck.restore(&mut state)?;
    let model =
        state.entry.meta.get("model").and_then(|j| j.as_str()).unwrap_or("mlp").to_string();
    let batch = state.entry.meta.get("batch").and_then(|j| j.as_usize()).unwrap_or(128);
    let data = dataset_for(&model, &args.str_or("data", "auto"), ck.seed.wrapping_add(1000));
    let (loss, acc) =
        train::evaluate(&state, data.as_ref(), batch, args.usize_or("eval-batches", 8))?;
    println!("{}: val_loss {:.4} val_acc {:.4} (step {})", ck.entry, loss, acc, ck.step);
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<()> {
    let cfg = ServerCfg {
        kind: args.str_or("kind", "lm_mcnclora8"),
        n_tasks: args.usize_or("tasks", 8),
        n_shards: args.usize_or("shards", 1),
        policy: BatchPolicy {
            max_batch: 16,
            max_delay: std::time::Duration::from_millis(args.u64_or("max-delay-ms", 5)),
        },
        mode: if args.bool_or("merged", false) { Mode::Merged } else { Mode::OnTheFly },
        cache_bytes: args.usize_or("cache-mb", 64) << 20,
        seed: args.u64_or("seed", 1),
        native_recon: args.bool_or("native-recon", false),
        queue_cap: args.usize_or("queue-cap", 1024),
        deadline: match args.u64_or("deadline-ms", 0) {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
        restart: RestartPolicy {
            max_restarts: args.u32_or("max-restarts", RestartPolicy::default().max_restarts),
            ..RestartPolicy::default()
        },
        retry: RetryPolicy { attempts: args.u32_or("retry", 0), ..RetryPolicy::default() },
        breaker: BreakerCfg {
            threshold: args.u32_or("breaker", 0),
            ..BreakerCfg::default()
        },
        ..ServerCfg::default()
    };
    let rate = args.f32_or("rate", 200.0) as f64;
    let secs = args.f32_or("secs", 5.0) as f64;
    let zipf_s = args.f32_or("zipf", 1.0) as f64;
    let n_tasks = cfg.n_tasks;
    // an operator-supplied NaN/∞ exponent must fail here, not panic the
    // workload generator mid-run
    Zipf::try_new(n_tasks, zipf_s).context("--zipf")?;

    println!(
        "serving {} ({:?}), {} tasks on {} shard(s), {:.0} req/s for {:.0}s …",
        cfg.kind, cfg.mode, n_tasks, cfg.n_shards, rate, secs
    );
    // --trace-out implies tracing on for the run; an explicit MCNC_TRACE
    // (e.g. sampled:100) still wins so operators can bound trace volume
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        if std::env::var("MCNC_TRACE").is_err() {
            mcnc::obs::trace::set_mode(mcnc::obs::TraceMode::All);
        }
        mcnc::obs::trace::clear();
    }
    // periodic metrics snapshots: the registry is process-global, so the
    // writer thread needs no handle on the server
    let metrics_file = args.get("metrics-file").map(std::path::PathBuf::from);
    let metrics_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let metrics_writer = metrics_file.clone().map(|path| {
        let stop = Arc::clone(&metrics_stop);
        let interval =
            std::time::Duration::from_millis(args.u64_or("metrics-interval-ms", 1000).max(10));
        std::thread::spawn(move || {
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                write_metrics_file(&path);
                // sleep in short slices so stop is honored promptly
                let mut left = interval;
                while !stop.load(std::sync::atomic::Ordering::Relaxed)
                    && left > std::time::Duration::ZERO
                {
                    let s = left.min(std::time::Duration::from_millis(50));
                    std::thread::sleep(s);
                    left = left.saturating_sub(s);
                }
            }
        })
    });
    let lm = MarkovLm::base(1, 128, 32);
    let schedule =
        open_loop(7, rate, std::time::Duration::from_secs_f64(secs), n_tasks, zipf_s);
    let server = Server::start(artifacts_dir(), cfg)?;
    if args.has("preload") {
        let path = args.require("preload")?;
        if path == "true" {
            anyhow::bail!("--preload expects a warm-start artifact path (see `mcnc warm`)");
        }
        let warm = server
            .preload(std::path::Path::new(path))
            .with_context(|| format!("preloading warm-start artifact {path:?}"))?;
        println!(
            "preloaded {path}: {} adapters installed, {} merged-θ prefills, {} foreign-task frames skipped across shards",
            warm.installed, warm.prefilled, warm.skipped
        );
    }
    let rep = if let Some(addr) = args.get("listen") {
        // socket front-end: remote clients drive the load (`mcnc replay
        // --connect`); --secs bounds the serving window, 0 = until killed
        let net_cfg = mcnc::net::NetCfg {
            addr: addr.clone(),
            max_conns: args.usize_or("max-conns", 1024),
            ..mcnc::net::NetCfg::default()
        };
        let listener = mcnc::net::NetListener::bind(net_cfg)?;
        println!(
            "listening on {} (MCNP1; spec docs/PROTOCOL.md) for {}",
            listener.local_addr()?,
            if secs > 0.0 { format!("{secs:.0}s") } else { "ever (kill to stop)".into() }
        );
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let timer = (secs > 0.0).then(|| {
            let stop = Arc::clone(&stop);
            let window = std::time::Duration::from_secs_f64(secs);
            std::thread::spawn(move || {
                // sleep in short slices so a finished run exits promptly
                let t0 = std::time::Instant::now();
                while t0.elapsed() < window {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                }
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            })
        });
        let net = listener.run(&server, &stop)?;
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(t) = timer {
            let _ = t.join();
        }
        println!(
            "net: {} conns accepted ({} refused, {} protocol errors), {} requests, {} frames in / {} out, {} B read / {} B written",
            net.accepted,
            net.refused,
            net.protocol_errors,
            net.requests,
            net.frames_in,
            net.frames_out,
            net.bytes_read,
            net.bytes_written,
        );
        None
    } else {
        Some(replay(&server, &lm, 9, &schedule))
    };
    let stats = server.stop()?;
    metrics_stop.store(true, std::sync::atomic::Ordering::Relaxed);
    if let Some(h) = metrics_writer {
        let _ = h.join();
    }
    if let Some(path) = &metrics_file {
        // final snapshot after stop so the file carries the run's totals
        write_metrics_file(path);
        println!("metrics snapshot: {}", path.display());
    }
    if let Some(path) = &trace_out {
        let recs = mcnc::obs::trace::records();
        std::fs::write(path, mcnc::obs::export::chrome_trace(&recs))
            .with_context(|| format!("writing chrome trace {}", path.display()))?;
        println!(
            "chrome trace: {} ({} records; load in Perfetto or chrome://tracing)",
            path.display(),
            recs.len()
        );
    }
    if let Some(rep) = &rep {
        println!(
            "ok {}/{} (rejected {} failed {} deadline-exceeded {} dropped {} timed-out {}) | throughput {:.1} req/s | p50 {:?} p99 {:?} | queue p50 {:?} p99 {:?} | occupancy {:.2} | recon {:.2} GFLOPs",
            rep.ok,
            schedule.len(),
            rep.rejected,
            rep.failed,
            rep.deadline_exceeded,
            rep.dropped,
            rep.timed_out,
            stats.throughput(),
            stats.latency.percentile(50.0),
            stats.latency.percentile(99.0),
            stats.queue_wait.percentile(50.0),
            stats.queue_wait.percentile(99.0),
            stats.occupancy(),
            stats.recon_flops as f64 / 1e9,
        );
    } else {
        println!(
            "served: throughput {:.1} req/s | p50 {:?} p99 {:?} | queue p50 {:?} p99 {:?} | occupancy {:.2} | recon {:.2} GFLOPs",
            stats.throughput(),
            stats.latency.percentile(50.0),
            stats.latency.percentile(99.0),
            stats.queue_wait.percentile(50.0),
            stats.queue_wait.percentile(99.0),
            stats.occupancy(),
            stats.recon_flops as f64 / 1e9,
        );
    }
    if stats.restarts + stats.deadline_shed + stats.batch_panics + stats.breaker_opens > 0 {
        println!(
            "fault recovery: {} shard restart(s), {} request(s) shed at deadline, {} contained batch panic(s), {} breaker open(s), {} breaker fast-fail(s), {} admission retry(s)",
            stats.restarts,
            stats.deadline_shed,
            stats.batch_panics,
            stats.breaker_opens,
            stats.breaker_fastfail,
            stats.retries,
        );
    }
    Ok(())
}

/// `mcnc replay --connect ADDR`: the remote client half of `serve
/// --listen` — generate the same deterministic open-loop workload the
/// in-process serve path uses and drive it over C concurrent MCNP1
/// connections, reporting client-measured end-to-end latency.
fn replay_cmd(args: &Args) -> Result<()> {
    let addr = args.require("connect")?;
    let conns = args.usize_or("conns", 8);
    let rate = args.f32_or("rate", 200.0) as f64;
    let secs = args.f32_or("secs", 5.0) as f64;
    let n_tasks = args.usize_or("tasks", 8);
    let zipf_s = args.f32_or("zipf", 1.0) as f64;
    Zipf::try_new(n_tasks, zipf_s).context("--zipf")?;
    let deadline = match args.u64_or("deadline-ms", 0) {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    let collect = std::time::Duration::from_secs(args.u64_or("collect-secs", 30).max(1));
    let lm = MarkovLm::base(1, 128, 32);
    let schedule = open_loop(
        args.u64_or("seed", 7),
        rate,
        std::time::Duration::from_secs_f64(secs),
        n_tasks,
        zipf_s,
    );
    println!(
        "replaying {} requests ({:.0} req/s, {n_tasks} tasks, zipf {zipf_s}) over {conns} connection(s) to {addr} …",
        schedule.len(),
        rate,
    );
    let rep = replay_socket(addr, &lm, 9, &schedule, conns, deadline, collect)?;
    println!(
        "ok {}/{} (rejected {} failed {} deadline-exceeded {} conn-errors {} missing {}) | e2e p50 {:?} p99 {:?} max {:?}",
        rep.ok,
        rep.sent,
        rep.rejected,
        rep.failed,
        rep.deadline_exceeded,
        rep.conn_errors,
        rep.missing,
        rep.latency.percentile(50.0),
        rep.latency.percentile(99.0),
        rep.latency.max(),
    );
    if rep.conn_errors > 0 || rep.missing > 0 {
        anyhow::bail!(
            "{} connection error(s), {} request(s) unanswered",
            rep.conn_errors,
            rep.missing
        );
    }
    Ok(())
}

/// Write one metrics-registry snapshot to `path`: Prometheus text
/// exposition when the extension is `.prom`/`.txt`, JSON otherwise.
/// Best-effort — a failed write warns and the run continues (metrics must
/// never take down serving).
fn write_metrics_file(path: &std::path::Path) {
    let snap = mcnc::obs::registry().snapshot();
    let body = match path.extension().and_then(|e| e.to_str()) {
        Some("prom") | Some("txt") => mcnc::obs::export::prometheus_text(&snap),
        _ => mcnc::util::json::to_string(&mcnc::obs::export::snapshot_json(&snap)),
    };
    if let Err(e) = std::fs::write(path, body) {
        eprintln!("warning: metrics snapshot {}: {e}", path.display());
    }
}

fn sphere_cmd(args: &Args) -> Result<()> {
    let acts = args.str_or("acts", "sine,sigmoid,relu");
    let ls = args.str_or("l", "1,5,10,100");
    let width = args.usize_or("width", 256);
    let n = args.usize_or("points", 2048);
    println!("{:<10} {:>8} {:>12}", "ACT", "L", "UNIFORMITY");
    for act in acts.split(',') {
        for l in ls.split(',') {
            let l: f32 = l.parse().map_err(|_| anyhow!("bad L {l:?}"))?;
            let cfg = GenCfg {
                k: 1,
                d: 3,
                width,
                depth: 3,
                freq: 1.0,
                act: Act::parse(act)?,
                normalize: true,
                ..GenCfg::default()
            };
            let gen = Generator::from_seed(cfg, 42);
            let alpha = Stream::new(7).uniform_f32(n, -l, l);
            let pts = gen.forward(&alpha, &vec![1.0; n]);
            let u = mcnc::sphere::uniformity(&pts, 3, 10.0, 11, 64);
            println!("{:<10} {:>8} {:>12.4}", act, l, u);
        }
    }
    Ok(())
}

fn pack_cmd(args: &Args) -> Result<()> {
    let inp = args.require("ckpt")?;
    let out = args.require("out")?;
    let codec = Codec::parse(&args.str_or("codec", "lossless"), args.usize_or("block", 64))?;
    let ck = Checkpoint::load(std::path::Path::new(inp))?;
    let wire = ck.save_v2(std::path::Path::new(out), codec)?;
    let in_bytes = std::fs::metadata(inp)?.len();
    println!(
        "{inp} → {out} [{}]: {in_bytes} → {wire} bytes ({:.2}x smaller, {} tensors)",
        codec.name(),
        in_bytes as f64 / wire.max(1) as f64,
        ck.tensors.len()
    );
    if !codec.is_lossless() {
        println!(
            "note: {} is lossy (absmax-bounded); keep the original for bit-exact restores",
            codec.name()
        );
    }
    Ok(())
}

fn warm_cmd(args: &Args) -> Result<()> {
    let out = args.require("out")?;
    let kind = args.str_or("kind", "lm_mcnclora8");
    let n_tasks = args.usize_or("tasks", 8);
    let seed = args.u64_or("seed", 1);
    let codec = Codec::parse(&args.str_or("codec", "lossless"), args.usize_or("block", 64))?;
    let wire = mcnc::coordinator::warm::write_synth_artifact(
        &artifacts_dir(),
        std::path::Path::new(out),
        &kind,
        n_tasks,
        seed,
        codec,
    )?;
    println!(
        "warm-start artifact {out} [{}]: {n_tasks} tasks for kind {kind}, {wire} bytes",
        codec.name()
    );
    println!("serve it with: mcnc serve --kind {kind} --tasks {n_tasks} --preload {out}");
    if !codec.is_lossless() {
        println!(
            "note: {} is lossy (absmax-bounded) — warmed adapters differ from \
             seed-synthesized ones by the quantization error",
            codec.name()
        );
    }
    Ok(())
}

fn config_cmd(args: &Args) -> Result<()> {
    let path = args.require("file")?;
    let cfg = Config::load(path)?;
    let exec = cfg.str_or("train.exec", "mlp_mcnc02");
    let mut forwarded = vec![
        format!("--exec={exec}"),
        format!("--steps={}", cfg.usize_or("train.steps", 300)),
        format!("--lr={}", cfg.f32_or("train.lr", 0.05)),
        format!("--seed={}", cfg.u64_or("train.seed", 1)),
        format!("--data={}", cfg.str_or("train.data", "auto")),
    ];
    let out = cfg.str_or("train.out", "");
    if !out.is_empty() {
        forwarded.push(format!("--out={out}"));
    }
    let fargs = Args::parse(forwarded);
    train_cmd(&fargs)
}
