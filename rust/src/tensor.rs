//! Host-side tensors: the small dense-array substrate everything above the
//! PJRT boundary uses (training state, data batches, reconstruction math).
//! Deliberately minimal — shaped `Vec<f32>` / `Vec<i32>` with the handful of
//! ops the coordinator needs; all heavy math lives in the XLA executables.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn from_f32(data: Vec<f32>, dims: &[usize]) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if data.len() != n {
            bail!("shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        Ok(Tensor { dims: dims.to_vec(), data: Data::F32(data) })
    }

    pub fn from_i32(data: Vec<i32>, dims: &[usize]) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if data.len() != n {
            bail!("shape {:?} wants {} elements, got {}", dims, n, data.len());
        }
        Ok(Tensor { dims: dims.to_vec(), data: Data::I32(data) })
    }

    pub fn zeros(dims: &[usize]) -> Tensor {
        let n = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: Data::F32(vec![0.0; n]) }
    }

    pub fn ones(dims: &[usize]) -> Tensor {
        let n = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: Data::F32(vec![1.0; n]) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { dims: vec![], data: Data::F32(vec![v]) }
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        match &self.data {
            Data::F32(v) if v.len() == 1 => Ok(v[0]),
            Data::I32(v) if v.len() == 1 => Ok(v[0] as f32),
            _ => bail!("tensor of {} elements is not a scalar", self.numel()),
        }
    }

    /// Reinterpret shape (numel must match).
    pub fn reshaped(mut self, dims: &[usize]) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if n != self.numel() {
            bail!("cannot reshape {:?} to {:?}", self.dims, dims);
        }
        self.dims = dims.to_vec();
        Ok(self)
    }

    /// L2 norm (f32 tensors).
    pub fn norm(&self) -> f32 {
        match &self.data {
            Data::F32(v) => v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt() as f32,
            Data::I32(_) => 0.0,
        }
    }
}

/// Max |a-b| over two f32 tensors (∞ on shape/type mismatch).
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    match (a.f32s(), b.f32s()) {
        (Ok(x), Ok(y)) if x.len() == y.len() => x
            .iter()
            .zip(y)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f32, f32::max),
        _ => f32::INFINITY,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::from_f32(vec![1.0; 6], &[2, 3]).is_ok());
        assert!(Tensor::from_f32(vec![1.0; 5], &[2, 3]).is_err());
        assert!(Tensor::from_i32(vec![1; 4], &[4]).is_ok());
    }

    #[test]
    fn scalar_and_numel() {
        let t = Tensor::scalar_f32(2.5);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.scalar().unwrap(), 2.5);
        assert_eq!(Tensor::zeros(&[3, 4]).numel(), 12);
        assert!(Tensor::zeros(&[2]).scalar().is_err());
    }

    #[test]
    fn dtype_accessors() {
        let t = Tensor::from_i32(vec![1, 2], &[2]).unwrap();
        assert_eq!(t.dtype(), DType::I32);
        assert!(t.f32s().is_err());
        assert_eq!(t.i32s().unwrap(), &[1, 2]);
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::from_f32(vec![0.0; 12], &[3, 4]).unwrap();
        let r = t.reshaped(&[2, 6]).unwrap();
        assert_eq!(r.dims, vec![2, 6]);
        assert!(r.reshaped(&[5]).is_err());
    }

    #[test]
    fn diff_and_norm() {
        let a = Tensor::from_f32(vec![3.0, 4.0], &[2]).unwrap();
        let b = Tensor::from_f32(vec![3.0, 4.5], &[2]).unwrap();
        assert!((a.norm() - 5.0).abs() < 1e-6);
        assert!((max_abs_diff(&a, &b) - 0.5).abs() < 1e-6);
        let c = Tensor::from_i32(vec![1, 2], &[2]).unwrap();
        assert_eq!(max_abs_diff(&a, &c), f32::INFINITY);
    }
}
