//! Streaming `io::Read`/`io::Write` adapters over the MCNC2 container.
//!
//! The encoder writes `magic | header | frame* | end-marker` incrementally;
//! the decoder yields tensors one frame at a time, so a receiver (e.g. a
//! serving shard ingesting a cold adapter) never materializes the whole
//! payload. Frame bodies are CRC-verified *before* any payload parsing, and
//! length fields are bounded, so truncated or bit-flipped streams fail with
//! an error — never a panic, never a silently wrong tensor.

use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};

use super::container::{
    crc32, encode_frame, read_varint, ContainerHeader, MAGIC_V2, MAX_FRAME, MAX_HEADER,
};
use super::{container, Codec};
use crate::tensor::Tensor;

/// Streaming MCNC2 writer. Call [`Encoder::finish`] to terminate the
/// stream; a dropped encoder leaves it truncated (which decoders reject).
pub struct Encoder<W: Write> {
    w: W,
    wire_bytes: usize,
    written: usize,
    declared: Option<usize>,
}

impl<W: Write> Encoder<W> {
    pub fn new(mut w: W, header: &ContainerHeader) -> Result<Encoder<W>> {
        let hj = header.to_json();
        if hj.len() > MAX_HEADER {
            bail!("container header of {} bytes exceeds bound", hj.len());
        }
        let mut pre = Vec::new();
        pre.extend_from_slice(MAGIC_V2);
        container::put_varint(&mut pre, hj.len() as u64);
        pre.extend_from_slice(hj.as_bytes());
        pre.extend_from_slice(&crc32(hj.as_bytes()).to_le_bytes());
        w.write_all(&pre)?;
        Ok(Encoder { w, wire_bytes: pre.len(), written: 0, declared: header.n_tensors })
    }

    /// Encode and append one tensor frame; returns its wire size.
    pub fn write_tensor(&mut self, name: &str, t: &Tensor, codec: Codec) -> Result<usize> {
        let body = encode_frame(name, t, codec)?;
        if body.len() > MAX_FRAME {
            bail!("frame {name:?} of {} bytes exceeds bound", body.len());
        }
        let mut len = Vec::new();
        container::put_varint(&mut len, body.len() as u64);
        self.w.write_all(&len)?;
        self.w.write_all(&body)?;
        self.w.write_all(&crc32(&body).to_le_bytes())?;
        let frame = len.len() + body.len() + 4;
        self.wire_bytes += frame;
        self.written += 1;
        Ok(frame)
    }

    /// Total bytes written so far (header + frames).
    pub fn wire_bytes(&self) -> usize {
        self.wire_bytes
    }

    /// Write the end marker and flush; returns the writer and the total
    /// wire size. Fails at the producer — not at some remote decoder — if
    /// fewer/more frames were written than the header declared.
    pub fn finish(mut self) -> Result<(W, usize)> {
        if let Some(n) = self.declared {
            if self.written != n {
                bail!("container wrote {} of {n} declared tensors", self.written);
            }
        }
        self.w.write_all(&[0u8])?; // varint 0 = end of frames
        self.w.flush()?;
        self.wire_bytes += 1;
        Ok((self.w, self.wire_bytes))
    }
}

/// Streaming MCNC2 reader: header up front, then one tensor per
/// [`Decoder::next_tensor`] call.
pub struct Decoder<R: Read> {
    r: R,
    header: ContainerHeader,
    seen: usize,
    done: bool,
}

impl<R: Read> Decoder<R> {
    /// Read and check the magic, then the header.
    pub fn new(mut r: R) -> Result<Decoder<R>> {
        let mut magic = [0u8; 6];
        r.read_exact(&mut magic)
            .map_err(|_| anyhow!("stream too short for MCNC2 magic"))?;
        if &magic != MAGIC_V2 {
            bail!("not an MCNC2 stream");
        }
        Decoder::after_magic(r)
    }

    /// Continue past an already-consumed magic (the checkpoint loader
    /// sniffs the magic itself to dispatch between MCNC1 and MCNC2).
    pub fn after_magic(mut r: R) -> Result<Decoder<R>> {
        let hlen = read_varint(&mut r)? as usize;
        if hlen > MAX_HEADER {
            bail!("container header length {hlen} unreasonable");
        }
        let hbuf = read_exactly(&mut r, hlen).map_err(|_| anyhow!("container header truncated"))?;
        let mut crc = [0u8; 4];
        r.read_exact(&mut crc).map_err(|_| anyhow!("container header CRC missing"))?;
        if crc32(&hbuf) != u32::from_le_bytes(crc) {
            bail!("container header CRC mismatch");
        }
        let header = ContainerHeader::parse(
            std::str::from_utf8(&hbuf).map_err(|_| anyhow!("container header not utf-8"))?,
        )?;
        Ok(Decoder { r, header, seen: 0, done: false })
    }

    pub fn header(&self) -> &ContainerHeader {
        &self.header
    }

    /// Decode the next frame, or `None` past the end marker. Errors are
    /// sticky only in the sense that callers should stop on the first one.
    pub fn next_tensor(&mut self) -> Result<Option<(String, Tensor, Codec)>> {
        if self.done {
            return Ok(None);
        }
        let len = read_varint(&mut self.r).map_err(|_| anyhow!("stream truncated (no frame)"))?
            as usize;
        if len == 0 {
            if let Some(n) = self.header.n_tensors {
                if self.seen != n {
                    bail!("stream ended after {} of {n} tensors", self.seen);
                }
            }
            self.done = true;
            return Ok(None);
        }
        if len > MAX_FRAME {
            bail!("frame length {len} unreasonable");
        }
        let body = read_exactly(&mut self.r, len).map_err(|_| anyhow!("frame truncated"))?;
        let mut crc = [0u8; 4];
        self.r.read_exact(&mut crc).map_err(|_| anyhow!("frame CRC missing"))?;
        if crc32(&body) != u32::from_le_bytes(crc) {
            bail!("frame CRC mismatch");
        }
        let frame = container::decode_frame(&body)?;
        self.seen += 1;
        Ok(Some(frame))
    }
}

/// Read exactly `n` bytes via a bounded incremental read, so a corrupt
/// length cannot drive a giant up-front allocation: the buffer only grows
/// as real bytes arrive.
fn read_exactly(r: &mut impl Read, n: usize) -> std::io::Result<Vec<u8>> {
    let mut buf = Vec::new();
    r.take(n as u64).read_to_end(&mut buf)?;
    if buf.len() != n {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("wanted {n} bytes, got {}", buf.len()),
        ));
    }
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream as Prng;

    fn sample_tensors() -> Vec<(String, Tensor)> {
        let mut s = Prng::new(21);
        vec![
            ("alpha".to_string(), Tensor::from_f32(s.normal_f32(486, 0.05), &[54, 9]).unwrap()),
            ("beta".to_string(), Tensor::ones(&[54])),
        ]
    }

    fn encode_all(codec: Codec) -> Vec<u8> {
        let header = ContainerHeader {
            entry: "mlp_mcnc02_train".into(),
            seed: 42,
            step: 10.0,
            n_tensors: Some(2),
        };
        let mut enc = Encoder::new(Vec::new(), &header).unwrap();
        for (name, t) in sample_tensors() {
            enc.write_tensor(&name, &t, codec).unwrap();
        }
        let (bytes, total) = enc.finish().unwrap();
        assert_eq!(bytes.len(), total);
        bytes
    }

    #[test]
    fn stream_roundtrip_per_tensor() {
        let bytes = encode_all(Codec::Lossless);
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        assert_eq!(dec.header().entry, "mlp_mcnc02_train");
        assert_eq!(dec.header().seed, 42);
        let orig = sample_tensors();
        let mut n = 0;
        while let Some((name, t, codec)) = dec.next_tensor().unwrap() {
            assert_eq!(name, orig[n].0);
            assert_eq!(codec, Codec::Lossless);
            let a = t.f32s().unwrap();
            let b = orig[n].1.f32s().unwrap();
            assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
            n += 1;
        }
        assert_eq!(n, 2);
        // past the end marker it stays None
        assert!(dec.next_tensor().unwrap().is_none());
    }

    #[test]
    fn truncation_always_errors() {
        let bytes = encode_all(Codec::Int8 { block: 64 });
        for cut in 0..bytes.len() {
            let r = drain(&bytes[..cut]);
            assert!(r.is_err(), "prefix of {cut} bytes decoded cleanly");
        }
        assert!(drain(&bytes).is_ok());
    }

    #[test]
    fn bit_flips_always_error() {
        let bytes = encode_all(Codec::Int4 { block: 32 });
        // flip one bit at a spread of positions incl. magic, header, CRCs
        for ix in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[ix] ^= 1 << (ix % 8);
            assert!(drain(&bad).is_err(), "bit flip at byte {ix} decoded cleanly");
        }
    }

    fn drain(bytes: &[u8]) -> Result<usize> {
        let mut dec = Decoder::new(bytes)?;
        let mut n = 0;
        while let Some(_t) = dec.next_tensor()? {
            n += 1;
        }
        Ok(n)
    }

    #[test]
    fn encoder_enforces_declared_count() {
        let header = ContainerHeader { entry: "e".into(), seed: 1, step: 0.0, n_tensors: Some(2) };
        let mut enc = Encoder::new(Vec::new(), &header).unwrap();
        enc.write_tensor("only", &Tensor::ones(&[3]), Codec::Lossless).unwrap();
        let err = enc.finish().unwrap_err();
        assert!(format!("{err:#}").contains("1 of 2"), "{err:#}");
    }

    #[test]
    fn rejects_huge_claimed_lengths_cheaply() {
        // MCNC2 magic + varint claiming a ~1 EiB header
        let mut bytes = MAGIC_V2.to_vec();
        container::put_varint(&mut bytes, 1 << 60);
        assert!(Decoder::new(&bytes[..]).is_err());

        // valid header, then a frame claiming more than MAX_FRAME
        let header = ContainerHeader { entry: "e".into(), seed: 1, step: 0.0, n_tensors: None };
        let enc = Encoder::new(Vec::new(), &header).unwrap();
        let (mut bytes, _) = enc.finish().unwrap();
        bytes.pop(); // drop end marker
        container::put_varint(&mut bytes, (MAX_FRAME as u64) + 1);
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        assert!(dec.next_tensor().is_err());
    }
}
