//! Streaming `io::Read`/`io::Write` adapters over the MCNC2 container.
//!
//! The encoder writes `magic | header | frame* | end-marker` incrementally;
//! the decoder yields tensors one frame at a time, so a receiver (e.g. a
//! serving shard ingesting a cold adapter) never materializes the whole
//! payload. Frame bodies are CRC-verified *before* any payload parsing, and
//! length fields are bounded, so truncated or bit-flipped streams fail with
//! an error — never a panic, never a silently wrong tensor.
//!
//! Two decode strategies share one framing pass:
//!
//! * [`Decoder::next_tensor`] — serial, one frame per call (the reference
//!   path and the low-memory choice);
//! * [`Decoder::decode_all`] — splits the remaining stream into raw frames
//!   (cheap, I/O-bound), then fans the expensive work — CRC verification,
//!   rANS entropy decode, dequantization — across `util::threadpool`.
//!   Frames are independent by construction (each carries its own length,
//!   body and CRC), which is what makes the fan-out safe; results return in
//!   frame order and are bit-identical to the serial path at every thread
//!   count.
//!
//! Decode errors name the failing frame: its zero-based tensor index and
//! the byte offset of its length field in the stream, so an operator
//! staring at a corrupt multi-gigabyte artifact knows where to look.

use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

use super::container::{
    crc32, decode_frame_into_packed, decode_frame_into_packed_q, encode_frame, read_varint,
    ContainerHeader, PackedPanels, MAGIC_V2, MAX_FRAME, MAX_HEADER,
};
use super::{container, Codec};
use crate::mcnc::kernel::{Isa, PackedB, PackedBQ};
use crate::tensor::Tensor;
use crate::util::threadpool::{self, ThreadPool};

/// Streaming MCNC2 writer. Call [`Encoder::finish`] to terminate the
/// stream; a dropped encoder leaves it truncated (which decoders reject).
///
/// ```
/// use mcnc::codec::{Codec, ContainerHeader, Decoder, Encoder};
/// use mcnc::tensor::Tensor;
///
/// let header = ContainerHeader {
///     entry: "demo".into(),
///     seed: 7,
///     step: 0.0,
///     n_tensors: Some(1),
/// };
/// let mut enc = Encoder::new(Vec::new(), &header).unwrap();
/// enc.write_tensor("w", &Tensor::ones(&[4]), Codec::Lossless).unwrap();
/// let (bytes, wire) = enc.finish().unwrap();
/// assert_eq!(bytes.len(), wire);
///
/// let mut dec = Decoder::new(&bytes[..]).unwrap();
/// assert_eq!(dec.header().entry, "demo");
/// let (name, t, codec) = dec.next_tensor().unwrap().expect("one frame");
/// assert_eq!((name.as_str(), codec), ("w", Codec::Lossless));
/// assert_eq!(t.f32s().unwrap(), &[1.0; 4][..]);
/// assert!(dec.next_tensor().unwrap().is_none());
/// ```
pub struct Encoder<W: Write> {
    w: W,
    wire_bytes: usize,
    written: usize,
    declared: Option<usize>,
}

impl<W: Write> Encoder<W> {
    /// Write the magic + CRC-protected header to `w` and return the
    /// encoder ready for [`Encoder::write_tensor`] calls.
    pub fn new(mut w: W, header: &ContainerHeader) -> Result<Encoder<W>> {
        let hj = header.to_json();
        if hj.len() > MAX_HEADER {
            bail!("container header of {} bytes exceeds bound", hj.len());
        }
        let mut pre = Vec::new();
        pre.extend_from_slice(MAGIC_V2);
        container::put_varint(&mut pre, hj.len() as u64);
        pre.extend_from_slice(hj.as_bytes());
        pre.extend_from_slice(&crc32(hj.as_bytes()).to_le_bytes());
        w.write_all(&pre)?;
        Ok(Encoder { w, wire_bytes: pre.len(), written: 0, declared: header.n_tensors })
    }

    /// Encode and append one tensor frame; returns its wire size.
    pub fn write_tensor(&mut self, name: &str, t: &Tensor, codec: Codec) -> Result<usize> {
        let body = encode_frame(name, t, codec)?;
        if body.len() > MAX_FRAME {
            bail!("frame {name:?} of {} bytes exceeds bound", body.len());
        }
        let mut len = Vec::new();
        container::put_varint(&mut len, body.len() as u64);
        self.w.write_all(&len)?;
        self.w.write_all(&body)?;
        self.w.write_all(&crc32(&body).to_le_bytes())?;
        let frame = len.len() + body.len() + 4;
        self.wire_bytes += frame;
        self.written += 1;
        Ok(frame)
    }

    /// Total bytes written so far (header + frames).
    pub fn wire_bytes(&self) -> usize {
        self.wire_bytes
    }

    /// Write the end marker and flush; returns the writer and the total
    /// wire size. Fails at the producer — not at some remote decoder — if
    /// fewer/more frames were written than the header declared.
    pub fn finish(mut self) -> Result<(W, usize)> {
        if let Some(n) = self.declared {
            if self.written != n {
                bail!("container wrote {} of {n} declared tensors", self.written);
            }
        }
        self.w.write_all(&[0u8])?; // varint 0 = end of frames
        self.w.flush()?;
        self.wire_bytes += 1;
        Ok((self.w, self.wire_bytes))
    }
}

/// `Read` wrapper counting consumed bytes, so frame errors can report the
/// stream offset they happened at.
struct Counted<R> {
    inner: R,
    n: usize,
}

impl<R: Read> Read for Counted<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let k = self.inner.read(buf)?;
        self.n += k;
        Ok(k)
    }
}

/// One frame split out of the stream but not yet verified or parsed — the
/// unit of work the parallel decode path ships to pool workers.
struct RawFrame {
    /// Zero-based tensor index in the stream.
    index: usize,
    /// Byte offset of the frame's length field from the start of the
    /// container (magic byte 0).
    offset: usize,
    body: Vec<u8>,
    /// The stored CRC-32, still unchecked.
    crc: u32,
}

/// Check a raw frame's stored CRC against its body; the error names the
/// frame index + stream byte offset.
fn verify_crc(f: &RawFrame) -> Result<()> {
    let computed = crc32(&f.body);
    if computed != f.crc {
        bail!(
            "frame {} at byte offset {}: CRC mismatch (stored {:08x}, computed {computed:08x})",
            f.index,
            f.offset,
            f.crc
        );
    }
    Ok(())
}

/// Verify a raw frame's CRC and parse its body. Runs on pool workers for
/// the parallel path and inline for the serial one; all failure modes are
/// `Err` (never a panic) and name the frame index + stream byte offset.
fn check_and_decode(f: &RawFrame) -> Result<(String, Tensor, Codec)> {
    verify_crc(f)?;
    container::decode_frame(&f.body)
        .with_context(|| format!("frame {} at byte offset {}", f.index, f.offset))
}

/// Streaming MCNC2 reader: header up front, then tensors — one per
/// [`Decoder::next_tensor`] call, or all remaining frames decoded across
/// the thread pool by [`Decoder::decode_all`].
pub struct Decoder<R: Read> {
    r: Counted<R>,
    header: ContainerHeader,
    seen: usize,
    done: bool,
}

impl<R: Read> Decoder<R> {
    /// Read and check the magic, then the header.
    pub fn new(mut r: R) -> Result<Decoder<R>> {
        let mut magic = [0u8; 6];
        r.read_exact(&mut magic)
            .map_err(|_| anyhow!("stream too short for MCNC2 magic"))?;
        if &magic != MAGIC_V2 {
            bail!("not an MCNC2 stream");
        }
        Decoder::after_magic(r)
    }

    /// Continue past an already-consumed magic (the checkpoint loader
    /// sniffs the magic itself to dispatch between MCNC1 and MCNC2).
    pub fn after_magic(r: R) -> Result<Decoder<R>> {
        // offsets include the magic whoever consumed it, so errors report
        // positions an operator can seek to in the file
        let mut r = Counted { inner: r, n: MAGIC_V2.len() };
        let hlen = read_varint(&mut r)? as usize;
        if hlen > MAX_HEADER {
            bail!("container header length {hlen} unreasonable");
        }
        let hbuf = read_exactly(&mut r, hlen).map_err(|_| anyhow!("container header truncated"))?;
        let mut crc = [0u8; 4];
        r.read_exact(&mut crc).map_err(|_| anyhow!("container header CRC missing"))?;
        if crc32(&hbuf) != u32::from_le_bytes(crc) {
            bail!("container header CRC mismatch");
        }
        let header = ContainerHeader::parse(
            std::str::from_utf8(&hbuf).map_err(|_| anyhow!("container header not utf-8"))?,
        )?;
        Ok(Decoder { r, header, seen: 0, done: false })
    }

    /// The container header parsed by [`Decoder::new`]/[`Decoder::after_magic`].
    pub fn header(&self) -> &ContainerHeader {
        &self.header
    }

    /// Split the next frame out of the stream without verifying or parsing
    /// it; `None` past the end marker (where the header's declared tensor
    /// count, if any, is enforced).
    fn read_raw_frame(&mut self) -> Result<Option<RawFrame>> {
        if self.done {
            return Ok(None);
        }
        let offset = self.r.n;
        let len = read_varint(&mut self.r)
            .map_err(|_| anyhow!("stream truncated (no frame)"))? as usize;
        if len == 0 {
            if let Some(n) = self.header.n_tensors {
                if self.seen != n {
                    bail!("stream ended after {} of {n} tensors", self.seen);
                }
            }
            self.done = true;
            return Ok(None);
        }
        if len > MAX_FRAME {
            bail!("frame length {len} unreasonable");
        }
        let index = self.seen;
        let body = read_exactly(&mut self.r, len)
            .map_err(|_| anyhow!("frame {index} at byte offset {offset}: truncated"))?;
        let mut crc = [0u8; 4];
        self.r
            .read_exact(&mut crc)
            .map_err(|_| anyhow!("frame {index} at byte offset {offset}: CRC missing"))?;
        self.seen += 1;
        Ok(Some(RawFrame { index, offset, body, crc: u32::from_le_bytes(crc) }))
    }

    /// Decode the next frame, or `None` past the end marker. Errors are
    /// sticky only in the sense that callers should stop on the first one.
    pub fn next_tensor(&mut self) -> Result<Option<(String, Tensor, Codec)>> {
        match self.read_raw_frame()? {
            None => Ok(None),
            Some(f) => check_and_decode(&f).map(Some),
        }
    }

    /// Decode the next frame straight into the kernel layer's [`PackedB`]
    /// panel layout for `isa` — the fused decode→pack path for 2-D weight
    /// frames feeding the dispatched GEMMs (see
    /// [`container::decode_frame_into_packed`]).
    pub fn next_packed(&mut self, isa: Isa) -> Result<Option<(String, PackedB, Codec)>> {
        let Some(f) = self.read_raw_frame()? else {
            return Ok(None);
        };
        verify_crc(&f)?;
        decode_frame_into_packed(&f.body, isa)
            .with_context(|| format!("frame {} at byte offset {}", f.index, f.offset))
            .map(Some)
    }

    /// Decode the next frame straight into the kernel layer's [`PackedBQ`]
    /// quantized panels for `isa` — the compressed-domain path: rANS
    /// symbols land in i8 panel slots with the wire scales alongside, and
    /// no f32 weight is ever materialized (see
    /// [`container::decode_frame_into_packed_q`]). Errors — never panics —
    /// on lossless frames and on row-straddling scale blocks; callers that
    /// must handle every codec use [`Decoder::decode_all_panels_with`] or
    /// fall back to [`Decoder::next_packed`].
    pub fn next_packed_q(&mut self, isa: Isa) -> Result<Option<(String, PackedBQ, Codec)>> {
        let Some(f) = self.read_raw_frame()? else {
            return Ok(None);
        };
        verify_crc(&f)?;
        decode_frame_into_packed_q(&f.body, isa)
            .with_context(|| format!("frame {} at byte offset {}", f.index, f.offset))
            .map(Some)
    }

    /// Decode every remaining frame into panels across the pool, selecting
    /// the compressed-domain or f32 path per frame by codec tag + block
    /// layout (see [`container::decode_frame_into_panels`]); `force_f32`
    /// pins the f32 fallback everywhere — the oracle switch. Ordering,
    /// error and bit-identity guarantees match [`Decoder::decode_all_with`].
    pub fn decode_all_panels_with(
        &mut self,
        pool: &ThreadPool,
        isa: Isa,
        force_f32: bool,
    ) -> Result<Vec<(String, PackedPanels, Codec)>> {
        self.decode_all_panels_filtered_with(pool, isa, force_f32, |_| true)
    }

    /// [`Decoder::decode_all_panels_with`], decoding only frames whose
    /// *name* passes `keep` — the shard-sliced warm ingest, panel edition.
    /// Every frame is still CRC-verified (corruption anywhere stays an
    /// error); skipped frames pay neither entropy decode nor packing.
    pub fn decode_all_panels_filtered_with(
        &mut self,
        pool: &ThreadPool,
        isa: Isa,
        force_f32: bool,
        keep: impl Fn(&str) -> bool + Send + Sync + Clone + 'static,
    ) -> Result<Vec<(String, PackedPanels, Codec)>> {
        let results = self.decode_windowed(
            pool,
            move |f: &RawFrame| -> Result<Option<(String, PackedPanels, Codec)>> {
                verify_crc(f)?;
                let name = container::peek_frame_name(&f.body)
                    .with_context(|| format!("frame {} at byte offset {}", f.index, f.offset))?;
                if !keep(&name) {
                    return Ok(None);
                }
                container::decode_frame_into_panels(&f.body, isa, force_f32)
                    .with_context(|| format!("frame {} at byte offset {}", f.index, f.offset))
                    .map(Some)
            },
        )?;
        Ok(results.into_iter().flatten().collect())
    }

    /// Decode every remaining frame, fanning CRC verification + entropy
    /// decode + dequantization across the process-wide thread pool in
    /// bounded windows. Results are in frame order and bit-identical to
    /// draining [`Decoder::next_tensor`]; on corruption the error for the
    /// lowest-indexed bad frame of its window is returned (deterministic
    /// regardless of worker scheduling), and a worker detecting corruption
    /// yields an `Err` — never a panic.
    pub fn decode_all(&mut self) -> Result<Vec<(String, Tensor, Codec)>> {
        self.decode_all_with(threadpool::global())
    }

    /// [`Decoder::decode_all`] on an explicit pool — the thread-count
    /// override hook for determinism tests and the decode-throughput bench.
    pub fn decode_all_with(&mut self, pool: &ThreadPool) -> Result<Vec<(String, Tensor, Codec)>> {
        self.decode_windowed(pool, check_and_decode)
    }

    /// [`Decoder::decode_all_with`], decoding only frames whose *name*
    /// passes `keep`. Every frame — kept or not — is still CRC-verified
    /// (corruption anywhere stays an error), but skipped frames pay
    /// neither entropy decode nor dequantization. This is how a shard
    /// ingests a multi-task warm artifact without doing the whole fleet's
    /// decode work: with S shards each keeping its `task % S` slice, total
    /// decode cost stays ~1× the artifact instead of S×.
    pub fn decode_all_filtered_with(
        &mut self,
        pool: &ThreadPool,
        keep: impl Fn(&str) -> bool + Send + Sync + Clone + 'static,
    ) -> Result<Vec<(String, Tensor, Codec)>> {
        let results = self.decode_windowed(
            pool,
            move |f: &RawFrame| -> Result<Option<(String, Tensor, Codec)>> {
                verify_crc(f)?;
                let name = container::peek_frame_name(&f.body)
                    .with_context(|| format!("frame {} at byte offset {}", f.index, f.offset))?;
                if !keep(&name) {
                    return Ok(None);
                }
                container::decode_frame(&f.body)
                    .with_context(|| format!("frame {} at byte offset {}", f.index, f.offset))
                    .map(Some)
            },
        )?;
        Ok(results.into_iter().flatten().collect())
    }

    /// The shared windowed fan-out: split raw frames off the stream in
    /// bounded batches, run `job` on each across the pool, and return the
    /// results in frame order (first error by index wins — earlier windows
    /// complete before later ones are read, so the guarantee is global).
    fn decode_windowed<T: Send + 'static>(
        &mut self,
        pool: &ThreadPool,
        job: impl Fn(&RawFrame) -> Result<T> + Send + Sync + Clone + 'static,
    ) -> Result<Vec<T>> {
        let window = fanout_window(pool);
        let mut out = Vec::new();
        loop {
            let mut batch = Vec::with_capacity(window);
            while batch.len() < window {
                match self.read_raw_frame()? {
                    Some(f) => batch.push(f),
                    None => break,
                }
            }
            if batch.is_empty() {
                return Ok(out);
            }
            let n = batch.len();
            let job = job.clone();
            for r in pool.map(batch, move |f| job(&f)) {
                out.push(r?);
            }
            if n < window {
                return Ok(out);
            }
        }
    }

    /// How many frames have been split off the stream so far (serial reads
    /// and `decode_all*` both count) — lets a filtering consumer report
    /// how much it skipped.
    pub fn frames_seen(&self) -> usize {
        self.seen
    }
}

/// Raw-frame window per fan-out round: enough to keep every worker busy,
/// while bounding buffered-but-undecoded frame bytes to O(pool width)
/// instead of O(stream) — a multi-GB artifact must not be held in memory
/// twice (compressed + decoded) just to decode in parallel.
fn fanout_window(pool: &ThreadPool) -> usize {
    (pool.len() * 4).max(8)
}

/// Read exactly `n` bytes via a bounded incremental read, so a corrupt
/// length cannot drive a giant up-front allocation: the buffer only grows
/// as real bytes arrive.
fn read_exactly(r: &mut impl Read, n: usize) -> std::io::Result<Vec<u8>> {
    let mut buf = Vec::new();
    r.take(n as u64).read_to_end(&mut buf)?;
    if buf.len() != n {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("wanted {n} bytes, got {}", buf.len()),
        ));
    }
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream as Prng;

    fn sample_tensors() -> Vec<(String, Tensor)> {
        let mut s = Prng::new(21);
        vec![
            ("alpha".to_string(), Tensor::from_f32(s.normal_f32(486, 0.05), &[54, 9]).unwrap()),
            ("beta".to_string(), Tensor::ones(&[54])),
        ]
    }

    fn encode_all(codec: Codec) -> Vec<u8> {
        let header = ContainerHeader {
            entry: "mlp_mcnc02_train".into(),
            seed: 42,
            step: 10.0,
            n_tensors: Some(2),
        };
        let mut enc = Encoder::new(Vec::new(), &header).unwrap();
        for (name, t) in sample_tensors() {
            enc.write_tensor(&name, &t, codec).unwrap();
        }
        let (bytes, total) = enc.finish().unwrap();
        assert_eq!(bytes.len(), total);
        bytes
    }

    #[test]
    fn stream_roundtrip_per_tensor() {
        let bytes = encode_all(Codec::Lossless);
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        assert_eq!(dec.header().entry, "mlp_mcnc02_train");
        assert_eq!(dec.header().seed, 42);
        let orig = sample_tensors();
        let mut n = 0;
        while let Some((name, t, codec)) = dec.next_tensor().unwrap() {
            assert_eq!(name, orig[n].0);
            assert_eq!(codec, Codec::Lossless);
            let a = t.f32s().unwrap();
            let b = orig[n].1.f32s().unwrap();
            assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
            n += 1;
        }
        assert_eq!(n, 2);
        // past the end marker it stays None
        assert!(dec.next_tensor().unwrap().is_none());
    }

    #[test]
    fn decode_all_matches_serial_bitwise() {
        for codec in [Codec::Lossless, Codec::Int8 { block: 64 }, Codec::Int4 { block: 32 }] {
            let bytes = encode_all(codec);
            let mut serial = Vec::new();
            let mut dec = Decoder::new(&bytes[..]).unwrap();
            while let Some(f) = dec.next_tensor().unwrap() {
                serial.push(f);
            }
            for threads in [1usize, 3] {
                let pool = crate::util::threadpool::ThreadPool::new(threads);
                let mut dec = Decoder::new(&bytes[..]).unwrap();
                let par = dec.decode_all_with(&pool).unwrap();
                assert_eq!(par.len(), serial.len());
                for ((an, at, ac), (bn, bt, bc)) in par.iter().zip(&serial) {
                    assert_eq!((an, ac), (bn, bc));
                    assert_eq!(at.dims, bt.dims);
                    let (af, bf) = (at.f32s().unwrap(), bt.f32s().unwrap());
                    assert!(af.iter().zip(bf).all(|(x, y)| x.to_bits() == y.to_bits()));
                }
                // decode_all consumed the stream: nothing left to yield
                assert!(dec.next_tensor().unwrap().is_none());
            }
        }
    }

    #[test]
    fn decode_all_after_partial_serial_reads_the_rest() {
        let bytes = encode_all(Codec::Lossless);
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let (first, _, _) = dec.next_tensor().unwrap().unwrap();
        assert_eq!(first, "alpha");
        let rest = dec.decode_all().unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].0, "beta");
    }

    #[test]
    fn filtered_decode_skips_but_still_crc_checks() {
        let bytes = encode_all(Codec::Int8 { block: 64 });
        let pool = crate::util::threadpool::ThreadPool::new(2);

        // keep only "beta": one tensor out, both frames seen
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let out = dec.decode_all_filtered_with(&pool, |n| n == "beta").unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, "beta");
        assert_eq!(dec.frames_seen(), 2);

        // filtered result is bit-identical to the matching full-decode frame
        let all = Decoder::new(&bytes[..]).unwrap().decode_all_with(&pool).unwrap();
        let beta = all.iter().find(|(n, _, _)| n == "beta").unwrap();
        assert!(out[0]
            .1
            .f32s()
            .unwrap()
            .iter()
            .zip(beta.1.f32s().unwrap())
            .all(|(a, b)| a.to_bits() == b.to_bits()));

        // a bit flip inside the *skipped* frame's body must still error:
        // every frame is CRC-verified even when its decode is skipped
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let f0 = dec.read_raw_frame().unwrap().unwrap();
        assert_eq!(f0.index, 0, "alpha is frame 0 (the one we skip)");
        let mut bad = bytes.clone();
        bad[f0.offset + 2] ^= 0x08;
        let err = Decoder::new(&bad[..])
            .unwrap()
            .decode_all_filtered_with(&pool, |n| n == "beta")
            .unwrap_err();
        assert!(format!("{err:#}").contains("CRC mismatch"), "{err:#}");
    }

    #[test]
    fn next_packed_yields_panel_layout() {
        use crate::mcnc::kernel;
        let bytes = encode_all(Codec::Int8 { block: 64 });
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let (name, pb, codec) = dec.next_packed(kernel::Isa::Scalar).unwrap().unwrap();
        assert_eq!(name, "alpha");
        assert_eq!(codec, Codec::Int8 { block: 64 });
        assert_eq!((pb.k, pb.n), (54, 9));
        // the second frame is 1-D: the packed path must reject it cleanly
        assert!(dec.next_packed(kernel::Isa::Scalar).is_err());
    }

    #[test]
    fn next_packed_q_yields_quantized_panels() {
        use crate::mcnc::kernel;
        // alpha is [54, 9]: block 9 tiles whole rows → admissible
        let header = ContainerHeader { entry: "q".into(), seed: 1, step: 0.0, n_tensors: Some(1) };
        let mut enc = Encoder::new(Vec::new(), &header).unwrap();
        let t = sample_tensors().remove(0).1;
        enc.write_tensor("alpha", &t, Codec::Int8 { block: 9 }).unwrap();
        let (bytes, _) = enc.finish().unwrap();
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let (name, pq, codec) = dec.next_packed_q(kernel::Isa::Scalar).unwrap().unwrap();
        assert_eq!(name, "alpha");
        assert_eq!(codec, Codec::Int8 { block: 9 });
        assert_eq!((pq.k, pq.n), (54, 9));
        assert!(dec.next_packed_q(kernel::Isa::Scalar).unwrap().is_none());

        // block 64 straddles the 9-wide rows: the fused-q path must reject
        // it cleanly through the streaming wrapper too
        let bytes = encode_all(Codec::Int8 { block: 64 });
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let err = dec.next_packed_q(kernel::Isa::Scalar).unwrap_err();
        assert!(format!("{err:#}").contains("straddles"), "{err:#}");
    }

    #[test]
    fn decode_all_panels_selects_per_frame_and_respects_filter() {
        use crate::mcnc::kernel::Isa;
        let mut s = Prng::new(33);
        let header = ContainerHeader { entry: "p".into(), seed: 3, step: 0.0, n_tensors: Some(3) };
        let mut enc = Encoder::new(Vec::new(), &header).unwrap();
        let q = Tensor::from_f32(s.normal_f32(54 * 9, 0.05), &[54, 9]).unwrap();
        let l = Tensor::from_f32(s.normal_f32(4 * 6, 0.05), &[4, 6]).unwrap();
        enc.write_tensor("quant", &q, Codec::Int8 { block: 9 }).unwrap();
        enc.write_tensor("lossless", &l, Codec::Lossless).unwrap();
        enc.write_tensor("straddle", &l, Codec::Int8 { block: 5 }).unwrap();
        let (bytes, _) = enc.finish().unwrap();
        let pool = crate::util::threadpool::ThreadPool::new(2);

        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let out = dec.decode_all_panels_with(&pool, Isa::Scalar, false).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out[0].1.is_quant(), "row-aligned int8 frame takes the q path");
        assert!(!out[1].1.is_quant(), "lossless falls back to f32 panels");
        assert!(!out[2].1.is_quant(), "straddling block falls back to f32 panels");
        assert_eq!((out[0].1.k(), out[0].1.n()), (54, 9));

        // the oracle switch pins f32 everywhere
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let forced = dec.decode_all_panels_with(&pool, Isa::Scalar, true).unwrap();
        assert!(forced.iter().all(|(_, p, _)| !p.is_quant()));

        // filtered: only the kept frame decodes, all frames CRC-checked
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let kept = dec
            .decode_all_panels_filtered_with(&pool, Isa::Scalar, false, |n| n == "quant")
            .unwrap();
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].0, "quant");
        assert_eq!(dec.frames_seen(), 3);

        // a bit flip anywhere still errors on the panels path
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let f0 = dec.read_raw_frame().unwrap().unwrap();
        let mut bad = bytes.clone();
        bad[f0.offset + 3] ^= 0x10;
        let err = Decoder::new(&bad[..])
            .unwrap()
            .decode_all_panels_with(&pool, Isa::Scalar, false)
            .unwrap_err();
        assert!(format!("{err:#}").contains("frame 0"), "{err:#}");
    }

    #[test]
    fn crc_mismatch_names_frame_index_and_offset() {
        let bytes = encode_all(Codec::Lossless);
        // find the second frame: walk the framing exactly as the decoder
        // does, then flip a bit inside that frame's body
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        let f0 = dec.read_raw_frame().unwrap().unwrap();
        let f1 = dec.read_raw_frame().unwrap().unwrap();
        assert_eq!(f0.index, 0);
        assert_eq!(f1.index, 1);
        assert!(f1.offset > f0.offset);

        let mut bad = bytes.clone();
        bad[f1.offset + 2] ^= 0x40; // inside frame 1's body
        let mut dec = Decoder::new(&bad[..]).unwrap();
        assert!(dec.next_tensor().unwrap().is_some(), "frame 0 is untouched");
        let err = dec.next_tensor().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("frame 1"), "{msg}");
        assert!(msg.contains(&format!("byte offset {}", f1.offset)), "{msg}");
        assert!(msg.contains("CRC mismatch"), "{msg}");

        // the parallel path reports the same frame deterministically
        let mut dec = Decoder::new(&bad[..]).unwrap();
        let err = dec.decode_all().unwrap_err();
        assert!(format!("{err:#}").contains("frame 1"), "{err:#}");
    }

    #[test]
    fn truncation_always_errors() {
        let bytes = encode_all(Codec::Int8 { block: 64 });
        for cut in 0..bytes.len() {
            let r = drain(&bytes[..cut]);
            assert!(r.is_err(), "prefix of {cut} bytes decoded cleanly");
        }
        assert!(drain(&bytes).is_ok());
    }

    #[test]
    fn bit_flips_always_error() {
        let bytes = encode_all(Codec::Int4 { block: 32 });
        // flip one bit at a spread of positions incl. magic, header, CRCs
        for ix in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[ix] ^= 1 << (ix % 8);
            assert!(drain(&bad).is_err(), "bit flip at byte {ix} decoded cleanly");
        }
    }

    fn drain(bytes: &[u8]) -> Result<usize> {
        let mut dec = Decoder::new(bytes)?;
        let mut n = 0;
        while let Some(_t) = dec.next_tensor()? {
            n += 1;
        }
        Ok(n)
    }

    #[test]
    fn encoder_enforces_declared_count() {
        let header = ContainerHeader { entry: "e".into(), seed: 1, step: 0.0, n_tensors: Some(2) };
        let mut enc = Encoder::new(Vec::new(), &header).unwrap();
        enc.write_tensor("only", &Tensor::ones(&[3]), Codec::Lossless).unwrap();
        let err = enc.finish().unwrap_err();
        assert!(format!("{err:#}").contains("1 of 2"), "{err:#}");
    }

    #[test]
    fn rejects_huge_claimed_lengths_cheaply() {
        // MCNC2 magic + varint claiming a ~1 EiB header
        let mut bytes = MAGIC_V2.to_vec();
        container::put_varint(&mut bytes, 1 << 60);
        assert!(Decoder::new(&bytes[..]).is_err());

        // valid header, then a frame claiming more than MAX_FRAME
        let header = ContainerHeader { entry: "e".into(), seed: 1, step: 0.0, n_tensors: None };
        let enc = Encoder::new(Vec::new(), &header).unwrap();
        let (mut bytes, _) = enc.finish().unwrap();
        bytes.pop(); // drop end marker
        container::put_varint(&mut bytes, (MAX_FRAME as u64) + 1);
        let mut dec = Decoder::new(&bytes[..]).unwrap();
        assert!(dec.next_tensor().is_err());
    }
}
