//! Order-0 rANS entropy coder over small-alphabet byte symbols.
//!
//! Classic 32-bit range asymmetric numeral system with byte-wise
//! renormalization: the coder state lives in `[2^23, 2^31)`, symbol
//! frequencies are normalized to a 12-bit total and serialized sparsely as
//! `(symbol, freq)` pairs ahead of the byte stream, so an `encode` blob is
//! self-contained given the symbol count and alphabet size. The decoder
//! validates the table (sum, bounds, duplicates) before building its slot
//! lookup and fails — never panics — on truncated or inconsistent streams,
//! including a final-state check so a corrupt stream cannot silently decode
//! to plausible-looking symbols.
//!
//! This is the payload stage of the MCNC2 container: quantized weight
//! symbols (alphabet 2^bits) and lossless f32 byte planes (alphabet 256)
//! both go through it.

use anyhow::{anyhow, bail, Result};

use super::container::{get_varint, put_varint};

/// log2 of the normalized frequency total.
pub const SCALE_BITS: u32 = 12;
const M: u32 = 1 << SCALE_BITS;
/// Lower bound of the coder state interval `[L, 256·L)`.
const RANS_L: u32 = 1 << 23;

/// Scale raw counts so they sum to exactly `M`, keeping every present
/// symbol at frequency ≥ 1 (a present symbol must stay encodable).
fn normalize(counts: &[u64]) -> Vec<u32> {
    let total: u64 = counts.iter().sum();
    let mut freqs = vec![0u32; counts.len()];
    if total == 0 {
        return freqs;
    }
    let mut sum: i64 = 0;
    for (f, &c) in freqs.iter_mut().zip(counts) {
        if c > 0 {
            *f = ((c as u128 * M as u128 / total as u128) as u32).max(1);
            sum += *f as i64;
        }
    }
    // Fix rounding drift on the largest adjustable entries. The drift is
    // bounded by the alphabet size (≤ 256 < M), so when sum > M some entry
    // is ≥ 2 by pigeonhole and the loop always terminates.
    while sum != M as i64 {
        let step: i64 = if sum > M as i64 { -1 } else { 1 };
        let mut best = usize::MAX;
        for (s, &f) in freqs.iter().enumerate() {
            if f == 0 || (step < 0 && f <= 1) {
                continue;
            }
            if best == usize::MAX || f > freqs[best] {
                best = s;
            }
        }
        freqs[best] = (freqs[best] as i64 + step) as u32;
        sum += step;
    }
    freqs
}

/// Entropy-encode `symbols` (each `< alphabet`, alphabet ≤ 256) into a
/// self-contained blob: sparse frequency table, then the rANS byte stream
/// (initial decoder state first).
pub fn encode(symbols: &[u8], alphabet: usize) -> Vec<u8> {
    debug_assert!((1..=256).contains(&alphabet));
    let mut counts = vec![0u64; alphabet];
    for &s in symbols {
        counts[s as usize] += 1;
    }
    let freqs = normalize(&counts);

    let mut out = Vec::new();
    let present: Vec<usize> = (0..alphabet).filter(|&s| freqs[s] > 0).collect();
    put_varint(&mut out, present.len() as u64);
    for &s in &present {
        out.push(s as u8);
        put_varint(&mut out, freqs[s] as u64);
    }
    if symbols.is_empty() {
        return out;
    }

    let mut cums = vec![0u32; alphabet + 1];
    for s in 0..alphabet {
        cums[s + 1] = cums[s] + freqs[s];
    }

    // Encode in reverse so the decoder emits symbols forward; renorm bytes
    // land in emission order and the whole body is reversed at the end,
    // which also leaves the final state first (big-endian) for the decoder.
    let mut body: Vec<u8> = Vec::new();
    let mut x: u32 = RANS_L;
    for &s in symbols.iter().rev() {
        let f = freqs[s as usize];
        let x_max = ((RANS_L >> SCALE_BITS) << 8) * f;
        while x >= x_max {
            body.push((x & 0xff) as u8);
            x >>= 8;
        }
        x = (x / f) * M + (x % f) + cums[s as usize];
    }
    body.extend_from_slice(&x.to_le_bytes());
    body.reverse();
    out.extend_from_slice(&body);
    out
}

/// Decode exactly `n` symbols from an [`encode`] blob. Every failure mode
/// of a corrupt blob (bad table, truncation, trailing bytes, inconsistent
/// final state) is an `Err`, never a panic.
pub fn decode(blob: &[u8], n: usize, alphabet: usize) -> Result<Vec<u8>> {
    if !(1..=256).contains(&alphabet) {
        bail!("rans alphabet {alphabet} out of range");
    }
    let mut pos = 0usize;
    let n_present = get_varint(blob, &mut pos)? as usize;
    if n_present > alphabet {
        bail!("rans table has {n_present} entries for alphabet {alphabet}");
    }
    let mut freqs = vec![0u32; alphabet];
    let mut sum = 0u64;
    for _ in 0..n_present {
        let s = *blob.get(pos).ok_or_else(|| anyhow!("rans table truncated"))? as usize;
        pos += 1;
        let f = get_varint(blob, &mut pos)?;
        if s >= alphabet || freqs[s] != 0 || f == 0 || f > M as u64 {
            bail!("rans table entry (sym {s}, freq {f}) invalid");
        }
        freqs[s] = f as u32;
        sum += f;
    }
    if n == 0 {
        if pos != blob.len() {
            bail!("rans blob has {} trailing bytes", blob.len() - pos);
        }
        return Ok(Vec::new());
    }
    if sum != M as u64 {
        bail!("rans table sums to {sum}, want {M}");
    }

    let mut cums = vec![0u32; alphabet + 1];
    for s in 0..alphabet {
        cums[s + 1] = cums[s] + freqs[s];
    }
    let mut slot_sym = vec![0u8; M as usize];
    for s in 0..alphabet {
        for slot in cums[s]..cums[s + 1] {
            slot_sym[slot as usize] = s as u8;
        }
    }

    if blob.len() < pos + 4 {
        bail!("rans stream truncated (no state)");
    }
    let mut x = u32::from_be_bytes([blob[pos], blob[pos + 1], blob[pos + 2], blob[pos + 3]]);
    pos += 4;
    if x < RANS_L {
        bail!("rans initial state {x:#x} below interval");
    }

    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let slot = x & (M - 1);
        let s = slot_sym[slot as usize];
        out.push(s);
        x = freqs[s as usize] * (x >> SCALE_BITS) + slot - cums[s as usize];
        while x < RANS_L {
            let b = *blob.get(pos).ok_or_else(|| anyhow!("rans stream truncated"))?;
            pos += 1;
            x = (x << 8) | b as u32;
        }
    }
    if x != RANS_L {
        bail!("rans stream corrupt (final state {x:#x})");
    }
    if pos != blob.len() {
        bail!("rans blob has {} trailing bytes", blob.len() - pos);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    fn roundtrip(symbols: &[u8], alphabet: usize) {
        let blob = encode(symbols, alphabet);
        let back = decode(&blob, symbols.len(), alphabet).unwrap();
        assert_eq!(back, symbols, "alphabet {alphabet}, n {}", symbols.len());
    }

    #[test]
    fn roundtrip_empty_single_uniform() {
        roundtrip(&[], 256);
        roundtrip(&[7], 16);
        roundtrip(&[3; 1000], 16); // single-symbol alphabet: freq = M
        let mut s = Stream::new(5);
        let syms: Vec<u8> = (0..4096).map(|_| (s.next_u64() & 0xff) as u8).collect();
        roundtrip(&syms, 256);
    }

    #[test]
    fn roundtrip_skewed_and_compresses() {
        // Geometric-ish distribution over a 16-symbol alphabet.
        let mut s = Stream::new(9);
        let syms: Vec<u8> = (0..8192)
            .map(|_| {
                let a = (s.next_u64() & 0x0f) as u8;
                let b = (s.next_u64() & 0x0f) as u8;
                a.min(b)
            })
            .collect();
        let blob = encode(&syms, 16);
        let back = decode(&blob, syms.len(), 16).unwrap();
        assert_eq!(back, syms);
        // Entropy ≈ 3.2 bits/sym < 4, so the blob beats 4-bit packing.
        assert!(blob.len() < syms.len() / 2, "blob {} vs packed {}", blob.len(), syms.len() / 2);
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        let mut s = Stream::new(11);
        let syms: Vec<u8> = (0..500).map(|_| (s.next_u64() % 7) as u8).collect();
        let blob = encode(&syms, 8);
        // truncation at every prefix length
        for cut in 0..blob.len() {
            assert!(decode(&blob[..cut], syms.len(), 8).is_err(), "cut at {cut}");
        }
        // wrong symbol count
        assert!(decode(&blob, syms.len() + 1, 8).is_err());
        // trailing garbage
        let mut long = blob.clone();
        long.push(0xAA);
        assert!(decode(&long, syms.len(), 8).is_err());
    }

    #[test]
    fn bad_tables_rejected() {
        // table claiming more entries than the alphabet
        let mut blob = Vec::new();
        put_varint(&mut blob, 300);
        assert!(decode(&blob, 4, 256).is_err());
        // duplicate symbol entries
        let mut blob = Vec::new();
        put_varint(&mut blob, 2);
        blob.push(1);
        put_varint(&mut blob, 2048);
        blob.push(1);
        put_varint(&mut blob, 2048);
        assert!(decode(&blob, 4, 16).is_err());
        // sum != M
        let mut blob = Vec::new();
        put_varint(&mut blob, 1);
        blob.push(0);
        put_varint(&mut blob, 17);
        blob.extend_from_slice(&(RANS_L).to_be_bytes());
        assert!(decode(&blob, 4, 16).is_err());
    }

    #[test]
    fn normalize_sums_to_m() {
        let counts = vec![1u64, 0, 100, 3, 0, 999_999];
        let freqs = normalize(&counts);
        assert_eq!(freqs.iter().sum::<u32>(), M);
        for (f, c) in freqs.iter().zip(&counts) {
            assert_eq!(*f > 0, *c > 0);
        }
        assert!(normalize(&[0, 0, 0]).iter().all(|&f| f == 0));
    }
}
