//! `codec` — the MCNC2 compressed artifact container.
//!
//! The paper's premise is that *storing and transmitting* models is the
//! bottleneck, yet the original `.mcnc` checkpoint ships raw f32-LE with no
//! integrity checking: 4 bytes/param over the wire. This subsystem turns
//! the Table-8 "compress and ship" scenario into a real wire format:
//!
//! * [`quantizer`] — block-wise absmax int8/int4 quantization as a true
//!   encode/decode pair (same layout math as `baselines::quant`, which now
//!   delegates its fake-quant to this module);
//! * [`rans`] — an order-0 rANS entropy coder over the quantized symbols
//!   (and over f32 byte planes in lossless mode — the ZipNN observation
//!   that exponent bytes of trained weights are highly compressible);
//! * [`container`] — the `MCNC2` frame format: varint-framed per-tensor
//!   frames, each CRC32-protected, carrying a codec tag + shape + payload;
//! * [`stream`] — `io::Read`/`io::Write` encoder/decoder adapters so a
//!   receiver can decode tensor-by-tensor without materializing the whole
//!   payload.
//!
//! Codec choice is per tensor, so bit-exactness stays selectable per tensor
//! role: `Lossless` round-trips every f32 bit pattern exactly, while
//! `Int8`/`Int4` trade the absmax quantization error bound of
//! `baselines::quant::worst_rel_error` for a much smaller wire size.
//! Corrupt streams (truncations, bit flips) fail decoding with an error —
//! never a panic, never a silent mis-decode (CRC32 catches all single-bit
//! and burst-≤32 errors in frame bodies).
//!
//! Decoding parallelizes per frame: frames are self-delimiting and
//! independently CRC-protected, so [`Decoder::decode_all`] splits the
//! stream serially and fans entropy decode + dequantization across the
//! process-wide thread pool, bit-identically to the serial path. The
//! byte-level wire specification — with a hand-decodable worked example —
//! lives in `docs/FORMAT.md`.

#![warn(missing_docs)]

pub mod container;
pub mod quantizer;
pub mod rans;
pub mod stream;

use anyhow::{bail, Result};

pub use container::{ContainerHeader, PackedPanels, MAGIC_V2};
pub use stream::{Decoder, Encoder};

/// Per-tensor payload encoding inside an MCNC2 container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// f32 passthrough: byte-plane split + entropy coding, bit-exact.
    Lossless,
    /// Block-wise absmax 8-bit quantization + entropy-coded symbols.
    Int8 {
        /// Elements per absmax scaling group.
        block: usize,
    },
    /// Block-wise absmax 4-bit quantization + entropy-coded symbols.
    Int4 {
        /// Elements per absmax scaling group.
        block: usize,
    },
}

impl Codec {
    /// Parse a CLI/config spelling; `block` applies to the quantized modes.
    pub fn parse(s: &str, block: usize) -> Result<Codec> {
        match s {
            "lossless" | "f32" => Ok(Codec::Lossless),
            "int8" => Ok(Codec::Int8 { block }),
            "int4" => Ok(Codec::Int4 { block }),
            _ => bail!("unknown codec {s:?} (expected lossless|int8|int4)"),
        }
    }

    /// Canonical CLI/report spelling ([`Codec::parse`] accepts it back).
    pub fn name(&self) -> &'static str {
        match self {
            Codec::Lossless => "lossless",
            Codec::Int8 { .. } => "int8",
            Codec::Int4 { .. } => "int4",
        }
    }

    /// Whether decode(encode(t)) is bit-identical to `t`.
    pub fn is_lossless(&self) -> bool {
        matches!(self, Codec::Lossless)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        assert_eq!(Codec::parse("lossless", 64).unwrap(), Codec::Lossless);
        assert_eq!(Codec::parse("f32", 64).unwrap(), Codec::Lossless);
        assert_eq!(Codec::parse("int8", 32).unwrap(), Codec::Int8 { block: 32 });
        assert_eq!(Codec::parse("int4", 64).unwrap(), Codec::Int4 { block: 64 });
        assert!(Codec::parse("zstd", 64).is_err());
    }

    #[test]
    fn names_and_lossless_flag() {
        assert_eq!(Codec::Lossless.name(), "lossless");
        assert_eq!(Codec::Int8 { block: 64 }.name(), "int8");
        assert_eq!(Codec::Int4 { block: 64 }.name(), "int4");
        assert!(Codec::Lossless.is_lossless());
        assert!(!Codec::Int8 { block: 64 }.is_lossless());
    }
}
