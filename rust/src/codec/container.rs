//! MCNC2 container plumbing: varints, CRC-32, the container header, and
//! the per-tensor frame codec. Wire layout:
//!
//! ```text
//! magic "MCNC2\n"
//! varint hlen | header JSON | u32 crc32(header)
//! frames*:  varint body_len | body | u32 crc32(body)
//! end:      varint 0
//! ```
//!
//! A frame body is `varint name_len | name | varint ndims | dims… | codec
//! tag (u8) | payload`. Payloads:
//!
//! * lossless (tag 0): the four little-endian f32 byte planes, each as a
//!   symbol section — trained-weight exponent planes are highly skewed
//!   (the ZipNN observation), mantissa planes fall back to raw;
//! * int8/int4 (tag 1/2): `varint block | f32-LE scales | symbol section`
//!   over the biased quantized symbols.
//!
//! A symbol section is `flag (u8)` + either an rANS blob (`1`, when entropy
//! coding beats bit-packing) or bit-packed raw symbols (`0`), so a frame
//! never pays for entropy coding that does not win. Every structural field
//! a decoder allocates from is bounded, and the CRC is checked before any
//! payload parsing — corruption surfaces as an error, never a panic or a
//! silent mis-decode.

use anyhow::{anyhow, bail, Context, Result};
use std::io::Read;

use crate::mcnc::kernel::{
    quant_panels_admissible, Isa, PackedB, PackedBBuilder, PackedBQ, PackedBQBuilder,
};
use crate::tensor::Tensor;
use crate::util::json::{self, Json};

use super::{quantizer, rans, Codec};

/// Stream magic of the MCNC2 container (`docs/FORMAT.md` is the byte-level
/// specification of everything that follows it).
pub const MAGIC_V2: &[u8; 6] = b"MCNC2\n";
/// Header JSON length bound: a corrupt length must not drive a giant
/// allocation (also applied to legacy MCNC1 headers by `Checkpoint::load`).
pub const MAX_HEADER: usize = 1 << 20;
/// Per-tensor frame length bound.
pub const MAX_FRAME: usize = 1 << 30;
/// Decode-side cap on tensor elements (1 GiB of f32).
const MAX_ELEMS: usize = 1 << 28;
const MAX_DIMS: usize = 8;
const MAX_NAME: usize = 4096;
/// Container header version produced and accepted (`docs/FORMAT.md` §2).
pub const VERSION: u64 = 2;
/// Longest legal LEB128 varint: 10 bytes carry 70 payload bits, enough for
/// any u64 (`docs/FORMAT.md` §1.1).
pub const MAX_VARINT_BYTES: usize = 10;
/// Frame codec tag: lossless byte-plane payload (`docs/FORMAT.md` §3).
pub const TAG_LOSSLESS: u8 = 0;
/// Frame codec tag: block-absmax int8 payload.
pub const TAG_INT8: u8 = 1;
/// Frame codec tag: block-absmax int4 payload.
pub const TAG_INT4: u8 = 2;
/// Symbol width of the int8 codec (`docs/FORMAT.md` §4.2).
pub const INT8_BITS: u32 = 8;
/// Symbol width of the int4 codec.
pub const INT4_BITS: u32 = 4;

// ---------------------------------------------------------------------------
// varints + CRC-32
// ---------------------------------------------------------------------------

/// Append a LEB128 varint.
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Read a LEB128 varint from `buf` at `*pos`, advancing it.
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos).ok_or_else(|| anyhow!("varint truncated"))?;
        *pos += 1;
        if shift == 63 && (b & 0x7f) > 1 {
            bail!("varint overflows u64");
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 7 * MAX_VARINT_BYTES as u32 {
            bail!("varint too long");
        }
    }
}

/// Read a LEB128 varint from a reader (the streaming decode path).
pub fn read_varint(r: &mut impl Read) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let mut byte = [0u8; 1];
        r.read_exact(&mut byte).map_err(|_| anyhow!("varint truncated"))?;
        let b = byte[0];
        if shift == 63 && (b & 0x7f) > 1 {
            bail!("varint overflows u64");
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 7 * MAX_VARINT_BYTES as u32 {
            bail!("varint too long");
        }
    }
}

const fn crc32_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3, reflected) — the per-frame integrity check.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// Container header
// ---------------------------------------------------------------------------

/// Decoded MCNC2 container header. The seed is serialized as a decimal
/// *string*: JSON numbers are f64, which silently loses u64 precision for
/// seeds ≥ 2^53.
#[derive(Debug, Clone, PartialEq)]
pub struct ContainerHeader {
    /// Manifest entry the payload belongs to (e.g. `mlp_mcnc02_train`).
    pub entry: String,
    /// Base seed the receiver re-derives θ0 and the generator from.
    pub seed: u64,
    /// Training step the payload was snapshotted at.
    pub step: f32,
    /// Expected frame count, when the producer knows it up front. The
    /// decoder checks it at the end marker, so a corrupted frame-length
    /// field cannot silently truncate the stream (a flipped length byte
    /// can read as the end marker; the CRC-protected count catches it).
    pub n_tensors: Option<usize>,
}

impl ContainerHeader {
    /// Serialize to the wire's JSON spelling (seed as a decimal string).
    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("version", Json::num(VERSION as f64)),
            ("entry", Json::str(self.entry.clone())),
            ("seed", Json::str(self.seed.to_string())),
            ("step", Json::num(self.step as f64)),
        ];
        if let Some(n) = self.n_tensors {
            pairs.push(("n_tensors", Json::num(n as f64)));
        }
        json::to_string(&Json::obj(pairs))
    }

    /// Parse the wire JSON; rejects any version other than 2 and accepts
    /// both seed spellings (decimal string, legacy number).
    pub fn parse(text: &str) -> Result<ContainerHeader> {
        let j = json::parse(text).map_err(|e| anyhow!("container header: {e}"))?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != VERSION as usize {
            bail!("container header version {version}, want {VERSION}");
        }
        let seed = match j.get("seed") {
            Some(s) => seed_from_json(s)?,
            None => 0,
        };
        Ok(ContainerHeader {
            entry: j.get("entry").and_then(Json::as_str).unwrap_or("").to_string(),
            seed,
            step: j.get("step").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            n_tensors: j.get("n_tensors").and_then(Json::as_usize),
        })
    }
}

/// Seeds round-trip as decimal strings (u64-exact); legacy MCNC1 headers
/// hold JSON numbers. Accept both spellings on read.
pub fn seed_from_json(j: &Json) -> Result<u64> {
    match j {
        Json::Str(s) => s.parse::<u64>().map_err(|_| anyhow!("bad seed string {s:?}")),
        Json::Num(n) if *n >= 0.0 && n.is_finite() => Ok(*n as u64),
        _ => bail!("seed must be a decimal string or non-negative number"),
    }
}

// ---------------------------------------------------------------------------
// Symbol sections (shared by lossless planes and quantized payloads)
// ---------------------------------------------------------------------------

fn pack_bits(symbols: &[u8], bits: u32) -> Vec<u8> {
    if bits == 8 {
        return symbols.to_vec();
    }
    debug_assert_eq!(bits, 4);
    let mut out = vec![0u8; symbols.len().div_ceil(2)];
    for (i, &s) in symbols.iter().enumerate() {
        out[i / 2] |= (s & 0x0f) << ((i % 2) * 4);
    }
    out
}

fn unpack_bits(bytes: &[u8], n: usize, bits: u32) -> Vec<u8> {
    if bits == 8 {
        return bytes.to_vec();
    }
    debug_assert_eq!(bits, 4);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push((bytes[i / 2] >> ((i % 2) * 4)) & 0x0f);
    }
    out
}

/// Write one symbol section: rANS blob when it beats bit-packing, else
/// bit-packed raw (worst case costs 1 flag byte over raw).
fn put_symbols(out: &mut Vec<u8>, symbols: &[u8], bits: u32) {
    let blob = rans::encode(symbols, 1usize << bits);
    let packed_len = (symbols.len() * bits as usize).div_ceil(8);
    let mut framed = Vec::new();
    put_varint(&mut framed, blob.len() as u64);
    if framed.len() + blob.len() < packed_len {
        out.push(1);
        out.extend_from_slice(&framed);
        out.extend_from_slice(&blob);
    } else {
        out.push(0);
        out.extend_from_slice(&pack_bits(symbols, bits));
    }
}

/// Read one symbol section of exactly `n` symbols.
fn get_symbols(buf: &[u8], pos: &mut usize, n: usize, bits: u32) -> Result<Vec<u8>> {
    let flag = *buf.get(*pos).ok_or_else(|| anyhow!("symbol section truncated"))?;
    *pos += 1;
    match flag {
        1 => {
            let len = get_varint(buf, pos)? as usize;
            let end = pos
                .checked_add(len)
                .filter(|&e| e <= buf.len())
                .ok_or_else(|| anyhow!("rans section overruns frame"))?;
            let syms = rans::decode(&buf[*pos..end], n, 1usize << bits)?;
            *pos = end;
            Ok(syms)
        }
        0 => {
            let plen = (n * bits as usize).div_ceil(8);
            let end = pos
                .checked_add(plen)
                .filter(|&e| e <= buf.len())
                .ok_or_else(|| anyhow!("raw section overruns frame"))?;
            let syms = unpack_bits(&buf[*pos..end], n, bits);
            *pos = end;
            Ok(syms)
        }
        f => bail!("bad symbol-section flag {f}"),
    }
}

// ---------------------------------------------------------------------------
// Tensor frames
// ---------------------------------------------------------------------------

/// Serialize one tensor frame body. The stream layer wraps it in
/// `varint len | body | crc32(body)`.
pub fn encode_frame(name: &str, t: &Tensor, codec: Codec) -> Result<Vec<u8>> {
    let w = t
        .f32s()
        .map_err(|_| anyhow!("only f32 tensors are encoded (tensor {name:?})"))?;
    if name.len() > MAX_NAME {
        bail!("tensor name of {} bytes exceeds frame bound", name.len());
    }
    let mut b = Vec::new();
    put_varint(&mut b, name.len() as u64);
    b.extend_from_slice(name.as_bytes());
    put_varint(&mut b, t.dims.len() as u64);
    for &d in &t.dims {
        put_varint(&mut b, d as u64);
    }
    match codec {
        Codec::Lossless => {
            b.push(TAG_LOSSLESS);
            for plane in 0..4 {
                let bytes: Vec<u8> = w.iter().map(|v| v.to_le_bytes()[plane]).collect();
                put_symbols(&mut b, &bytes, 8);
            }
        }
        Codec::Int8 { block } | Codec::Int4 { block } => {
            let bits = if matches!(codec, Codec::Int8 { .. }) { INT8_BITS } else { INT4_BITS };
            b.push(if bits == INT8_BITS { TAG_INT8 } else { TAG_INT4 });
            let q = quantizer::quantize(w, bits, block);
            put_varint(&mut b, q.block as u64);
            for s in &q.scales {
                b.extend_from_slice(&s.to_le_bytes());
            }
            put_symbols(&mut b, &q.symbols, bits);
        }
    }
    Ok(b)
}

/// Parsed frame preamble: everything ahead of the payload bytes.
struct FrameMeta {
    name: String,
    dims: Vec<usize>,
    numel: usize,
    tag: u8,
}

/// Parse name, shape and codec tag, advancing `*pos` to the payload.
/// Structural bounds (name/dims/element counts) are enforced before any
/// allocation is sized from untrusted fields.
fn parse_frame_meta(b: &[u8], pos: &mut usize) -> Result<FrameMeta> {
    let name = parse_name(b, pos)?;

    let ndims = get_varint(b, pos)? as usize;
    if ndims > MAX_DIMS {
        bail!("frame has {ndims} dims");
    }
    let mut dims = Vec::with_capacity(ndims);
    let mut numel = 1usize;
    for _ in 0..ndims {
        let d = get_varint(b, pos)? as usize;
        numel = numel
            .checked_mul(d)
            .filter(|&n| n <= MAX_ELEMS)
            .ok_or_else(|| anyhow!("frame {name:?} element count overflows"))?;
        dims.push(d);
    }

    let tag = *b.get(*pos).ok_or_else(|| anyhow!("frame codec tag missing"))?;
    *pos += 1;
    Ok(FrameMeta { name, dims, numel, tag })
}

/// Parse the quantized payload fields shared by tag 1/2: block size, the
/// per-block scale array, and the biased symbol section.
fn parse_quantized_payload(
    b: &[u8],
    pos: &mut usize,
    name: &str,
    numel: usize,
    bits: u32,
) -> Result<(usize, Vec<f32>, Vec<u8>)> {
    let block = get_varint(b, pos)? as usize;
    if block == 0 {
        bail!("frame {name:?} has zero quantization block");
    }
    let n_scales = numel.div_ceil(block);
    let send = n_scales
        .checked_mul(4)
        .and_then(|sb| pos.checked_add(sb))
        .filter(|&e| e <= b.len())
        .ok_or_else(|| anyhow!("frame {name:?} scales overrun body"))?;
    let scales: Vec<f32> = b[*pos..send]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    *pos = send;
    let symbols = get_symbols(b, pos, numel, bits)?;
    Ok((block, scales, symbols))
}

/// Parse the name field at the head of a frame body, advancing `*pos` —
/// the one implementation behind both the full preamble parse and the
/// cheap name peek, so the two paths can never disagree on name framing.
fn parse_name(b: &[u8], pos: &mut usize) -> Result<String> {
    let nlen = get_varint(b, pos)? as usize;
    if nlen > MAX_NAME {
        bail!("frame name length {nlen} unreasonable");
    }
    let nend = pos
        .checked_add(nlen)
        .filter(|&e| e <= b.len())
        .ok_or_else(|| anyhow!("frame name overruns body"))?;
    let name = std::str::from_utf8(&b[*pos..nend])
        .map_err(|_| anyhow!("frame name is not utf-8"))?
        .to_string();
    *pos = nend;
    Ok(name)
}

/// Read just the tensor name off a frame body — the cheap peek the
/// filtered parallel decode uses to skip entropy-decoding frames a
/// consumer does not want (e.g. another shard's warm-start tasks). Call
/// only on CRC-verified bodies: the name bytes are trusted like any other
/// frame field.
pub fn peek_frame_name(b: &[u8]) -> Result<String> {
    parse_name(b, &mut 0)
}

/// Parse one CRC-verified frame body back into a named tensor.
pub fn decode_frame(b: &[u8]) -> Result<(String, Tensor, Codec)> {
    let mut pos = 0usize;
    let meta = parse_frame_meta(b, &mut pos)?;
    let FrameMeta { name, dims, numel, tag } = meta;
    let (w, codec) = match tag {
        TAG_LOSSLESS => {
            let mut planes = Vec::with_capacity(4);
            for _ in 0..4 {
                planes.push(get_symbols(b, &mut pos, numel, 8)?);
            }
            let mut w = Vec::with_capacity(numel);
            for i in 0..numel {
                w.push(f32::from_le_bytes([
                    planes[0][i],
                    planes[1][i],
                    planes[2][i],
                    planes[3][i],
                ]));
            }
            (w, Codec::Lossless)
        }
        TAG_INT8 | TAG_INT4 => {
            let bits = if tag == TAG_INT8 { INT8_BITS } else { INT4_BITS };
            let (block, scales, symbols) =
                parse_quantized_payload(b, &mut pos, &name, numel, bits)?;
            let q = quantizer::Quantized { bits, block, scales, symbols };
            let codec = if bits == INT8_BITS {
                Codec::Int8 { block }
            } else {
                Codec::Int4 { block }
            };
            (quantizer::dequantize(&q), codec)
        }
        t => bail!("unknown codec tag {t}"),
    };
    if pos != b.len() {
        bail!("frame {name:?} has {} trailing bytes", b.len() - pos);
    }
    Ok((name, Tensor::from_f32(w, &dims)?, codec))
}

/// Fused decode→pack: parse a CRC-verified 2-D `[k, n]` weight frame
/// straight into the kernel layer's [`PackedB`] panel layout for `isa`
/// (degrading to scalar if unavailable), so a warm-start or cold-fill
/// consumer that feeds the dispatched GEMMs skips the intermediate
/// row-major `Tensor` entirely. Dequantization is element-for-element the
/// [`quantizer::dequantize`] formula, so the packed values are bit-identical
/// to packing the output of [`decode_frame`].
///
/// Packed-A panels are deliberately *not* produced here: A is per-GEMM-call
/// scratch repacked from the activations of the moment, not a decodable
/// artifact.
pub fn decode_frame_into_packed(b: &[u8], isa: Isa) -> Result<(String, PackedB, Codec)> {
    let mut pos = 0usize;
    let meta = parse_frame_meta(b, &mut pos)?;
    let FrameMeta { name, dims, numel, tag } = meta;
    if dims.len() != 2 {
        bail!("frame {name:?} is {}-D; packed decode needs a 2-D [k, n] weight", dims.len());
    }
    // the panel buffer is k × ⌈n/NR⌉·NR floats — NR-padding can blow a
    // skinny-but-legal frame (huge k, n = 1) far past the MAX_ELEMS cap
    // the plain decode path enforces, so bound the *padded* size before
    // allocating (16 = the widest microtile NR across ISAs; see
    // mcnc::kernel — update if a wider kernel is ever added)
    const MAX_NR: usize = 16;
    let padded_cols = dims[1].div_ceil(MAX_NR).max(1).saturating_mul(MAX_NR);
    dims[0]
        .checked_mul(padded_cols)
        .filter(|&p| p <= MAX_ELEMS)
        .ok_or_else(|| anyhow!("frame {name:?} padded panel size exceeds bound"))?;
    let mut builder = PackedBBuilder::new_for(isa, dims[0], dims[1]);
    let codec = match tag {
        TAG_LOSSLESS => {
            let mut planes = Vec::with_capacity(4);
            for _ in 0..4 {
                planes.push(get_symbols(b, &mut pos, numel, 8)?);
            }
            for i in 0..numel {
                builder.push(f32::from_le_bytes([
                    planes[0][i],
                    planes[1][i],
                    planes[2][i],
                    planes[3][i],
                ]));
            }
            Codec::Lossless
        }
        TAG_INT8 | TAG_INT4 => {
            let bits = if tag == TAG_INT8 { INT8_BITS } else { INT4_BITS };
            let (block, scales, symbols) =
                parse_quantized_payload(b, &mut pos, &name, numel, bits)?;
            let bias = 1i32 << (bits - 1);
            for (ci, chunk) in symbols.chunks(block).enumerate() {
                let scale = scales.get(ci).copied().unwrap_or(0.0);
                for &s in chunk {
                    builder.push((s as i32 - bias) as f32 * scale);
                }
            }
            if bits == INT8_BITS {
                Codec::Int8 { block }
            } else {
                Codec::Int4 { block }
            }
        }
        t => bail!("unknown codec tag {t}"),
    };
    if pos != b.len() {
        bail!("frame {name:?} has {} trailing bytes", b.len() - pos);
    }
    Ok((name, builder.finish()?, codec))
}

/// Fused decode→pack for the *compressed domain*: parse a CRC-verified
/// 2-D quantized `[k, n]` weight frame straight into the kernel layer's
/// [`PackedBQ`] — rANS symbols into i8 panel slots, wire scales carried
/// alongside — with no f32 weight materialization at all. The panels are
/// bit-identical to [`crate::mcnc::kernel::pack_bq_for`] over the frame's
/// embedded `quantize(w)` symbols/scales (which the wire round-trips
/// exactly), so a consumer can cross-check the two construction paths.
///
/// Errors — never panics — on lossless frames and on quantized frames
/// whose scale blocks straddle weight rows (the `block % n == 0` /
/// single-block layout rule on [`PackedBQ`]); callers fall back to
/// [`decode_frame_into_packed`], which handles every codec.
pub fn decode_frame_into_packed_q(b: &[u8], isa: Isa) -> Result<(String, PackedBQ, Codec)> {
    let mut pos = 0usize;
    let meta = parse_frame_meta(b, &mut pos)?;
    let FrameMeta { name, dims, numel, tag } = meta;
    if dims.len() != 2 {
        bail!("frame {name:?} is {}-D; packed decode needs a 2-D [k, n] weight", dims.len());
    }
    let bits = match tag {
        TAG_INT8 => INT8_BITS,
        TAG_INT4 => INT4_BITS,
        TAG_LOSSLESS => {
            bail!("frame {name:?} is lossless; packed-q decode needs a quantized frame")
        }
        t => bail!("unknown codec tag {t}"),
    };
    // padded panel bound, mirroring decode_frame_into_packed: panels are 8
    // columns wide with k rounded up to the widest interleave (ku = 4).
    // The symbols are i8 (4× smaller than f32), but applying the same
    // element cap keeps the two fused paths' admission behavior identical.
    const MAX_KU: usize = 4;
    let padded_rows = dims[0].div_ceil(MAX_KU).saturating_mul(MAX_KU);
    let padded_cols = dims[1].div_ceil(8).max(1).saturating_mul(8);
    padded_rows
        .checked_mul(padded_cols)
        .filter(|&p| p <= MAX_ELEMS)
        .ok_or_else(|| anyhow!("frame {name:?} padded panel size exceeds bound"))?;
    let (block, scales, symbols) = parse_quantized_payload(b, &mut pos, &name, numel, bits)?;
    if pos != b.len() {
        bail!("frame {name:?} has {} trailing bytes", b.len() - pos);
    }
    let mut builder = PackedBQBuilder::new_for(isa, dims[0], dims[1], bits, block, scales)
        .with_context(|| format!("frame {name:?}"))?;
    for &s in &symbols {
        builder.push(s);
    }
    let codec = if bits == INT8_BITS { Codec::Int8 { block } } else { Codec::Int4 { block } };
    Ok((name, builder.finish()?, codec))
}

/// One decoded weight frame in whichever panel form the cold-fill path
/// chose for it: quantized panels when the frame's codec and block layout
/// admit the compressed-domain GEMM, f32 panels otherwise (lossless
/// frames, row-straddling blocks, or a forced-oracle override).
pub enum PackedPanels {
    /// f32 panels feeding the dispatched f32 GEMM — the oracle/fallback.
    F32(PackedB),
    /// Quantized panels feeding `mcnc::kernel::gemm_q` — no f32 weight
    /// was ever materialized on the way here.
    Quant(PackedBQ),
}

impl PackedPanels {
    /// Rows of the logical `[k, n]` weight.
    pub fn k(&self) -> usize {
        match self {
            PackedPanels::F32(p) => p.k,
            PackedPanels::Quant(p) => p.k,
        }
    }

    /// Columns of the logical `[k, n]` weight.
    pub fn n(&self) -> usize {
        match self {
            PackedPanels::F32(p) => p.n,
            PackedPanels::Quant(p) => p.n,
        }
    }

    /// Did this frame land on the compressed-domain path?
    pub fn is_quant(&self) -> bool {
        matches!(self, PackedPanels::Quant(_))
    }
}

/// Fused decode with per-frame path selection: quantized 2-D frames whose
/// scale blocks tile whole rows become [`PackedBQ`] via
/// [`decode_frame_into_packed_q`]; everything else (lossless frames,
/// row-straddling blocks) falls back to the f32
/// [`decode_frame_into_packed`]. `force_f32` pins the fallback for every
/// frame — the oracle switch serving uses to cross-check the two paths on
/// identical artifacts. The selection peeks only the frame preamble and
/// the block-size varint, so no payload work is duplicated.
pub fn decode_frame_into_panels(
    b: &[u8],
    isa: Isa,
    force_f32: bool,
) -> Result<(String, PackedPanels, Codec)> {
    if !force_f32 {
        let mut pos = 0usize;
        let meta = parse_frame_meta(b, &mut pos)?;
        if meta.dims.len() == 2 && (meta.tag == TAG_INT8 || meta.tag == TAG_INT4) {
            let block = get_varint(b, &mut pos)? as usize;
            if quant_panels_admissible(meta.dims[0], meta.dims[1], block) {
                let (name, pq, codec) = decode_frame_into_packed_q(b, isa)?;
                return Ok((name, PackedPanels::Quant(pq), codec));
            }
        }
    }
    let (name, pb, codec) = decode_frame_into_packed(b, isa)?;
    Ok((name, PackedPanels::F32(pb), codec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    #[test]
    fn varint_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
            let mut r: &[u8] = &buf;
            assert_eq!(read_varint(&mut r).unwrap(), v);
        }
        // truncated + overlong
        let mut pos = 0;
        assert!(get_varint(&[0x80], &mut pos).is_err());
        let mut pos = 0;
        assert!(get_varint(&[0xff; 11], &mut pos).is_err());
        let mut pos = 0;
        // 10th byte would shift a >1 payload past bit 63
        assert!(get_varint(&[0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x02], &mut pos)
            .is_err());
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 of "123456789" is the classic check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn header_seed_string_roundtrip() {
        let h = ContainerHeader {
            entry: "mlp_mcnc02_train".into(),
            seed: u64::MAX,
            step: 7.5,
            n_tensors: Some(3),
        };
        let j = h.to_json();
        assert!(j.contains("\"18446744073709551615\""), "{j}");
        let back = ContainerHeader::parse(&j).unwrap();
        assert_eq!(back, h);
        // numeric seeds still accepted
        let legacy = r#"{"version":2,"entry":"e","seed":42,"step":0}"#;
        assert_eq!(ContainerHeader::parse(legacy).unwrap().seed, 42);
        // wrong version rejected
        assert!(ContainerHeader::parse(r#"{"version":1,"entry":"e"}"#).is_err());
    }

    #[test]
    fn frame_roundtrip_lossless_and_quantized() {
        let vals = Stream::new(3).normal_f32(200, 0.05);
        let t = Tensor::from_f32(vals.clone(), &[20, 10]).unwrap();
        for codec in [Codec::Lossless, Codec::Int8 { block: 64 }, Codec::Int4 { block: 32 }] {
            let body = encode_frame("alpha", &t, codec).unwrap();
            let (name, back, c) = decode_frame(&body).unwrap();
            assert_eq!(name, "alpha");
            assert_eq!(c, codec);
            assert_eq!(back.dims, t.dims);
            let bf = back.f32s().unwrap();
            if codec.is_lossless() {
                for (a, b) in vals.iter().zip(bf) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            } else {
                let mut fq = vals.clone();
                let (bits, block) = match codec {
                    Codec::Int8 { block } => (8, block),
                    Codec::Int4 { block } => (4, block),
                    Codec::Lossless => unreachable!(),
                };
                crate::baselines::quant::fake_quant(&mut fq, bits, block);
                for (a, b) in fq.iter().zip(bf) {
                    assert!(a == b, "{a:e} vs {b:e}");
                }
            }
        }
    }

    #[test]
    fn packed_decode_matches_decode_then_pack() {
        use crate::mcnc::kernel;
        let vals = Stream::new(17).normal_f32(20 * 33, 0.05);
        let t = Tensor::from_f32(vals, &[20, 33]).unwrap();
        for isa in [Isa::Scalar, kernel::active()] {
            for codec in [Codec::Lossless, Codec::Int8 { block: 64 }, Codec::Int4 { block: 7 }] {
                let body = encode_frame("w", &t, codec).unwrap();
                let (name, pb, c) = decode_frame_into_packed(&body, isa).unwrap();
                assert_eq!(name, "w");
                assert_eq!(c, codec);
                let (_, back, _) = decode_frame(&body).unwrap();
                let want = kernel::pack_b_for(isa, back.f32s().unwrap(), 20, 33);
                assert_eq!(pb.isa(), want.isa(), "{isa:?} {codec:?}");
                assert_eq!(pb.panels(), want.panels(), "{isa:?} {codec:?}");
            }
        }
    }

    #[test]
    fn packed_decode_rejects_non_2d_and_corrupt() {
        let t1 = Tensor::ones(&[6]);
        let body = encode_frame("v", &t1, Codec::Lossless).unwrap();
        let err = decode_frame_into_packed(&body, Isa::Scalar).unwrap_err();
        assert!(format!("{err:#}").contains("2-D"), "{err:#}");

        let t2 = Tensor::ones(&[2, 3]);
        let mut body = encode_frame("m", &t2, Codec::Int8 { block: 4 }).unwrap();
        body.truncate(body.len() - 1);
        assert!(decode_frame_into_packed(&body, Isa::Scalar).is_err());
    }

    #[test]
    fn packed_q_decode_matches_quantize_then_pack() {
        // decode-to-PackedBQ must equal quantize(source) + pack_bq_for
        // bit-for-bit: a frame embeds exactly quantize(w) (ISA-invariant
        // by the quantizer parity tests) and the wire round-trips symbols
        // and scales exactly.
        use crate::mcnc::kernel;
        let (k, n) = (20usize, 33usize);
        let vals = Stream::new(19).normal_f32(k * n, 0.05);
        let t = Tensor::from_f32(vals.clone(), &[k, n]).unwrap();
        for isa in [Isa::Scalar, kernel::active()] {
            for codec in [
                Codec::Int8 { block: n },     // one row per group
                Codec::Int4 { block: 2 * n }, // two rows per group
                Codec::Int8 { block: k * n }, // single group
            ] {
                let (bits, block) = match codec {
                    Codec::Int8 { block } => (8u32, block),
                    Codec::Int4 { block } => (4, block),
                    Codec::Lossless => unreachable!(),
                };
                let body = encode_frame("w", &t, codec).unwrap();
                let (name, pq, c) = decode_frame_into_packed_q(&body, isa).unwrap();
                assert_eq!(name, "w");
                assert_eq!(c, codec);
                let q = quantizer::quantize_with(Isa::Scalar, &vals, bits, block);
                let want =
                    kernel::pack_bq_for(isa, k, n, bits, block, &q.scales, &q.symbols).unwrap();
                assert_eq!(pq.isa(), want.isa(), "{isa:?} {codec:?}");
                assert_eq!(pq.ku(), want.ku(), "{isa:?} {codec:?}");
                assert_eq!(pq.panels(), want.panels(), "{isa:?} {codec:?}");
                assert_eq!(pq.scales(), want.scales(), "{isa:?} {codec:?}");
                assert_eq!(pq.group_rows(), want.group_rows());
            }
        }
    }

    #[test]
    fn packed_q_decode_rejects_lossless_straddle_non_2d_and_corrupt() {
        let t = Tensor::ones(&[4, 6]);
        // lossless frames have no symbols to keep — callers fall back
        let body = encode_frame("w", &t, Codec::Lossless).unwrap();
        let err = decode_frame_into_packed_q(&body, Isa::Scalar).unwrap_err();
        assert!(format!("{err:#}").contains("lossless"), "{err:#}");
        // a block that straddles rows fails the layout admission rule
        let body = encode_frame("w", &t, Codec::Int8 { block: 4 }).unwrap();
        let err = decode_frame_into_packed_q(&body, Isa::Scalar).unwrap_err();
        assert!(format!("{err:#}").contains("straddles"), "{err:#}");
        // non-2-D rejected like the f32 fused path
        let body = encode_frame("v", &Tensor::ones(&[6]), Codec::Int8 { block: 6 }).unwrap();
        let err = decode_frame_into_packed_q(&body, Isa::Scalar).unwrap_err();
        assert!(format!("{err:#}").contains("2-D"), "{err:#}");
        // truncation errors (never panics) at every cut point
        let body = encode_frame("w", &t, Codec::Int8 { block: 6 }).unwrap();
        for cut in 0..body.len() {
            assert!(
                decode_frame_into_packed_q(&body[..cut], Isa::Scalar).is_err(),
                "cut at {cut} did not error"
            );
        }
    }

    #[test]
    fn panels_decode_selects_path_per_frame() {
        let t = Tensor::from_f32(Stream::new(5).normal_f32(48, 0.1), &[6, 8]).unwrap();
        // row-aligned quantized frame → compressed-domain panels
        let body = encode_frame("w", &t, Codec::Int8 { block: 8 }).unwrap();
        let (name, p, c) = decode_frame_into_panels(&body, Isa::Scalar, false).unwrap();
        assert_eq!((name.as_str(), c), ("w", Codec::Int8 { block: 8 }));
        assert!(p.is_quant());
        assert_eq!((p.k(), p.n()), (6, 8));
        // the forced-oracle switch pins the f32 fallback on the same frame
        let (_, p, _) = decode_frame_into_panels(&body, Isa::Scalar, true).unwrap();
        assert!(!p.is_quant());
        assert_eq!((p.k(), p.n()), (6, 8));
        // a row-straddling block falls back instead of erroring
        let body = encode_frame("w", &t, Codec::Int8 { block: 5 }).unwrap();
        let (_, p, _) = decode_frame_into_panels(&body, Isa::Scalar, false).unwrap();
        assert!(!p.is_quant());
        // lossless frames always take the f32 path
        let body = encode_frame("w", &t, Codec::Lossless).unwrap();
        let (_, p, c) = decode_frame_into_panels(&body, Isa::Scalar, false).unwrap();
        assert!(!p.is_quant());
        assert_eq!(c, Codec::Lossless);
        // non-2-D frames error on both paths, so selection errors too
        let body = encode_frame("v", &Tensor::ones(&[5]), Codec::Int8 { block: 5 }).unwrap();
        assert!(decode_frame_into_panels(&body, Isa::Scalar, false).is_err());
    }

    #[test]
    fn frame_handles_empty_and_scalar() {
        let empty = Tensor::from_f32(vec![], &[0, 4]).unwrap();
        let body = encode_frame("e", &empty, Codec::Lossless).unwrap();
        let (_, back, _) = decode_frame(&body).unwrap();
        assert_eq!(back.dims, vec![0, 4]);
        assert_eq!(back.numel(), 0);

        let scalar = Tensor::scalar_f32(-2.5);
        let body = encode_frame("s", &scalar, Codec::Int8 { block: 64 }).unwrap();
        let (_, back, _) = decode_frame(&body).unwrap();
        assert_eq!(back.numel(), 1);
        assert!((back.f32s().unwrap()[0] + 2.5).abs() < 0.02);
    }

    #[test]
    fn frame_rejects_i32_and_garbage() {
        let t = Tensor::from_i32(vec![1, 2], &[2]).unwrap();
        assert!(encode_frame("x", &t, Codec::Lossless).is_err());
        assert!(decode_frame(&[]).is_err());
        // huge claimed dims must not allocate
        let mut b = Vec::new();
        put_varint(&mut b, 1);
        b.push(b'x');
        put_varint(&mut b, 2);
        put_varint(&mut b, u32::MAX as u64);
        put_varint(&mut b, u32::MAX as u64);
        assert!(decode_frame(&b).is_err());
    }

    #[test]
    fn lossless_compresses_trained_like_weights() {
        // N(0, 0.05) weights: exponent byte plane is highly skewed.
        let vals = Stream::new(8).normal_f32(16384, 0.05);
        let t = Tensor::from_f32(vals, &[16384]).unwrap();
        let body = encode_frame("w", &t, Codec::Lossless).unwrap();
        assert!(
            body.len() < 16384 * 4,
            "lossless frame {} vs raw {}",
            body.len(),
            16384 * 4
        );
    }
}
