//! Block-wise absmax int-N quantization as a real encode/decode pair.
//!
//! The layout is the QLoRA-style scheme `baselines::quant` has always
//! simulated (per `block`-sized group: symmetric absmax scaling to
//! `bits`-wide signed integers) — but here the quantized symbols and
//! per-block scales are materialized so they can be entropy-coded and
//! shipped. `dequantize(quantize(w))` reproduces `baselines::quant::
//! fake_quant(w)` exactly; `fake_quant` now delegates here so the layout
//! math lives in one place.
//!
//! Symbols are stored biased to unsigned: `q ∈ [-2^(bits-1), 2^(bits-1)-1]`
//! maps to `q + 2^(bits-1) ∈ [0, 2^bits)`, a dense alphabet for the rANS
//! stage.
//!
//! The two hot scans — the per-block absmax reduction and the
//! divide/round/clamp encode loop — run on `mcnc::kernel`'s dispatched
//! SIMD microkernels. Every ISA is bit-identical to the scalar formula
//! (enforced by the kernel's parity tests), so a checkpoint encodes to the
//! same bytes on every host; [`quantize_with`] pins the ISA explicitly for
//! tests and benches.
//!
//! The symbols/scales pair is also exactly what the compressed-domain
//! GEMM consumes: `mcnc::kernel::pack_bq` lays the biased symbols out as
//! i8 panels and `gemm_q` multiplies against them directly, so a weight
//! whose scale blocks tile whole rows never needs [`dequantize`] on the
//! serving path at all (see `codec::container::decode_frame_into_packed_q`).

use crate::mcnc::kernel::{self, Isa};

/// A quantized f32 slice: per-block scales + biased symbols.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    /// Symbol width in bits (2..=8).
    pub bits: u32,
    /// Elements per absmax scaling group.
    pub block: usize,
    /// `numel.div_ceil(block)` scales; 0.0 marks an all-zero block.
    pub scales: Vec<f32>,
    /// One biased symbol per element, each `< 2^bits`.
    pub symbols: Vec<u8>,
}

impl Quantized {
    /// Alphabet size of the symbol stream.
    pub fn alphabet(&self) -> usize {
        1usize << self.bits
    }
}

/// Quantize `w` per `block`-sized group with symmetric absmax scaling.
/// `bits` must be in 2..=8. Scans run on the process-wide kernel ISA.
pub fn quantize(w: &[f32], bits: u32, block: usize) -> Quantized {
    quantize_with(kernel::active(), w, bits, block)
}

/// [`quantize`] with the kernel ISA pinned per call — the dispatch
/// override hook for parity tests and scalar-vs-SIMD benches. Results are
/// bit-identical across ISAs.
pub fn quantize_with(isa: Isa, w: &[f32], bits: u32, block: usize) -> Quantized {
    assert!((2..=8).contains(&bits));
    let block = block.max(1);
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let bias = 1i32 << (bits - 1);
    let mut scales = Vec::with_capacity(w.len().div_ceil(block));
    let mut symbols = Vec::with_capacity(w.len());
    for chunk in w.chunks(block) {
        let absmax = kernel::absmax_for(isa, chunk);
        if absmax == 0.0 {
            scales.push(0.0);
            for _ in chunk {
                symbols.push(bias as u8);
            }
            continue;
        }
        let scale = absmax / qmax;
        scales.push(scale);
        kernel::quantize_block_for(isa, chunk, scale, bits, &mut symbols);
    }
    Quantized { bits, block, scales, symbols }
}

/// Reconstruct the f32 values. Inverse of [`quantize`] up to the absmax
/// quantization error (`baselines::quant::worst_rel_error` bounds it).
pub fn dequantize(q: &Quantized) -> Vec<f32> {
    let bias = 1i32 << (q.bits - 1);
    let block = q.block.max(1);
    let mut out = Vec::with_capacity(q.symbols.len());
    for (ci, chunk) in q.symbols.chunks(block).enumerate() {
        let scale = q.scales.get(ci).copied().unwrap_or(0.0);
        for &s in chunk {
            out.push((s as i32 - bias) as f32 * scale);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    #[test]
    fn matches_fake_quant_exactly() {
        for (seed, bits, block) in [(1u64, 8u32, 64usize), (2, 4, 32), (3, 4, 7), (4, 8, 1)] {
            let w = Stream::new(seed).normal_f32(1000, 0.05);
            let mut fq = w.clone();
            crate::baselines::quant::fake_quant(&mut fq, bits, block);
            let deq = dequantize(&quantize(&w, bits, block));
            assert_eq!(deq.len(), w.len());
            for (i, (a, b)) in deq.iter().zip(&fq).enumerate() {
                assert!(a == b, "bits={bits} block={block} [{i}]: {a:e} vs {b:e}");
            }
        }
    }

    #[test]
    fn symbols_within_alphabet() {
        let w = Stream::new(7).normal_f32(513, 1.0);
        for bits in [2u32, 4, 8] {
            let q = quantize(&w, bits, 64);
            assert!(q.symbols.iter().all(|&s| (s as usize) < q.alphabet()));
            assert_eq!(q.scales.len(), w.len().div_ceil(64));
            assert_eq!(q.symbols.len(), w.len());
        }
    }

    #[test]
    fn zero_blocks_are_exact() {
        let mut w = vec![0.0f32; 100];
        w[70] = 0.5; // second block (of 64) non-zero
        let q = quantize(&w, 4, 64);
        assert_eq!(q.scales[0], 0.0);
        assert!(q.scales[1] > 0.0);
        let deq = dequantize(&q);
        assert!(deq[..64].iter().all(|&v| v == 0.0));
        assert!((deq[70] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn simd_and_scalar_quantize_identically() {
        // the wire format must not depend on the encoding host's ISA:
        // scales AND symbols bit-identical, across block sizes that leave
        // SIMD remainders and data with ties / NaN / inf / denormals.
        let mut w = Stream::new(21).normal_f32(2053, 0.05);
        w[0] = f32::NAN;
        w[100] = f32::INFINITY;
        w[200] = f32::NEG_INFINITY;
        w[300] = 1.0e-42;
        w[400] = 0.5 * w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for (bits, block) in [(8u32, 64usize), (4, 33), (2, 7), (8, 1), (4, 4096)] {
            let scalar = quantize_with(kernel::Isa::Scalar, &w, bits, block);
            let active = quantize_with(kernel::active(), &w, bits, block);
            assert_eq!(scalar, active, "bits={bits} block={block}");
            assert_eq!(quantize(&w, bits, block), scalar);
        }
    }

    #[test]
    fn error_bounded_per_block() {
        let w = Stream::new(12).normal_f32(4096, 0.3);
        for bits in [4u32, 8] {
            let deq = dequantize(&quantize(&w, bits, 64));
            let bound = crate::baselines::quant::worst_rel_error(bits) * 1.01;
            for (orig, back) in w.chunks(64).zip(deq.chunks(64)) {
                let absmax = orig.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                for (a, b) in orig.iter().zip(back) {
                    assert!((a - b).abs() <= absmax * bound, "{a} vs {b} (absmax {absmax})");
                }
            }
        }
    }
}
