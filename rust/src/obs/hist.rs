//! Log-bucketed duration histogram (HDR-style), promoted here from
//! `coordinator/metrics.rs` so every layer — coordinator, codec call
//! sites, kernels — can record durations into the same bucket layout.
//!
//! Buckets are geometric with ~4% relative resolution: bucket `i` covers
//! `(1µs·1.04^(i-1), 1µs·1.04^i]`, i.e. the *bound* of bucket `i` is
//! `1µs·1.04^i`, and bucket 0 holds everything at or below 1µs. Both
//! [`Histogram::record`] and [`Histogram::percentile`] use the same bound
//! semantics, so a reported percentile is always a conservative upper
//! bound on the true sample value (within one 4% bucket).
//!
//! Two flavours share the layout:
//!
//! * [`Histogram`] — plain, single-writer, mergeable across shards.
//! * [`AtomicHistogram`] — concurrent recorder for the obs registry;
//!   [`AtomicHistogram::snapshot`] yields a plain [`Histogram`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Geometric bucket growth factor (~4% relative resolution).
pub const GROWTH: f64 = 1.04;
/// Bucket count: 1.04^448 ≈ 4.3e7 µs ≈ 43 s full scale.
pub const N_BUCKETS: usize = 448;

/// Map a sample in microseconds to its bucket index. Bucket `i` covers
/// `(1.04^(i-1), 1.04^i]` µs with bucket 0 holding `us <= 1`; samples
/// beyond the last bound saturate into the final bucket.
fn bucket_index(us: f64) -> usize {
    if us <= 1.0 {
        0
    } else {
        let i = (us.ln() / GROWTH.ln()).ceil();
        (i as usize).min(N_BUCKETS - 1)
    }
}

/// Upper bound of bucket `i` in microseconds (`1.04^i`; bucket 0 → 1µs).
pub fn bucket_bound_us(i: usize) -> f64 {
    GROWTH.powi(i as i32)
}

/// Latency histogram with ~4% relative resolution, 1µs .. ~43s.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>, // geometric: bound_i = 1µs * 1.04^i
    count: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: vec![0; N_BUCKETS], count: 0, sum_us: 0.0, max_us: 0.0 }
    }
}

impl Histogram {
    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of the recorded samples, in microseconds.
    pub fn sum_us(&self) -> f64 {
        self.sum_us
    }

    /// Exact mean of the recorded samples.
    pub fn mean(&self) -> Duration {
        Duration::from_secs_f64(self.sum_us / self.count.max(1) as f64 / 1e6)
    }

    /// Largest recorded sample.
    pub fn max(&self) -> Duration {
        Duration::from_secs_f64(self.max_us / 1e6)
    }

    /// Percentile as the containing bucket's *upper* bound — a
    /// conservative estimate, never below the true sample value.
    pub fn percentile(&self, p: f64) -> Duration {
        self.pct(p, false)
    }

    /// Percentile as the containing bucket's *geometric midpoint*
    /// (`1.04^(i-1/2)`; arithmetic midpoint 0.5µs for bucket 0) — an
    /// unbiased-in-log estimator, always at or below [`Histogram::percentile`].
    pub fn percentile_mid(&self, p: f64) -> Duration {
        self.pct(p, true)
    }

    fn pct(&self, p: f64, midpoint: bool) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (p / 100.0 * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let us = if !midpoint {
                    bucket_bound_us(i)
                } else if i == 0 {
                    0.5
                } else {
                    GROWTH.powf(i as f64 - 0.5)
                };
                return Duration::from_secs_f64(us / 1e6);
            }
        }
        self.max()
    }

    /// Fold another histogram's buckets and counters into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Non-empty buckets as `(upper bound µs, count)` pairs, ascending —
    /// the exporter's view (Prometheus `_bucket` lines, JSON snapshots).
    pub fn nonzero_buckets(&self) -> Vec<(f64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_bound_us(i), c))
            .collect()
    }
}

/// Concurrent histogram for the obs registry: the same bucket layout as
/// [`Histogram`], recorded with relaxed atomics so many shard threads can
/// share one instance. Sums are kept in integer nanoseconds (exact for
/// any realistic serving window).
#[derive(Debug)]
pub struct AtomicHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl AtomicHistogram {
    /// Record one sample (relaxed atomics; safe from any thread).
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u128::from(u64::MAX)) as u64;
        let idx = bucket_index(ns as f64 / 1e3);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the current contents into a plain, mergeable [`Histogram`].
    /// Concurrent recorders may land between field reads; the drift is at
    /// most the handful of in-flight samples.
    pub fn snapshot(&self) -> Histogram {
        Histogram {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_ns.load(Ordering::Relaxed) as f64 / 1e3,
            max_us: self.max_ns.load(Ordering::Relaxed) as f64 / 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 < p99);
        // ~4% resolution
        assert!((p50.as_secs_f64() * 1e6 - 500.0).abs() < 40.0, "{p50:?}");
        assert!((p99.as_secs_f64() * 1e6 - 990.0).abs() < 80.0, "{p99:?}");
        assert!(h.mean().as_micros() > 400 && h.mean().as_micros() < 600);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::default();
        assert_eq!(h.percentile(99.0), Duration::ZERO);
        assert_eq!(h.percentile_mid(99.0), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(1000));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.max() >= Duration::from_micros(1000));
    }

    /// The (1µs, 1.04µs] regression: bound semantics put 1.0µs in bucket
    /// 0 (reported bound exactly 1µs) and anything above it in bucket 1+
    /// (reported bound > 1µs). The old floor-indexing collapsed both into
    /// bucket 0.
    #[test]
    fn bucket_bound_semantics_at_one_microsecond() {
        let mut at = Histogram::default();
        at.record(Duration::from_nanos(1000));
        assert_eq!(at.percentile(100.0), Duration::from_micros(1), "1µs stays in bucket 0");

        let mut above = Histogram::default();
        above.record(Duration::from_nanos(1020)); // 1.02µs ∈ (1, 1.04]
        let p = above.percentile(100.0).as_secs_f64() * 1e6;
        assert!(p > 1.0 && p <= 1.0401, "1.02µs maps to bucket 1 (bound 1.04µs), got {p}");
    }

    #[test]
    fn record_never_underestimates() {
        let mut h = Histogram::default();
        for us in [1u64, 2, 3, 7, 19, 100, 999, 12345] {
            let mut one = Histogram::default();
            one.record(Duration::from_micros(us));
            let bound = one.percentile(100.0).as_secs_f64() * 1e6;
            assert!(bound >= us as f64, "bound {bound} < sample {us}");
            assert!(bound <= us as f64 * GROWTH * GROWTH, "bound {bound} too loose for {us}");
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 8);
    }

    /// `percentile` (and the midpoint estimator) stay monotone in `p` on
    /// a histogram merged from several disjoint per-shard ranges, and the
    /// merged percentiles are bracketed by the per-shard extremes.
    #[test]
    fn percentile_monotone_across_merged_shards() {
        let mut shards = Vec::new();
        for s in 0..4u64 {
            let mut h = Histogram::default();
            for i in 0..250u64 {
                h.record(Duration::from_micros(1 + s * 250 + i));
            }
            shards.push(h);
        }
        let mut merged = Histogram::default();
        for h in &shards {
            merged.merge(h);
        }
        assert_eq!(merged.count(), 1000);
        let mut prev = Duration::ZERO;
        let mut prev_mid = Duration::ZERO;
        for p in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let v = merged.percentile(p);
            let m = merged.percentile_mid(p);
            assert!(v >= prev, "percentile({p}) regressed: {v:?} < {prev:?}");
            assert!(m >= prev_mid, "percentile_mid({p}) regressed");
            assert!(m <= v, "midpoint above bucket bound at p={p}");
            prev = v;
            prev_mid = m;
        }
        // Bracketed by the per-shard extremes.
        let lo = shards.iter().map(|h| h.percentile(50.0)).min().unwrap();
        let hi = shards.iter().map(|h| h.percentile(50.0)).max().unwrap();
        let p50 = merged.percentile(50.0);
        assert!(p50 >= lo && p50 <= hi, "merged p50 {p50:?} outside [{lo:?}, {hi:?}]");
    }

    #[test]
    fn atomic_matches_plain() {
        let at = AtomicHistogram::default();
        let mut plain = Histogram::default();
        for i in [1u64, 5, 42, 1000, 30_000] {
            at.record(Duration::from_micros(i));
            plain.record(Duration::from_micros(i));
        }
        let snap = at.snapshot();
        assert_eq!(snap.count(), plain.count());
        assert_eq!(snap.nonzero_buckets(), plain.nonzero_buckets());
        assert_eq!(snap.percentile(50.0), plain.percentile(50.0));
        assert!((snap.sum_us() - plain.sum_us()).abs() < 1e-6);
    }

    #[test]
    fn nonzero_buckets_cumulative_equals_count() {
        let mut h = Histogram::default();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i * 7));
        }
        let nz = h.nonzero_buckets();
        assert!(!nz.is_empty());
        assert!(nz.windows(2).all(|w| w[0].0 < w[1].0), "bounds ascending");
        assert_eq!(nz.iter().map(|&(_, c)| c).sum::<u64>(), h.count());
    }
}
