//! Exporters: Prometheus text exposition, JSON snapshots, and Chrome
//! trace-event JSON (loadable in Perfetto / `chrome://tracing`).
//!
//! All three are pure functions of a [`Snapshot`] or a span list — no IO,
//! no clocks — so callers (`mcnc serve --metrics-file/--trace-out`,
//! benches, tests) decide where the bytes go. Histograms export with
//! cumulative `_bucket{le=...}` lines over their non-empty buckets plus
//! `+Inf`, `_sum`, and `_count`, all in microseconds.

use std::fmt::Write as _;

use super::hist::Histogram;
use super::registry::{MetricId, Snapshot};
use super::trace::SpanRecord;
use crate::util::json::{to_string, Json};

/// Render a snapshot in Prometheus text exposition format (version 0.0.4):
/// `# TYPE` headers, then `name{labels} value` sample lines.
pub fn prometheus_text(s: &Snapshot) -> String {
    let mut out = String::new();
    let mut last_name = "";
    for (id, v) in &s.counters {
        type_header(&mut out, &mut last_name, id.name, "counter");
        let _ = writeln!(out, "{}{} {v}", id.name, label_block(id));
    }
    for (id, v) in &s.gauges {
        type_header(&mut out, &mut last_name, id.name, "gauge");
        let _ = writeln!(out, "{}{} {v}", id.name, label_block(id));
    }
    for (id, h) in &s.histograms {
        type_header(&mut out, &mut last_name, id.name, "histogram");
        let mut acc = 0u64;
        for (upper_us, count) in h.nonzero_buckets() {
            acc += count;
            let le = fmt_f64(upper_us);
            let _ = writeln!(out, "{}_bucket{} {acc}", id.name, label_block_with(id, "le", &le));
        }
        let _ =
            writeln!(out, "{}_bucket{} {}", id.name, label_block_with(id, "le", "+Inf"), h.count());
        let _ = writeln!(out, "{}_sum{} {}", id.name, label_block(id), fmt_f64(h.sum_us()));
        let _ = writeln!(out, "{}_count{} {}", id.name, label_block(id), h.count());
    }
    out
}

fn type_header(out: &mut String, last: &mut &str, name: &'static str, kind: &str) {
    if *last != name {
        let _ = writeln!(out, "# TYPE {name} {kind}");
        *last = name;
    }
}

fn label_block(id: &MetricId) -> String {
    if id.labels.is_empty() {
        return String::new();
    }
    let mut s = String::from("{");
    for (i, (k, v)) in id.labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{k}=\"{}\"", escape_label(v));
    }
    s.push('}');
    s
}

fn label_block_with(id: &MetricId, key: &str, value: &str) -> String {
    let mut s = String::from("{");
    for (k, v) in &id.labels {
        let _ = write!(s, "{k}=\"{}\",", escape_label(v));
    }
    let _ = write!(s, "{key}=\"{}\"", escape_label(value));
    s.push('}');
    s
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Shortest-ish float rendering: integers print bare, otherwise 4 decimal
/// places (Prometheus `le` bounds and `_sum` values).
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{v}")
    } else {
        format!("{v:.4}")
    }
}

/// Serialize a snapshot as JSON (`mcnc serve --metrics-file`, bench
/// sidecars). Counters and gauges carry `name`/`labels`/`value`;
/// histograms add count, sum, max, percentile estimates, and their
/// non-empty `[upper_us, count]` buckets.
pub fn snapshot_json(s: &Snapshot) -> Json {
    Json::obj(vec![
        (
            "counters",
            Json::Arr(
                s.counters.iter().map(|(id, v)| metric_obj(id, Json::Num(*v as f64))).collect(),
            ),
        ),
        (
            "gauges",
            Json::Arr(s.gauges.iter().map(|(id, v)| metric_obj(id, Json::Num(*v as f64))).collect()),
        ),
        (
            "histograms",
            Json::Arr(s.histograms.iter().map(|(id, h)| histogram_obj(id, h)).collect()),
        ),
    ])
}

fn labels_obj(id: &MetricId) -> Json {
    Json::Obj(id.labels.iter().map(|(k, v)| (k.to_string(), Json::str(v.as_str()))).collect())
}

fn metric_obj(id: &MetricId, value: Json) -> Json {
    Json::obj(vec![("name", Json::str(id.name)), ("labels", labels_obj(id)), ("value", value)])
}

fn histogram_obj(id: &MetricId, h: &Histogram) -> Json {
    Json::obj(vec![
        ("name", Json::str(id.name)),
        ("labels", labels_obj(id)),
        ("count", Json::Num(h.count() as f64)),
        ("sum_us", Json::Num(h.sum_us())),
        ("max_us", Json::Num(h.max().as_secs_f64() * 1e6)),
        ("p50_us", Json::Num(h.percentile(50.0).as_secs_f64() * 1e6)),
        ("p90_us", Json::Num(h.percentile(90.0).as_secs_f64() * 1e6)),
        ("p99_us", Json::Num(h.percentile(99.0).as_secs_f64() * 1e6)),
        (
            "buckets",
            Json::Arr(
                h.nonzero_buckets()
                    .into_iter()
                    .map(|(u, c)| Json::Arr(vec![Json::Num(u), Json::Num(c as f64)]))
                    .collect(),
            ),
        ),
    ])
}

/// Render trace records as Chrome trace-event JSON: one `pid` (the
/// server), one `tid` **track per shard** (named via `thread_name`
/// metadata), duration spans as `ph:"X"` complete events and structured
/// events as `ph:"i"` instants. Load the output in Perfetto
/// (<https://ui.perfetto.dev>) or `chrome://tracing`.
pub fn chrome_trace(records: &[SpanRecord]) -> String {
    let mut events = Vec::new();
    let mut shards: Vec<u32> = records.iter().map(|r| r.shard).collect();
    shards.sort_unstable();
    shards.dedup();
    for s in &shards {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(*s as f64)),
            ("args", Json::obj(vec![("name", Json::str(format!("shard {s}")))])),
        ]));
    }
    for r in records {
        let args = Json::obj(vec![
            ("trace_id", Json::Num(r.trace_id as f64)),
            ("task", Json::Num(r.task as f64)),
        ]);
        let mut ev = vec![
            ("name", Json::str(r.kind.name())),
            ("cat", Json::str("mcnc")),
            ("ph", Json::str(if r.kind.is_event() { "i" } else { "X" })),
            ("ts", Json::Num(r.start_us as f64)),
        ];
        if r.kind.is_event() {
            ev.push(("s", Json::str("t"))); // thread-scoped instant
        } else {
            ev.push(("dur", Json::Num(r.dur_us as f64)));
        }
        ev.push(("pid", Json::Num(1.0)));
        ev.push(("tid", Json::Num(r.shard as f64)));
        ev.push(("args", args));
        events.push(Json::obj(ev));
    }
    to_string(&Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;
    use crate::obs::trace::Kind;
    use crate::util::json;
    use std::time::Duration;

    fn sample_snapshot() -> Snapshot {
        let r = Registry::default();
        r.counter("test_hits_total", &[("shard", "0")]).add(3);
        r.counter("test_hits_total", &[("shard", "1")]).add(4);
        r.gauge("test_bytes", &[]).set(1024);
        let h = r.histogram("test_wait_us", &[("shard", "0")]);
        h.record(Duration::from_micros(1));
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(5000));
        r.snapshot()
    }

    #[test]
    fn prometheus_families_and_values() {
        let text = prometheus_text(&sample_snapshot());
        assert!(text.contains("# TYPE test_hits_total counter"));
        assert!(text.contains("test_hits_total{shard=\"0\"} 3"));
        assert!(text.contains("test_hits_total{shard=\"1\"} 4"));
        assert!(text.contains("# TYPE test_bytes gauge"));
        assert!(text.contains("test_bytes 1024"));
        assert!(text.contains("# TYPE test_wait_us histogram"));
        assert!(text.contains("test_wait_us_bucket{shard=\"0\",le=\"+Inf\"} 4"));
        assert!(text.contains("test_wait_us_count{shard=\"0\"} 4"));
        // One _bucket line per non-empty bucket + the +Inf line.
        let buckets = text.lines().filter(|l| l.starts_with("test_wait_us_bucket")).count();
        assert_eq!(buckets, 4);
        // Cumulative bucket values never decrease.
        let mut prev = 0u64;
        for l in text.lines().filter(|l| l.starts_with("test_wait_us_bucket")) {
            let v: u64 = l.rsplit(' ').next().and_then(|v| v.parse().ok()).expect("bucket value");
            assert!(v >= prev, "cumulative buckets must be monotone: {l}");
            prev = v;
        }
        // The TYPE header appears once per family, not once per label set.
        assert_eq!(text.matches("# TYPE test_hits_total").count(), 1);
    }

    #[test]
    fn snapshot_json_roundtrips() {
        let j = snapshot_json(&sample_snapshot());
        let parsed = json::parse(&to_string(&j)).expect("snapshot JSON parses");
        let counters = parsed.get("counters").and_then(Json::as_arr).expect("counters");
        assert_eq!(counters.len(), 2);
        let hists = parsed.get("histograms").and_then(Json::as_arr).expect("histograms");
        assert_eq!(hists.len(), 1);
        let h = &hists[0];
        assert_eq!(h.get("count").and_then(Json::as_f64), Some(4.0));
        let p50 = h.get("p50_us").and_then(Json::as_f64).expect("p50");
        let p99 = h.get("p99_us").and_then(Json::as_f64).expect("p99");
        assert!(p50 <= p99);
    }

    #[test]
    fn chrome_trace_roundtrips_with_tracks() {
        let t0 = 100u64;
        let recs = vec![
            SpanRecord { trace_id: 1, shard: 0, task: 2, kind: Kind::Queue, start_us: t0, dur_us: 40 },
            SpanRecord {
                trace_id: 1,
                shard: 0,
                task: 2,
                kind: Kind::Batch,
                start_us: t0 + 40,
                dur_us: 50,
            },
            SpanRecord { trace_id: 0, shard: 1, task: 0, kind: Kind::Restart, start_us: 90, dur_us: 0 },
        ];
        let parsed = json::parse(&chrome_trace(&recs)).expect("chrome trace parses");
        let events = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        // 2 thread_name metadata records (shards 0 and 1) + 3 records.
        assert_eq!(events.len(), 5);
        let metas = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .count();
        assert_eq!(metas, 2, "one thread_name track per shard");
        for e in events {
            match e.get("ph").and_then(Json::as_str) {
                Some("X") => {
                    assert!(e.get("dur").and_then(Json::as_f64).expect("dur") >= 0.0);
                    assert_eq!(e.get("cat").and_then(Json::as_str), Some("mcnc"));
                }
                Some("i") => assert_eq!(e.get("s").and_then(Json::as_str), Some("t")),
                Some("M") => {}
                ph => panic!("unexpected ph {ph:?}"),
            }
        }
    }

    #[test]
    fn label_escaping() {
        let r = Registry::default();
        r.counter("test_esc_total", &[("codec", "a\"b\\c")]).inc();
        let text = prometheus_text(&r.snapshot());
        assert!(text.contains("codec=\"a\\\"b\\\\c\""), "{text}");
    }
}
