//! Pre-bound metric handle bundles for the serving path.
//!
//! Registration takes the registry mutex, so hot loops bind their handles
//! once — a [`ShardObs`] per supervised shard incarnation, an
//! [`EngineObs`] per engine, a [`ServerObs`] per dispatcher — and then
//! every update is a relaxed atomic op. The bundles mirror (they do not
//! replace) the per-shard `ServeStats` counters: `ServeStats` remains the
//! exact per-`Server` accounting returned by `stop()`, while the registry
//! is the process-wide live view behind `Server::metrics_snapshot()`.
//!
//! Codec decode is timed *here*, from the coordinator-side caller, never
//! inside `codec/` — that keeps the codec wall-clock-free so mcnc-lint's
//! `determinism` rule holds (see ARCHITECTURE.md §Observability).

use std::io::Read;
use std::sync::Arc;
use std::time::Duration;

use super::hist::AtomicHistogram;
use super::registry::{registry, Counter, Gauge};

/// Task-affinity label classes: batches are labelled `task_mod` = task id
/// modulo this, keeping label cardinality bounded at any task count.
pub const TASK_MOD_CLASSES: usize = 8;

/// Per-shard serving metrics, bound once per supervised engine
/// incarnation and updated from the shard run loop.
#[derive(Debug, Clone)]
pub struct ShardObs {
    /// `mcnc_serve_queue_wait_us{shard}` — enqueue → batch formation.
    pub queue_wait_us: Arc<AtomicHistogram>,
    /// `mcnc_serve_latency_us{shard}` — enqueue → response (Ok only).
    pub latency_us: Arc<AtomicHistogram>,
    /// `mcnc_serve_batches_total{shard,task_mod}` — executed batches,
    /// indexed by `task % TASK_MOD_CLASSES`.
    pub batches: Vec<Arc<Counter>>,
    /// `mcnc_serve_batch_requests_total{shard}` — real (non-padding)
    /// requests dispatched into batches; with `batches` this yields the
    /// registry's batch-occupancy figure.
    pub batch_requests: Arc<Counter>,
    /// `mcnc_serve_deadline_shed_total{shard}`.
    pub deadline_shed: Arc<Counter>,
    /// `mcnc_serve_errors_total{shard}` — error responses sent.
    pub errors: Arc<Counter>,
    /// `mcnc_serve_batch_panics_total{shard}` — contained batch panics.
    pub batch_panics: Arc<Counter>,
    /// `mcnc_serve_breaker_opens_total{shard}`.
    pub breaker_opens: Arc<Counter>,
    /// `mcnc_serve_restarts_total{shard}` — supervisor engine restarts.
    pub restarts: Arc<Counter>,
}

impl ShardObs {
    /// Bind this shard's handles in the process-wide registry.
    pub fn register(shard: usize) -> ShardObs {
        let r = registry();
        let s = shard.to_string();
        let l: &[(&str, &str)] = &[("shard", &s)];
        ShardObs {
            queue_wait_us: r.histogram("mcnc_serve_queue_wait_us", l),
            latency_us: r.histogram("mcnc_serve_latency_us", l),
            batches: (0..TASK_MOD_CLASSES)
                .map(|m| {
                    let m = m.to_string();
                    r.counter("mcnc_serve_batches_total", &[("shard", &s), ("task_mod", &m)])
                })
                .collect(),
            batch_requests: r.counter("mcnc_serve_batch_requests_total", l),
            deadline_shed: r.counter("mcnc_serve_deadline_shed_total", l),
            errors: r.counter("mcnc_serve_errors_total", l),
            batch_panics: r.counter("mcnc_serve_batch_panics_total", l),
            breaker_opens: r.counter("mcnc_serve_breaker_opens_total", l),
            restarts: r.counter("mcnc_serve_restarts_total", l),
        }
    }

    /// The batch counter for `task`'s affinity class.
    pub fn batch_counter(&self, task: usize) -> &Counter {
        &self.batches[task % TASK_MOD_CLASSES]
    }
}

/// Per-engine cache / reconstruction / decode metrics (merged-θ serving).
#[derive(Debug, Clone)]
pub struct EngineObs {
    /// `mcnc_cache_hits_total{shard}` — merged-LRU hits.
    pub cache_hits: Arc<Counter>,
    /// `mcnc_cache_misses_total{shard}` — cold reconstructions paid.
    pub cache_misses: Arc<Counter>,
    /// `mcnc_cache_evictions_total{shard}`.
    pub cache_evictions: Arc<Counter>,
    /// `mcnc_cache_used_bytes{shard}` gauge.
    pub cache_used_bytes: Arc<Gauge>,
    /// `mcnc_cache_entries{shard}` gauge.
    pub cache_entries: Arc<Gauge>,
    /// `mcnc_serve_native_fills_total{shard}` — cold fills served by the
    /// native blocked-GEMM engine rather than PJRT.
    pub native_fills: Arc<Counter>,
    /// `mcnc_recon_flops_total{shard}` — analytic reconstruction FLOPs.
    pub recon_flops: Arc<Counter>,
    /// `mcnc_codec_decode_us{shard}` — caller-side decode wall time.
    pub decode_us: Arc<AtomicHistogram>,
    /// `mcnc_codec_decode_bytes_total{shard}` — wire bytes decoded; with
    /// `mcnc_codec_decode_us` this yields decode MB/s.
    pub decode_bytes: Arc<Counter>,
    /// `mcnc_codec_decode_frames_total{shard}` — frames decoded.
    pub decode_frames: Arc<Counter>,
}

impl EngineObs {
    /// Bind this shard-engine's handles in the process-wide registry.
    pub fn register(shard: usize) -> EngineObs {
        let r = registry();
        let s = shard.to_string();
        let l: &[(&str, &str)] = &[("shard", &s)];
        EngineObs {
            cache_hits: r.counter("mcnc_cache_hits_total", l),
            cache_misses: r.counter("mcnc_cache_misses_total", l),
            cache_evictions: r.counter("mcnc_cache_evictions_total", l),
            cache_used_bytes: r.gauge("mcnc_cache_used_bytes", l),
            cache_entries: r.gauge("mcnc_cache_entries", l),
            native_fills: r.counter("mcnc_serve_native_fills_total", l),
            recon_flops: r.counter("mcnc_recon_flops_total", l),
            decode_us: r.histogram("mcnc_codec_decode_us", l),
            decode_bytes: r.counter("mcnc_codec_decode_bytes_total", l),
            decode_frames: r.counter("mcnc_codec_decode_frames_total", l),
        }
    }

    /// Record one caller-timed decode: `bytes` off the wire, `frames`
    /// produced, `elapsed` wall time at the coordinator call site.
    pub fn record_decode(&self, bytes: u64, frames: u64, elapsed: Duration) {
        self.decode_us.record(elapsed);
        self.decode_bytes.add(bytes);
        self.decode_frames.add(frames);
    }
}

/// Dispatcher-side admission counters (no labels; one logical front end).
#[derive(Debug, Clone)]
pub struct ServerObs {
    /// `mcnc_serve_requests_total` — ids minted at submit.
    pub requests: Arc<Counter>,
    /// `mcnc_serve_rejected_total` — bounced at admission, queue full.
    pub rejected: Arc<Counter>,
    /// `mcnc_serve_retries_total` — admission retries after backpressure.
    pub retries: Arc<Counter>,
    /// `mcnc_serve_breaker_fastfail_total` — fast-failed by an open breaker.
    pub fastfail: Arc<Counter>,
}

impl ServerObs {
    /// Bind the dispatcher handles in the process-wide registry.
    pub fn register() -> ServerObs {
        let r = registry();
        ServerObs {
            requests: r.counter("mcnc_serve_requests_total", &[]),
            rejected: r.counter("mcnc_serve_rejected_total", &[]),
            retries: r.counter("mcnc_serve_retries_total", &[]),
            fastfail: r.counter("mcnc_serve_breaker_fastfail_total", &[]),
        }
    }
}

/// Socket front-end counters (no labels; one listener per process). Bound
/// once by `NetListener::run` and updated from the poll loop.
#[derive(Debug, Clone)]
pub struct NetObs {
    /// `mcnc_net_connections` gauge — currently open connections.
    pub connections: Arc<Gauge>,
    /// `mcnc_net_accepted_total` — connections accepted.
    pub accepted: Arc<Counter>,
    /// `mcnc_net_closed_total` — connections closed (any reason).
    pub closed: Arc<Counter>,
    /// `mcnc_net_bytes_read_total` — raw bytes off client sockets.
    pub bytes_read: Arc<Counter>,
    /// `mcnc_net_bytes_written_total` — raw bytes to client sockets.
    pub bytes_written: Arc<Counter>,
    /// `mcnc_net_frames_in_total` — complete frames decoded.
    pub frames_in: Arc<Counter>,
    /// `mcnc_net_frames_out_total` — reply/pong frames queued.
    pub frames_out: Arc<Counter>,
    /// `mcnc_net_requests_total` — requests submitted via the socket path.
    pub requests: Arc<Counter>,
    /// `mcnc_net_protocol_errors_total` — connections dropped for
    /// protocol violations (bad preamble, corrupt frame, bad message).
    pub protocol_errors: Arc<Counter>,
}

impl NetObs {
    /// Bind the socket front-end handles in the process-wide registry.
    pub fn register() -> NetObs {
        let r = registry();
        NetObs {
            connections: r.gauge("mcnc_net_connections", &[]),
            accepted: r.counter("mcnc_net_accepted_total", &[]),
            closed: r.counter("mcnc_net_closed_total", &[]),
            bytes_read: r.counter("mcnc_net_bytes_read_total", &[]),
            bytes_written: r.counter("mcnc_net_bytes_written_total", &[]),
            frames_in: r.counter("mcnc_net_frames_in_total", &[]),
            frames_out: r.counter("mcnc_net_frames_out_total", &[]),
            requests: r.counter("mcnc_net_requests_total", &[]),
            protocol_errors: r.counter("mcnc_net_protocol_errors_total", &[]),
        }
    }
}

/// Count frames decoded per codec: `mcnc_codec_frames_total{codec}`.
/// Registry lookup per call — use on cold decode paths only.
pub fn count_decoded_frame(codec_name: &str) {
    registry().counter("mcnc_codec_frames_total", &[("codec", codec_name)]).inc();
}

/// Byte-metering `Read` adapter so decode call sites can report wire
/// bytes without the codec layer counting for them.
#[derive(Debug)]
pub struct MeterRead<R> {
    inner: R,
    n: u64,
}

impl<R> MeterRead<R> {
    /// Wrap a reader.
    pub fn new(inner: R) -> MeterRead<R> {
        MeterRead { inner, n: 0 }
    }

    /// Bytes read through this wrapper so far.
    pub fn bytes(&self) -> u64 {
        self.n
    }
}

impl<R: Read> Read for MeterRead<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.n += n as u64;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_read_counts_bytes() {
        let data = vec![7u8; 1000];
        let mut m = MeterRead::new(&data[..]);
        let mut buf = [0u8; 64];
        let mut total = 0usize;
        loop {
            let n = m.read(&mut buf).expect("read");
            if n == 0 {
                break;
            }
            total += n;
        }
        assert_eq!(total, 1000);
        assert_eq!(m.bytes(), 1000);
    }

    #[test]
    fn bundles_bind_against_global_registry() {
        // Same (name, labels) → same underlying handle, so two bindings of
        // shard 63's bundle share counters.
        let a = ShardObs::register(63);
        let b = ShardObs::register(63);
        assert!(Arc::ptr_eq(&a.batch_requests, &b.batch_requests));
        assert!(Arc::ptr_eq(&a.batches[3], &b.batches[3]));
        assert!(std::ptr::eq(a.batch_counter(3), &*a.batches[3]));
        let e = EngineObs::register(63);
        e.record_decode(10, 2, Duration::from_micros(5));
        assert!(e.decode_us.count() >= 1);
    }
}
