//! Observability: metrics registry, request tracing, and exporters.
//!
//! Dependency-free (std only) and callable from **any** layer — the one
//! module exempt from the usual "lower layers never look up" rule, with
//! two constraints (see ARCHITECTURE.md §Observability):
//!
//! * obs never calls back into the layers it observes, and
//! * the codec is timed from coordinator-side call sites only, so
//!   `codec/` itself stays wall-clock-free (mcnc-lint `determinism`).
//!
//! The pieces:
//!
//! * [`registry`] — global named counters / gauges / histograms with
//!   label sets (`shard`, `task_mod`, `codec`, `isa`); lock-free updates
//!   after a mutex-guarded registration. [`hooks`] pre-binds the serving
//!   path's handles.
//! * [`hist`] — the log-bucketed [`Histogram`] (promoted from
//!   `coordinator/metrics.rs`) plus its concurrent [`AtomicHistogram`].
//! * [`trace`] — per-request spans and structured events in a lock-free
//!   ring, sampled via `MCNC_TRACE=off|sampled:N|all`; disabled hooks
//!   cost one relaxed atomic load.
//! * [`export`] — Prometheus text, JSON snapshots, and Chrome trace-event
//!   JSON (Perfetto-loadable), all pure functions of a [`Snapshot`] or a
//!   span list.
//!
//! Metric names are stable snake_case, enforced by mcnc-lint's
//! `metrics-naming` rule; docs/OBSERVABILITY.md is the catalog.

#![warn(missing_docs)]

pub mod export;
pub mod hist;
pub mod hooks;
pub mod registry;
pub mod trace;

pub use hist::{AtomicHistogram, Histogram};
pub use hooks::{count_decoded_frame, EngineObs, MeterRead, NetObs, ServerObs, ShardObs};
pub use registry::{registry, Counter, Gauge, IdGen, MetricId, Registry, Snapshot};
pub use trace::{Kind, SpanRecord, TraceMode};

/// Initialize observability from the environment: tracing mode from
/// `MCNC_TRACE` and the trace epoch. Call once near process start.
pub fn init_from_env() {
    trace::init_from_env();
}
