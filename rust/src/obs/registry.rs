//! Global metrics registry: named counters, gauges, and histograms with
//! small fixed label sets (`shard`, `task_mod`, `codec`, `isa`).
//!
//! Registration (cold path) takes a mutex; the handles it returns are
//! `Arc`s whose updates are lock-free — [`Counter`] is sharded across
//! cache-line-padded lanes keyed by thread, [`Gauge`] is one atomic, and
//! [`AtomicHistogram`] records with relaxed atomics. Registering the same
//! `(name, labels)` pair twice returns the *same* handle, so a metric is
//! registered once per process no matter how many shards bind it.
//!
//! Metric names are `snake_case` by convention and by lint: mcnc-lint's
//! `metrics-naming` rule checks every name literal passed to
//! [`Registry::counter`]/[`Registry::gauge`]/[`Registry::histogram`] and
//! bans bare `AtomicU64` counters in `coordinator/` (see docs/LINTS.md).

use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::hist::{AtomicHistogram, Histogram};

/// Number of counter lanes; power of two, sized for typical shard counts.
const LANES: usize = 8;

/// Monotonically assigned per-thread lane index (mod [`LANES`]).
static NEXT_LANE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static LANE: usize = NEXT_LANE.fetch_add(1, Ordering::Relaxed) & (LANES - 1);
}

#[repr(align(64))]
#[derive(Default, Debug)]
struct Lane(AtomicU64);

/// Lock-free monotonic counter, sharded across cache-line-padded lanes so
/// concurrent shard threads don't contend on one cache line.
#[derive(Default, Debug)]
pub struct Counter {
    lanes: [Lane; LANES],
}

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` (relaxed; this thread's lane).
    pub fn add(&self, n: u64) {
        LANE.with(|l| self.lanes[*l].0.fetch_add(n, Ordering::Relaxed));
    }

    /// Sum across lanes. Not a linearizable read — concurrent increments
    /// may or may not be included — but never undercounts the past.
    pub fn get(&self) -> u64 {
        self.lanes.iter().map(|l| l.0.load(Ordering::Relaxed)).sum()
    }
}

/// Last-write-wins signed gauge (e.g. cache bytes in use).
#[derive(Default, Debug)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Adjust the value by `d`.
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Unique-id mint (request/trace ids). Deliberately *not* a metric: it is
/// the one sanctioned home for a bare fetch-add word in the serving path,
/// so `coordinator/` itself never needs to declare an `AtomicU64`.
#[derive(Default, Debug)]
pub struct IdGen(AtomicU64);

impl IdGen {
    /// Return the next id, starting from 0.
    pub fn next(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
}

/// A metric's identity: stable `snake_case` name plus sorted label pairs.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricId {
    /// Stable snake_case metric name (e.g. `mcnc_serve_batches_total`).
    pub name: &'static str,
    /// Label pairs, sorted by key (e.g. `[("shard", "2")]`).
    pub labels: Vec<(&'static str, String)>,
}

impl MetricId {
    fn new(name: &'static str, labels: &[(&'static str, &str)]) -> MetricId {
        let mut labels: Vec<(&'static str, String)> =
            labels.iter().map(|&(k, v)| (k, v.to_string())).collect();
        labels.sort();
        MetricId { name, labels }
    }

    /// Value of label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| *k == key).map(|(_, v)| v.as_str())
    }
}

/// True iff `name` is non-empty `snake_case`: starts with a lowercase
/// letter, then lowercase letters, digits, and underscores only.
pub fn is_snake_case(name: &str) -> bool {
    let mut chars = name.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_lowercase())
        && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

#[derive(Default, Debug)]
struct Inner {
    counters: Vec<(MetricId, Arc<Counter>)>,
    gauges: Vec<(MetricId, Arc<Gauge>)>,
    histograms: Vec<(MetricId, Arc<AtomicHistogram>)>,
}

/// Metric registry. Use the process-wide [`registry()`] in serving code;
/// `Registry::default()` gives an isolated instance for unit tests.
#[derive(Default, Debug)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    fn locked(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A poisoned registry mutex only means a panicking thread held it
        // mid-registration; the Vec push is not left half-done.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Get-or-register the counter `(name, labels)`.
    pub fn counter(&self, name: &'static str, labels: &[(&'static str, &str)]) -> Arc<Counter> {
        debug_assert!(is_snake_case(name), "metric name `{name}` is not snake_case");
        let id = MetricId::new(name, labels);
        let mut g = self.locked();
        if let Some((_, c)) = g.counters.iter().find(|(i, _)| *i == id) {
            return c.clone();
        }
        debug_assert!(
            g.gauges.iter().all(|(i, _)| i.name != name)
                && g.histograms.iter().all(|(i, _)| i.name != name),
            "metric `{name}` already registered with a different type"
        );
        let c = Arc::new(Counter::default());
        g.counters.push((id, c.clone()));
        c
    }

    /// Get-or-register the gauge `(name, labels)`.
    pub fn gauge(&self, name: &'static str, labels: &[(&'static str, &str)]) -> Arc<Gauge> {
        debug_assert!(is_snake_case(name), "metric name `{name}` is not snake_case");
        let id = MetricId::new(name, labels);
        let mut g = self.locked();
        if let Some((_, c)) = g.gauges.iter().find(|(i, _)| *i == id) {
            return c.clone();
        }
        let c = Arc::new(Gauge::default());
        g.gauges.push((id, c.clone()));
        c
    }

    /// Get-or-register the histogram `(name, labels)`.
    pub fn histogram(
        &self,
        name: &'static str,
        labels: &[(&'static str, &str)],
    ) -> Arc<AtomicHistogram> {
        debug_assert!(is_snake_case(name), "metric name `{name}` is not snake_case");
        let id = MetricId::new(name, labels);
        let mut g = self.locked();
        if let Some((_, c)) = g.histograms.iter().find(|(i, _)| *i == id) {
            return c.clone();
        }
        let c = Arc::new(AtomicHistogram::default());
        g.histograms.push((id, c.clone()));
        c
    }

    /// Point-in-time copy of every registered metric, sorted by
    /// `(name, labels)` so exports are deterministic.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.locked();
        let mut s = Snapshot {
            counters: g.counters.iter().map(|(i, c)| (i.clone(), c.get())).collect(),
            gauges: g.gauges.iter().map(|(i, c)| (i.clone(), c.get())).collect(),
            histograms: g.histograms.iter().map(|(i, h)| (i.clone(), h.snapshot())).collect(),
        };
        s.counters.sort_by(|a, b| a.0.cmp(&b.0));
        s.gauges.sort_by(|a, b| a.0.cmp(&b.0));
        s.histograms.sort_by(|a, b| a.0.cmp(&b.0));
        s
    }
}

/// The process-wide registry. Shared by every `Server`, bench, and test
/// in the process, so assertions against it should be monotone (`>=`) or
/// structural rather than exact.
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::default)
}

/// Point-in-time registry contents (see [`Registry::snapshot`]).
#[derive(Default, Debug, Clone)]
pub struct Snapshot {
    /// Counter values by metric id.
    pub counters: Vec<(MetricId, u64)>,
    /// Gauge values by metric id.
    pub gauges: Vec<(MetricId, i64)>,
    /// Histogram copies by metric id.
    pub histograms: Vec<(MetricId, Histogram)>,
}

impl Snapshot {
    /// Sum of `name` across all label sets.
    pub fn counter_sum(&self, name: &str) -> u64 {
        self.counters.iter().filter(|(i, _)| i.name == name).map(|(_, v)| v).sum()
    }

    /// Sum of `name` across label sets where label `key` equals `value`.
    pub fn counter_with(&self, name: &str, key: &str, value: &str) -> u64 {
        self.counters
            .iter()
            .filter(|(i, _)| i.name == name && i.label(key) == Some(value))
            .map(|(_, v)| v)
            .sum()
    }

    /// Sum of gauge `name` across all label sets.
    pub fn gauge_sum(&self, name: &str) -> i64 {
        self.gauges.iter().filter(|(i, _)| i.name == name).map(|(_, v)| v).sum()
    }

    /// All label sets of histogram `name` merged into one [`Histogram`].
    pub fn histogram_merged(&self, name: &str) -> Histogram {
        let mut out = Histogram::default();
        for (_, h) in self.histograms.iter().filter(|(i, _)| i.name == name) {
            out.merge(h);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counter_registered_once_and_sums_lanes() {
        let r = Registry::default();
        let a = r.counter("test_hits_total", &[("shard", "0")]);
        let b = r.counter("test_hits_total", &[("shard", "0")]);
        assert!(Arc::ptr_eq(&a, &b), "same (name, labels) must share one handle");
        let other = r.counter("test_hits_total", &[("shard", "1")]);
        a.inc();
        b.add(2);
        other.add(10);
        let snap = r.snapshot();
        assert_eq!(snap.counter_sum("test_hits_total"), 13);
        assert_eq!(snap.counter_with("test_hits_total", "shard", "0"), 3);
        assert_eq!(snap.counter_with("test_hits_total", "shard", "1"), 10);
    }

    #[test]
    fn counter_is_thread_safe() {
        let r = Registry::default();
        let c = r.counter("test_threads_total", &[]);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().expect("counter thread");
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn gauge_and_histogram_roundtrip() {
        let r = Registry::default();
        let g = r.gauge("test_bytes", &[("shard", "0")]);
        g.set(100);
        g.add(-40);
        let h = r.histogram("test_wait_us", &[("shard", "0")]);
        h.record(Duration::from_micros(50));
        h.record(Duration::from_micros(500));
        let snap = r.snapshot();
        assert_eq!(snap.gauge_sum("test_bytes"), 60);
        let merged = snap.histogram_merged("test_wait_us");
        assert_eq!(merged.count(), 2);
        assert!(merged.percentile(100.0) >= Duration::from_micros(500));
    }

    #[test]
    fn snapshot_is_sorted() {
        let r = Registry::default();
        r.counter("test_zz_total", &[]).inc();
        r.counter("test_aa_total", &[("shard", "1")]).inc();
        r.counter("test_aa_total", &[("shard", "0")]).inc();
        let snap = r.snapshot();
        let order: Vec<_> =
            snap.counters.iter().map(|(i, _)| (i.name, i.labels.clone())).collect();
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(order, sorted);
    }

    #[test]
    fn snake_case_validator() {
        assert!(is_snake_case("mcnc_serve_batches_total"));
        assert!(is_snake_case("x1_y2"));
        assert!(!is_snake_case("Bad-Name"));
        assert!(!is_snake_case("camelCase"));
        assert!(!is_snake_case("1leading"));
        assert!(!is_snake_case(""));
    }

    #[test]
    fn id_gen_is_dense() {
        let g = IdGen::default();
        assert_eq!(g.next(), 0);
        assert_eq!(g.next(), 1);
        assert_eq!(g.next(), 2);
    }
}
