//! Span-based request tracing into a fixed-size lock-free ring buffer.
//!
//! Every request's id (minted by the dispatcher at `submit`/`submit_with`)
//! doubles as its **trace id**; the coordinator and engine record spans
//! against it as the request moves queue → batch → decode → GEMM. Spans
//! land in a global ring of seqlock-guarded slots: writers claim a ticket
//! with one fetch-add and publish the record with relaxed stores bracketed
//! by a version counter, so a reader ([`records`]) can detect and skip
//! torn slots without any lock.
//!
//! Sampling is controlled by `MCNC_TRACE`:
//!
//! * `off` (default) — every hook is a single relaxed atomic load.
//! * `sampled:N` — record spans for trace ids divisible by `N`.
//! * `all` — record everything (chaos runs, `mcnc serve --trace-out`).
//!
//! Structured WARN-worthy events (breaker open, shard restart, re-warm,
//! drain of a dead shard) go through [`event`], which both emits a WARN
//! log line and, when tracing is on, drops an instant record into the
//! ring so the event shows up on the shard's trace track.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::logging;

/// Ring capacity in records (power of two; ~3 MiB once allocated, and the
/// ring is only allocated on the first record).
pub const RING_CAP: usize = 1 << 16;

const MODE_OFF: u8 = 0;
const MODE_SAMPLED: u8 = 1;
const MODE_ALL: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_OFF);
static SAMPLE_N: AtomicU64 = AtomicU64::new(1);

/// Tracing mode (see module docs for the `MCNC_TRACE` forms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceMode {
    /// No recording; hooks cost one relaxed atomic load.
    Off,
    /// Record trace ids divisible by `N` (N clamped to ≥ 1).
    Sampled(u64),
    /// Record every span and event.
    All,
}

/// Install a tracing mode (tests, benches, `--trace-out`).
pub fn set_mode(m: TraceMode) {
    match m {
        TraceMode::Off => MODE.store(MODE_OFF, Ordering::Relaxed),
        TraceMode::Sampled(n) => {
            SAMPLE_N.store(n.max(1), Ordering::Relaxed);
            MODE.store(MODE_SAMPLED, Ordering::Relaxed);
        }
        TraceMode::All => MODE.store(MODE_ALL, Ordering::Relaxed),
    }
}

/// Current tracing mode.
pub fn mode() -> TraceMode {
    match MODE.load(Ordering::Relaxed) {
        MODE_ALL => TraceMode::All,
        MODE_SAMPLED => TraceMode::Sampled(SAMPLE_N.load(Ordering::Relaxed)),
        _ => TraceMode::Off,
    }
}

/// Parse `MCNC_TRACE` (`off` | `sampled:N` | `all`; default `off`) and pin
/// the trace epoch so span timestamps start near zero.
pub fn init_from_env() {
    epoch();
    let m = match std::env::var("MCNC_TRACE").as_deref() {
        Ok("all") => TraceMode::All,
        Ok(s) => match s.strip_prefix("sampled:").and_then(|n| n.parse::<u64>().ok()) {
            Some(n) => TraceMode::Sampled(n),
            None => TraceMode::Off,
        },
        Err(_) => TraceMode::Off,
    };
    set_mode(m);
}

/// True when any recording mode is active. This is the entire cost of a
/// disabled hook: one relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    MODE.load(Ordering::Relaxed) != MODE_OFF
}

/// Should spans for `trace_id` be recorded under the current mode?
#[inline]
pub fn sampled(trace_id: u64) -> bool {
    match MODE.load(Ordering::Relaxed) {
        MODE_OFF => false,
        MODE_ALL => true,
        _ => trace_id % SAMPLE_N.load(Ordering::Relaxed).max(1) == 0,
    }
}

/// Span and event kinds. The first group are duration spans; the rest are
/// instant events mirrored from WARN-level structured logs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    /// Request sat in the shard queue (enqueue → batch formation).
    Queue = 0,
    /// Batch execution on the shard's engine.
    Batch = 1,
    /// Codec decode, timed from the coordinator-side caller.
    Decode = 2,
    /// Kernel GEMM work for a merged-θ cold fill.
    Gemm = 3,
    /// Merged-LRU cold fill (reconstruction, either backend).
    Fill = 4,
    /// Circuit breaker transitioned closed → open.
    BreakerOpen = 5,
    /// Supervisor restarted a crashed shard engine.
    Restart = 6,
    /// Replacement engine re-warmed from the preload artifact.
    Rewarm = 7,
    /// Permanently dead shard began draining requests with errors.
    DrainDead = 8,
    /// Socket listener accepted a new client connection.
    Accept = 9,
    /// One read burst off a client socket (bytes → decoded frames).
    NetRead = 10,
    /// One write burst flushing queued reply frames to a client socket.
    NetWrite = 11,
}

impl Kind {
    /// Stable display name (trace-event `name` field).
    pub fn name(self) -> &'static str {
        match self {
            Kind::Queue => "queue",
            Kind::Batch => "batch",
            Kind::Decode => "decode",
            Kind::Gemm => "gemm",
            Kind::Fill => "fill",
            Kind::BreakerOpen => "breaker_open",
            Kind::Restart => "restart",
            Kind::Rewarm => "rewarm",
            Kind::DrainDead => "drain_dead",
            Kind::Accept => "accept",
            Kind::NetRead => "net_read",
            Kind::NetWrite => "net_write",
        }
    }

    /// Instant event (no duration) vs duration span.
    pub fn is_event(self) -> bool {
        matches!(self, Kind::BreakerOpen | Kind::Restart | Kind::Rewarm | Kind::DrainDead)
    }

    fn from_u8(v: u8) -> Option<Kind> {
        Some(match v {
            0 => Kind::Queue,
            1 => Kind::Batch,
            2 => Kind::Decode,
            3 => Kind::Gemm,
            4 => Kind::Fill,
            5 => Kind::BreakerOpen,
            6 => Kind::Restart,
            7 => Kind::Rewarm,
            8 => Kind::DrainDead,
            9 => Kind::Accept,
            10 => Kind::NetRead,
            11 => Kind::NetWrite,
            _ => return None,
        })
    }
}

/// One decoded ring record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// Request id the span belongs to (0 for shard-level events).
    pub trace_id: u64,
    /// Shard the work ran on (one Chrome-trace track per shard).
    pub shard: u32,
    /// Task id, when the span is batch-scoped.
    pub task: u32,
    /// Span or event kind.
    pub kind: Kind,
    /// Start, µs since the trace epoch.
    pub start_us: u64,
    /// Duration in µs (0 for instant events).
    pub dur_us: u64,
}

#[derive(Default)]
struct Slot {
    seq: AtomicU64, // 0 = never written; odd = write in progress
    trace_id: AtomicU64,
    meta: AtomicU64, // shard | task << 16 | kind << 48
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

struct Ring {
    slots: Vec<Slot>,
    head: AtomicU64,
}

static RING: OnceLock<Ring> = OnceLock::new();

fn ring() -> &'static Ring {
    RING.get_or_init(|| Ring {
        slots: (0..RING_CAP).map(|_| Slot::default()).collect(),
        head: AtomicU64::new(0),
    })
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-wide trace epoch (pinned on first use; [`init_from_env`]
/// pins it at startup so timestamps start near zero).
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds from the trace epoch to `t` (0 if `t` predates it).
pub fn us_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

fn push(trace_id: u64, shard: usize, task: usize, kind: Kind, start_us: u64, dur_us: u64) {
    let r = ring();
    let ticket = r.head.fetch_add(1, Ordering::Relaxed);
    let slot = &r.slots[ticket as usize & (RING_CAP - 1)];
    // Seqlock write: odd while in progress, even (and changed) once done.
    // Two writers race on one slot only after a full ring wrap-around;
    // the reader then sees an odd or changed seq and skips the slot.
    slot.seq.fetch_add(1, Ordering::AcqRel);
    slot.trace_id.store(trace_id, Ordering::Relaxed);
    let meta = shard as u64 & 0xFFFF | ((task as u64 & 0xFFFF_FFFF) << 16) | ((kind as u64) << 48);
    slot.meta.store(meta, Ordering::Relaxed);
    slot.start_us.store(start_us, Ordering::Relaxed);
    slot.dur_us.store(dur_us, Ordering::Relaxed);
    slot.seq.fetch_add(1, Ordering::Release);
}

/// Record a duration span for `trace_id` if tracing is on and the id is
/// sampled. Callers pass `Instant`s they already hold (the shard loop
/// reuses the timestamps it takes for `ServeStats`), so an unsampled hook
/// does no clock reads.
pub fn span(trace_id: u64, shard: usize, task: usize, kind: Kind, start: Instant, end: Instant) {
    if !sampled(trace_id) {
        return;
    }
    let s = us_since_epoch(start);
    let e = us_since_epoch(end);
    push(trace_id, shard, task, kind, s, e.saturating_sub(s));
}

/// Route a structured WARN event: always emits a WARN log line
/// (`[obs] shard N: <kind> <detail>`), and when tracing is on also drops
/// an instant record onto the shard's trace track.
pub fn event(shard: usize, kind: Kind, detail: &str) {
    logging::log(logging::WARN, "obs", format_args!("shard {shard}: {} {detail}", kind.name()));
    if !enabled() {
        return;
    }
    let now = us_since_epoch(Instant::now());
    push(0, shard, 0, kind, now, 0);
}

/// Decode every valid ring slot, sorted by start time (events last among
/// equal starts). Torn slots (a writer mid-publish or lapped by a ring
/// wrap) are skipped.
pub fn records() -> Vec<SpanRecord> {
    let Some(r) = RING.get() else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for slot in &r.slots {
        let s1 = slot.seq.load(Ordering::Acquire);
        if s1 == 0 || s1 & 1 == 1 {
            continue;
        }
        let trace_id = slot.trace_id.load(Ordering::Relaxed);
        let meta = slot.meta.load(Ordering::Relaxed);
        let start_us = slot.start_us.load(Ordering::Relaxed);
        let dur_us = slot.dur_us.load(Ordering::Relaxed);
        std::sync::atomic::fence(Ordering::Acquire);
        if slot.seq.load(Ordering::Relaxed) != s1 {
            continue; // torn: a writer got in between the reads
        }
        let Some(kind) = Kind::from_u8((meta >> 48) as u8) else {
            continue;
        };
        out.push(SpanRecord {
            trace_id,
            shard: (meta & 0xFFFF) as u32,
            task: ((meta >> 16) & 0xFFFF_FFFF) as u32,
            kind,
            start_us,
            dur_us,
        });
    }
    out.sort_by_key(|r| (r.start_us, u64::MAX - r.dur_us));
    out
}

/// Reset the ring (head and every slot). Only meaningful while no writer
/// is active — a test/bench helper for isolating one run's spans.
pub fn clear() {
    let Some(r) = RING.get() else {
        return;
    };
    r.head.store(0, Ordering::Relaxed);
    for slot in &r.slots {
        slot.seq.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Serialize ring-global tests (cargo runs tests on threads).
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static M: std::sync::Mutex<()> = std::sync::Mutex::new(());
        M.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn off_mode_records_nothing() {
        let _g = lock();
        set_mode(TraceMode::Off);
        clear();
        let t = Instant::now();
        span(1, 0, 0, Kind::Queue, t, t + Duration::from_micros(5));
        assert!(!enabled());
        assert!(records().is_empty());
    }

    // The ring and mode are process-global and other tests in this binary
    // run servers concurrently, so these tests mark their own records with
    // distinctive trace ids / shard numbers and filter instead of asserting
    // exact ring counts.

    #[test]
    fn spans_and_events_roundtrip() {
        let _g = lock();
        set_mode(TraceMode::All);
        clear();
        let id = 0xDEAD_0007u64;
        let t0 = epoch();
        span(id, 2, 3, Kind::Queue, t0, t0 + Duration::from_micros(40));
        span(id, 2, 3, Kind::Batch, t0 + Duration::from_micros(40), t0 + Duration::from_micros(90));
        event(911, Kind::Restart, "cause: test");
        let recs = records();
        set_mode(TraceMode::Off);
        let mine: Vec<_> = recs.iter().filter(|r| r.trace_id == id).collect();
        assert_eq!(mine.len(), 2);
        let q = mine.iter().find(|r| r.kind == Kind::Queue).expect("queue span");
        assert_eq!((q.shard, q.task, q.dur_us), (2, 3, 40));
        let e = recs.iter().find(|r| r.shard == 911).expect("restart event");
        assert!(e.kind.is_event());
        assert_eq!((e.kind, e.dur_us), (Kind::Restart, 0));
        // Sorted by start time.
        assert!(recs.windows(2).all(|w| w[0].start_us <= w[1].start_us));
    }

    #[test]
    fn sampling_keeps_multiples() {
        let _g = lock();
        set_mode(TraceMode::Sampled(4));
        clear();
        let base = 0x5A3F_0000u64; // divisible by 4
        let t = epoch();
        for id in base..base + 16 {
            span(id, 0, 0, Kind::Queue, t, t + Duration::from_micros(1));
        }
        let recs = records();
        set_mode(TraceMode::Off);
        let mine: Vec<_> = recs.iter().filter(|r| (base..base + 16).contains(&r.trace_id)).collect();
        assert_eq!(mine.len(), 4, "ids base+0,4,8,12");
        assert!(mine.iter().all(|r| r.trace_id % 4 == 0));
    }

    #[test]
    fn mode_parse_forms() {
        let _g = lock();
        set_mode(TraceMode::Sampled(0));
        assert_eq!(mode(), TraceMode::Sampled(1), "N clamps to >= 1");
        set_mode(TraceMode::All);
        assert_eq!(mode(), TraceMode::All);
        set_mode(TraceMode::Off);
        assert_eq!(mode(), TraceMode::Off);
    }

    #[test]
    fn ring_wrap_keeps_latest() {
        let _g = lock();
        set_mode(TraceMode::All);
        clear();
        let base = 0x5EED_0000u64;
        let t = epoch();
        let n = RING_CAP as u64 + 10;
        for id in base..base + n {
            span(id, 0, 0, Kind::Queue, t, t + Duration::from_micros(1));
        }
        let recs = records();
        set_mode(TraceMode::Off);
        // Concurrent writers from other tests can take tickets too; they
        // only ever displace the oldest records (plus a torn slot or two).
        let mine = recs.iter().filter(|r| (base..base + n).contains(&r.trace_id)).count() as u64;
        let foreign = recs.len() as u64 - mine;
        assert!(recs.len() >= RING_CAP - 8, "kept {} of {RING_CAP}", recs.len());
        assert!(mine >= n.saturating_sub(10 + foreign + 8), "mine {mine}, foreign {foreign}");
        // Lapping ~10 writes past capacity cannot evict the newest record.
        assert!(recs.iter().any(|r| r.trace_id == base + n - 1));
    }
}
