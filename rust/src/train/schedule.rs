//! Learning-rate schedules (the paper uses cosine for ViT, plateau-decay
//! for ResNets, constant for ablations).

#[derive(Debug, Clone)]
pub enum LrSchedule {
    Const(f32),
    /// Cosine decay from `base` to `base*floor_frac` over `total` steps.
    Cosine { base: f32, total: usize, floor_frac: f32 },
    /// Multiply by `factor` when the monitored loss hasn't improved for
    /// `patience` observations (the paper's ResNet recipe).
    Plateau { base: f32, factor: f32, patience: usize },
}

pub struct LrState {
    pub schedule: LrSchedule,
    cur: f32,
    best: f32,
    stale: usize,
}

impl LrState {
    pub fn new(schedule: LrSchedule) -> LrState {
        let cur = match &schedule {
            LrSchedule::Const(b) => *b,
            LrSchedule::Cosine { base, .. } => *base,
            LrSchedule::Plateau { base, .. } => *base,
        };
        LrState { schedule, cur, best: f32::MAX, stale: 0 }
    }

    /// lr for `step`, fed the latest monitored loss (for plateau).
    pub fn lr(&mut self, step: usize, monitored_loss: Option<f32>) -> f32 {
        match &self.schedule {
            LrSchedule::Const(b) => *b,
            LrSchedule::Cosine { base, total, floor_frac } => {
                let t = (step as f32 / (*total).max(1) as f32).min(1.0);
                let cosine = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                base * (floor_frac + (1.0 - floor_frac) * cosine)
            }
            LrSchedule::Plateau { factor, patience, .. } => {
                if let Some(loss) = monitored_loss {
                    if loss < self.best - 1e-6 {
                        self.best = loss;
                        self.stale = 0;
                    } else {
                        self.stale += 1;
                        if self.stale > *patience {
                            self.cur *= factor;
                            self.stale = 0;
                        }
                    }
                }
                self.cur
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_decays_to_floor() {
        let mut s = LrState::new(LrSchedule::Cosine { base: 1.0, total: 100, floor_frac: 0.1 });
        let first = s.lr(0, None);
        let mid = s.lr(50, None);
        let last = s.lr(100, None);
        assert!((first - 1.0).abs() < 1e-6);
        assert!(mid < first && mid > last);
        assert!((last - 0.1).abs() < 1e-6);
        assert!((s.lr(1000, None) - 0.1).abs() < 1e-6); // clamped past total
    }

    #[test]
    fn plateau_halves_on_stall() {
        let mut s = LrState::new(LrSchedule::Plateau { base: 0.01, factor: 0.5, patience: 2 });
        assert_eq!(s.lr(0, Some(1.0)), 0.01);
        assert_eq!(s.lr(1, Some(0.9)), 0.01); // improving
        for i in 2..5 {
            s.lr(i, Some(0.95)); // stalls
        }
        assert!((s.lr(5, Some(0.95)) - 0.005).abs() < 1e-9);
    }

    #[test]
    fn constant_is_constant() {
        let mut s = LrState::new(LrSchedule::Const(0.05));
        assert_eq!(s.lr(0, None), 0.05);
        assert_eq!(s.lr(999, Some(123.0)), 0.05);
    }
}
