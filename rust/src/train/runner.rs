//! The training driver: schedule, prefetching, periodic held-out eval,
//! metric logging. One `Trainer::run` call regenerates any accuracy cell of
//! Tables 1-3/5-7/9/13-16 given the right (executable, dataset, budget).

use std::sync::Arc;

use anyhow::Result;

use crate::data::{Dataset, Prefetcher, Split};
use crate::train::schedule::{LrSchedule, LrState};
use crate::train::state::TrainState;

#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub steps: usize,
    pub batch: usize,
    pub schedule: LrSchedule,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub log_every: usize,
    pub verbose: bool,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 200,
            batch: 128,
            schedule: LrSchedule::Const(0.05),
            eval_every: 0, // 0 = only at the end
            eval_batches: 4,
            log_every: 50,
            verbose: false,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct History {
    pub losses: Vec<f32>,
    pub evals: Vec<(usize, f32, f32)>, // (step, val_loss, val_acc)
}

impl History {
    pub fn final_val_acc(&self) -> f32 {
        self.evals.last().map(|e| e.2).unwrap_or(f32::NAN)
    }

    pub fn final_val_loss(&self) -> f32 {
        self.evals.last().map(|e| e.1).unwrap_or(f32::NAN)
    }

    pub fn csv(&self) -> String {
        let mut s = String::from("step,train_loss\n");
        for (i, l) in self.losses.iter().enumerate() {
            s += &format!("{i},{l}\n");
        }
        s += "step,val_loss,val_acc\n";
        for (st, l, a) in &self.evals {
            s += &format!("{st},{l},{a}\n");
        }
        s
    }
}

/// Evaluate over `n` held-out batches; returns (mean loss, mean acc).
pub fn evaluate(
    state: &TrainState,
    data: &dyn Dataset,
    batch: usize,
    n: usize,
) -> Result<(f32, f32)> {
    let mut loss = 0.0f32;
    let mut acc = 0.0f32;
    for i in 0..n {
        let (x, y) = data.batch(Split::Val, i as u64, batch);
        let out = state.eval(x, y)?;
        loss += out.loss;
        acc += out.acc;
    }
    Ok((loss / n as f32, acc / n as f32))
}

/// Train `state` on `data` per `cfg`; data generation overlaps the PJRT
/// step through the prefetcher.
pub fn run(
    state: &mut TrainState,
    data: Arc<dyn Dataset>,
    cfg: &TrainCfg,
) -> Result<History> {
    let mut hist = History::default();
    let mut lr = LrState::new(cfg.schedule.clone());
    let d = Arc::clone(&data);
    let batch = cfg.batch;
    let pf = Prefetcher::new(move |s| d.batch(Split::Train, s, batch), cfg.steps as u64, 2);

    let mut step = 0usize;
    for (x, y) in pf {
        let cur_lr = lr.lr(step, hist.losses.last().copied());
        let out = state.step(x, y, cur_lr)?;
        hist.losses.push(out.loss);
        if cfg.verbose && cfg.log_every > 0 && step % cfg.log_every == 0 {
            crate::info!(
                "train",
                "{} step {:4} loss {:.4} acc {:.3} lr {:.4}",
                state.entry.name, step, out.loss, out.acc, cur_lr
            );
        }
        step += 1;
        if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
            let (vl, va) = evaluate(state, data.as_ref(), cfg.batch, cfg.eval_batches)?;
            hist.evals.push((step, vl, va));
            if cfg.verbose {
                crate::info!("train", "  eval @{step}: loss {vl:.4} acc {va:.3}");
            }
        }
    }
    let (vl, va) = evaluate(state, data.as_ref(), cfg.batch, cfg.eval_batches)?;
    hist.evals.push((step, vl, va));
    Ok(hist)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthVision;
    use crate::runtime::{artifacts_dir, Session};

    #[test]
    fn trainer_improves_val_acc() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let sess = Session::open(&dir).unwrap();
        let mut st = TrainState::new(&sess, "mlp_mcnc02_train", 3).unwrap();
        let data: Arc<dyn Dataset> = Arc::new(SynthVision::new(7, 10, 28, 28, 1));
        let before = evaluate(&st, data.as_ref(), 128, 2).unwrap();
        let cfg = TrainCfg {
            steps: 40,
            batch: 128,
            schedule: LrSchedule::Const(0.05),
            eval_every: 20,
            eval_batches: 2,
            ..TrainCfg::default()
        };
        let hist = run(&mut st, data, &cfg).unwrap();
        assert_eq!(hist.losses.len(), 40);
        assert_eq!(hist.evals.len(), 3); // 2 periodic + final
        assert!(hist.final_val_acc() > before.1, "{} -> {}", before.1, hist.final_val_acc());
        assert!(hist.csv().contains("val_loss"));
    }
}
