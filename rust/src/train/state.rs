//! Generic training state over any manifest train-step executable.
//!
//! `TrainState` owns the positional input slots (statics, trainables, Adam
//! moments) exactly as the manifest orders them, seeds them through the
//! init laws, and advances by running the PJRT step. It knows nothing about
//! models or methods beyond the manifest — every (model × method × rate)
//! combination trains through this one struct.

use anyhow::{anyhow, bail, Result};

#[cfg(not(feature = "pjrt"))]
use crate::runtime::xla_stub as xla;

use crate::runtime::init::init_inputs;
use crate::runtime::manifest::{Entry, Role};
use crate::runtime::session::tensor_to_literal;
use crate::runtime::Session;
use crate::tensor::Tensor;

pub struct TrainState<'s> {
    pub session: &'s Session,
    pub entry: Entry,
    pub eval_entry: Option<Entry>,
    ns: usize,
    nt: usize,
    /// statics + trainables + m + v, manifest order.
    slots: Vec<Tensor>,
    /// statics pre-marshaled once (they never change between steps) — the
    /// §Perf fix that removed ~25% of per-step wall time on small models.
    static_lits: Vec<xla::Literal>,
    pub t: f32,
    pub seed: u64,
    emits_importance: bool,
}

#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    pub loss: f32,
    pub acc: f32,
}

impl<'s> TrainState<'s> {
    pub fn new(session: &'s Session, train_name: &str, seed: u64) -> Result<TrainState<'s>> {
        let entry = session.entry(train_name)?.clone();
        if entry.kind() != "train_step" {
            bail!("{train_name} is a {:?}, not a train_step", entry.kind());
        }
        let eval_name = train_name.replace("_train", "_eval");
        let eval_entry = session.entry(&eval_name).ok().cloned();
        let ns = entry.count_role(Role::Static);
        let nt = entry.count_role(Role::Trainable);
        let slots = init_inputs(&entry, seed)?
            .into_iter()
            .take(ns + 3 * nt)
            .map(|(spec, t)| t.ok_or_else(|| anyhow!("uninitialized slot {}", spec.name)))
            .collect::<Result<Vec<_>>>()?;
        let emits_importance = entry
            .outputs
            .last()
            .map(|o| o.name == "importance")
            .unwrap_or(false);
        let static_lits = slots[..ns]
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainState { session, entry, eval_entry, ns, nt, slots, static_lits, t: 0.0, seed, emits_importance })
    }

    pub fn n_trainables(&self) -> usize {
        self.nt
    }

    /// One optimizer step; returns (loss, acc) for the pre-update params.
    pub fn step(&mut self, x: Tensor, y: Tensor, lr: f32) -> Result<StepOut> {
        let (out, step) = self.step_full(x, y, lr)?;
        drop(out);
        Ok(step)
    }

    /// Step + raw extra outputs (e.g. the dense step's importance vector).
    pub fn step_full(&mut self, x: Tensor, y: Tensor, lr: f32) -> Result<(Vec<Tensor>, StepOut)> {
        // statics reuse their cached literals; only the (small) mutable
        // state + batch get marshaled per step
        let mut fresh: Vec<xla::Literal> = self.slots[self.ns..]
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        fresh.push(tensor_to_literal(&Tensor::scalar_f32(self.t))?);
        fresh.push(tensor_to_literal(&Tensor::scalar_f32(lr))?);
        fresh.push(tensor_to_literal(&x)?);
        fresh.push(tensor_to_literal(&y)?);
        let refs: Vec<&xla::Literal> =
            self.static_lits.iter().chain(fresh.iter()).collect();
        let mut out = self.session.run_literals(&self.entry.name, &refs)?;
        // outputs: trainables', m', v', t', loss, acc (, importance)
        for i in 0..3 * self.nt {
            self.slots[self.ns + i] = std::mem::replace(&mut out[i], Tensor::zeros(&[]));
        }
        self.t = out[3 * self.nt].scalar()?;
        let step = StepOut {
            loss: out[3 * self.nt + 1].scalar()?,
            acc: out[3 * self.nt + 2].scalar()?,
        };
        let extra = out.split_off(3 * self.nt + 3);
        Ok((extra, step))
    }

    /// Importance vector from the last dense step (pruning substrate).
    pub fn importance(&mut self, x: Tensor, y: Tensor) -> Result<Vec<f32>> {
        if !self.emits_importance {
            bail!("{} does not emit importance", self.entry.name);
        }
        let (extra, _) = self.step_full(x, y, 0.0)?;
        Ok(extra
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("missing importance output"))?
            .f32s()?
            .to_vec())
    }

    /// Held-out evaluation through the paired eval executable.
    pub fn eval(&self, x: Tensor, y: Tensor) -> Result<StepOut> {
        let ev = self
            .eval_entry
            .as_ref()
            .ok_or_else(|| anyhow!("no eval executable for {}", self.entry.name))?;
        let mut inputs: Vec<Tensor> = self.slots[..self.ns + self.nt].to_vec();
        inputs.push(x);
        inputs.push(y);
        let out = self.session.run(&ev.name, &inputs)?;
        Ok(StepOut { loss: out[0].scalar()?, acc: out[1].scalar()? })
    }

    // ---- slot access -----------------------------------------------------

    pub fn slot_index(&self, name: &str) -> Option<usize> {
        self.entry.inputs[..self.ns + 3 * self.nt]
            .iter()
            .position(|s| s.name == name)
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.slot_index(name)
            .map(|i| &self.slots[i])
            .ok_or_else(|| anyhow!("no slot {name}"))
    }

    /// Replace a static (e.g. the pruning mask, or SWGAN-trained generator
    /// weights) or a trainable (checkpoint restore).
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let i = self.slot_index(name).ok_or_else(|| anyhow!("no slot {name}"))?;
        if t.dims != self.entry.inputs[i].shape {
            bail!("slot {name}: shape {:?} != {:?}", t.dims, self.entry.inputs[i].shape);
        }
        if i < self.ns {
            self.static_lits[i] = tensor_to_literal(&t)?;
        }
        self.slots[i] = t;
        Ok(())
    }

    /// The trainable tensors (the compressed representation), with names.
    pub fn trainables(&self) -> Vec<(&str, &Tensor)> {
        (0..self.nt)
            .map(|i| {
                (
                    self.entry.inputs[self.ns + i].name.as_str(),
                    &self.slots[self.ns + i],
                )
            })
            .collect()
    }

    /// Compressed-representation size in parameters (excluding raw leaves,
    /// matching the paper's accounting).
    pub fn compressed_params(&self) -> usize {
        self.entry.trainable_comp()
    }

    /// Reset the optimizer moments + step counter (used between pruning
    /// phases, like the paper's finetune-after-prune recipe).
    pub fn reset_optimizer(&mut self) {
        for i in self.ns + self.nt..self.ns + 3 * self.nt {
            self.slots[i] = Tensor::zeros(&self.slots[i].dims.clone());
        }
        self.t = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, Split, SynthVision};
    use crate::runtime::artifacts_dir;

    fn session() -> Option<Session> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Session::open(&dir).unwrap())
    }

    #[test]
    fn state_trains_and_evals() {
        let Some(sess) = session() else { return };
        let mut st = TrainState::new(&sess, "mlp_mcnc02_train", 5).unwrap();
        let ds = SynthVision::new(1, 10, 28, 28, 1);
        let (x0, y0) = ds.batch(Split::Val, 0, 128);
        let before = st.eval(x0.clone(), y0.clone()).unwrap();
        let mut last = f32::NAN;
        for step in 0..20 {
            let (x, y) = ds.batch(Split::Train, step % 4, 128);
            last = st.step(x, y, 0.05).unwrap().loss;
        }
        assert!(last.is_finite());
        let after = st.eval(x0, y0).unwrap();
        assert!(after.loss < before.loss, "{} -> {}", before.loss, after.loss);
        assert_eq!(st.t, 20.0);
        assert_eq!(st.compressed_params(), 540);
    }

    #[test]
    fn set_get_roundtrip_and_shape_check() {
        let Some(sess) = session() else { return };
        let mut st = TrainState::new(&sess, "mlp_dense_train", 1).unwrap();
        let dc = st.get("mask").unwrap().numel();
        let zeros = Tensor::zeros(&[dc]);
        st.set("mask", zeros.clone()).unwrap();
        assert_eq!(st.get("mask").unwrap(), &zeros);
        assert!(st.set("mask", Tensor::zeros(&[3])).is_err());
        assert!(st.get("nonexistent").is_err());
    }

    #[test]
    fn rejects_non_train_entries() {
        let Some(sess) = session() else { return };
        assert!(TrainState::new(&sess, "mlp_mcnc02_eval", 1).is_err());
    }
}
