//! Training orchestration: manifest-generic state, schedules, compressed
//! checkpoints, and the step-loop driver used by examples and benches.

pub mod checkpoint;
pub mod runner;
pub mod schedule;
pub mod state;

pub use checkpoint::Checkpoint;
pub use runner::{evaluate, run, History, TrainCfg};
pub use schedule::{LrSchedule, LrState};
pub use state::{StepOut, TrainState};
