//! Compressed checkpoint formats (`.mcnc`): what actually ships when a
//! model is stored or transmitted — the scalar seed (θ0 + generator are
//! re-derivable) plus the trainable tensors.
//!
//! Two on-disk layouts share the extension and are auto-detected by magic:
//!
//! ```text
//! MCNC1: magic "MCNC1\n" | u32 header_len | header JSON | f32-LE payload
//! MCNC2: the codec::container streaming format (quantized and/or
//!        entropy-coded per-tensor frames, CRC-protected)
//! ```
//!
//! [`Checkpoint::save`] keeps writing MCNC1 byte-for-byte as before;
//! [`Checkpoint::save_v2`] writes the compressed MCNC2 container, with the
//! codec selectable per tensor via [`Checkpoint::save_v2_with`] (e.g.
//! lossless for (α, β), int8 for a raw head). `stored_bytes` is the
//! paper's "model size" numerator for the MCNC1 layout.

use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::codec::{self, Codec, ContainerHeader};
use crate::tensor::Tensor;
use crate::util::json::{self, Json};

const MAGIC: &[u8; 6] = b"MCNC1\n";

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub entry: String,
    pub seed: u64,
    pub step: f32,
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn stored_bytes(&self) -> usize {
        MAGIC.len() + 4 + self.header().len()
            + self.tensors.iter().map(|(_, t)| t.numel() * 4).sum::<usize>()
    }

    pub fn stored_params(&self) -> usize {
        self.tensors.iter().map(|(_, t)| t.numel()).sum()
    }

    fn header(&self) -> String {
        let mut offset = 0usize;
        let tensors: Vec<Json> = self
            .tensors
            .iter()
            .map(|(name, t)| {
                let j = Json::obj(vec![
                    ("name", Json::str(name.clone())),
                    ("shape", Json::Arr(t.dims.iter().map(|&d| Json::num(d as f64)).collect())),
                    ("offset", Json::num(offset as f64)),
                ]);
                offset += t.numel();
                j
            })
            .collect();
        json::to_string(&Json::obj(vec![
            ("version", Json::num(1.0)),
            ("entry", Json::str(self.entry.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("step", Json::num(self.step as f64)),
            ("tensors", Json::Arr(tensors)),
        ]))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let header = self.header();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, t) in &self.tensors {
            let v = t.f32s().map_err(|_| anyhow!("only f32 tensors are checkpointed"))?;
            // SAFETY: f32 is plain-old-data, u8 has alignment 1, and the
            // byte view lives only for this iteration's borrow of `v`.
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    /// Write the checkpoint as a streaming MCNC2 container with one codec
    /// for every tensor. Returns the wire size in bytes.
    pub fn save_v2(&self, path: &Path, codec: Codec) -> Result<usize> {
        self.save_v2_with(path, |_, _| codec)
    }

    /// MCNC2 save with a per-tensor codec choice (`codec_for(name, t)`), so
    /// bit-exactness stays selectable per tensor role — e.g. lossless for
    /// the (α, β) manifold coordinates, int8 for a raw dense head.
    pub fn save_v2_with(
        &self,
        path: &Path,
        codec_for: impl Fn(&str, &Tensor) -> Codec,
    ) -> Result<usize> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let header = ContainerHeader {
            entry: self.entry.clone(),
            seed: self.seed,
            step: self.step,
            n_tensors: Some(self.tensors.len()),
        };
        let mut enc = codec::Encoder::new(BufWriter::new(f), &header)?;
        for (name, t) in &self.tensors {
            enc.write_tensor(name, t, codec_for(name, t))?;
        }
        let (_, wire) = enc.finish()?;
        Ok(wire)
    }

    /// Load either checkpoint format, auto-detected by magic. MCNC1 files
    /// read byte-for-byte exactly as they always have.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic == MAGIC {
            Checkpoint::load_v1(f)
        } else if &magic == codec::MAGIC_V2 {
            Checkpoint::load_v2(f)
        } else {
            bail!("not an .mcnc checkpoint");
        }
    }

    fn load_v1(mut f: std::fs::File) -> Result<Checkpoint> {
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        // a corrupt header length must not drive an unchecked allocation
        if hlen > codec::container::MAX_HEADER {
            bail!("checkpoint header length {hlen} unreasonable");
        }
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow!("checkpoint header: {e}"))?;
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        if payload.len() % 4 != 0 {
            bail!("payload not f32-aligned");
        }
        let floats: Vec<f32> = payload
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut tensors = Vec::new();
        let mut ranges: Vec<(usize, usize, String)> = Vec::new();
        for t in header.get("tensors").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = t.get("name").and_then(Json::as_str).unwrap_or("").to_string();
            let shape = t.get("shape").map(Json::usize_vec).unwrap_or_default();
            let offset = t.get("offset").and_then(Json::as_usize).unwrap_or(0);
            let n: usize = shape.iter().product();
            if offset + n > floats.len() {
                bail!("tensor {name} overruns payload");
            }
            if n > 0 {
                ranges.push((offset, offset + n, name.clone()));
            }
            tensors.push((name, Tensor::from_f32(floats[offset..offset + n].to_vec(), &shape)?));
        }
        // overlapping tensor ranges mean a corrupt (or adversarial) header
        ranges.sort();
        for pair in ranges.windows(2) {
            if pair[1].0 < pair[0].1 {
                bail!("tensors {} and {} overlap in the payload", pair[0].2, pair[1].2);
            }
        }
        let seed = match header.get("seed") {
            // written as a number by `save`, but accept the MCNC2 decimal
            // string spelling too (u64-exact for seeds ≥ 2^53)
            Some(j) => codec::container::seed_from_json(j)?,
            None => 0,
        };
        Ok(Checkpoint {
            entry: header.get("entry").and_then(Json::as_str).unwrap_or("").to_string(),
            seed,
            step: header.get("step").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            tensors,
        })
    }

    fn load_v2(f: std::fs::File) -> Result<Checkpoint> {
        let mut dec = codec::Decoder::after_magic(std::io::BufReader::new(f))?;
        // frames decode in parallel across the global pool (bit-identical
        // to the serial path; `--threads` / MCNC_THREADS pins the width)
        let tensors = dec
            .decode_all()?
            .into_iter()
            .map(|(name, t, _codec)| (name, t))
            .collect();
        let h = dec.header();
        Ok(Checkpoint { entry: h.entry.clone(), seed: h.seed, step: h.step, tensors })
    }

    /// Snapshot a training state's compressed representation.
    pub fn from_state(state: &super::state::TrainState) -> Checkpoint {
        Checkpoint {
            entry: state.entry.name.clone(),
            seed: state.seed,
            step: state.t,
            tensors: state
                .trainables()
                .into_iter()
                .map(|(n, t)| (n.to_string(), t.clone()))
                .collect(),
        }
    }

    /// Restore trainables into a state (entry names must match).
    pub fn restore(&self, state: &mut super::state::TrainState) -> Result<()> {
        if state.entry.name != self.entry {
            bail!("checkpoint is for {}, state is {}", self.entry, state.entry.name);
        }
        for (name, t) in &self.tensors {
            state.set(name, t.clone())?;
        }
        state.t = self.step;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            entry: "mlp_mcnc02_train".into(),
            seed: 42,
            step: 100.0,
            tensors: vec![
                ("alpha".into(), Tensor::from_f32((0..54).map(|i| i as f32 * 0.1).collect(), &[6, 9]).unwrap()),
                ("beta".into(), Tensor::ones(&[6])),
            ],
        }
    }

    #[test]
    fn roundtrip_bitwise() {
        let ck = sample();
        let dir = std::env::temp_dir().join(format!("mcnc_ck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.mcnc");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.entry, ck.entry);
        assert_eq!(back.seed, 42);
        assert_eq!(back.step, 100.0);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].1, ck.tensors[0].1);
        assert_eq!(back.tensors[1].1, ck.tensors[1].1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_accounting() {
        let ck = sample();
        assert_eq!(ck.stored_params(), 60);
        let size = ck.stored_bytes();
        assert!(size > 60 * 4, "payload plus header");
        assert!(size < 60 * 4 + 1000, "header stays small: {size}");
    }

    #[test]
    fn rejects_corrupt() {
        let dir = std::env::temp_dir().join(format!("mcnc_ck2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.mcnc");
        std::fs::write(&path, b"NOTMCNC").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("mcnc_ck_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn v2_lossless_roundtrip_u64_seed() {
        let mut ck = sample();
        ck.seed = u64::MAX; // only representable via the string spelling
        let dir = tmp("v2");
        let p1 = dir.join("a.mcnc");
        let p2 = dir.join("a2.mcnc");
        ck.save(&p1).unwrap();
        let wire = ck.save_v2(&p2, Codec::Lossless).unwrap();
        assert_eq!(wire as u64, std::fs::metadata(&p2).unwrap().len());

        let back = Checkpoint::load(&p2).unwrap();
        assert_eq!(back.entry, ck.entry);
        assert_eq!(back.seed, u64::MAX, "seed must round-trip u64-exactly");
        assert_eq!(back.step, ck.step);
        assert_eq!(back.tensors.len(), ck.tensors.len());
        for ((an, at), (bn, bt)) in back.tensors.iter().zip(&ck.tensors) {
            assert_eq!(an, bn);
            assert_eq!(at, bt);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v2_per_role_codec() {
        let ck = sample();
        let dir = tmp("role");
        let path = dir.join("mixed.mcnc");
        ck.save_v2_with(&path, |name, _| {
            if name == "alpha" {
                Codec::Int8 { block: 32 }
            } else {
                Codec::Lossless
            }
        })
        .unwrap();
        let back = Checkpoint::load(&path).unwrap();
        // beta (lossless) is bit-exact; alpha (int8) within the absmax bound
        assert_eq!(back.tensors[1].1, ck.tensors[1].1);
        let a = back.tensors[0].1.f32s().unwrap();
        let b = ck.tensors[0].1.f32s().unwrap();
        let bound = crate::baselines::quant::worst_rel_error(8) * 6.0; // absmax ≈ 5.3 per block
        assert!(a.iter().zip(b).all(|(x, y)| (x - y).abs() <= bound));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_rejects_huge_header_len() {
        let dir = tmp("hlen");
        let path = dir.join("huge.mcnc");
        let mut bytes = b"MCNC1\n".to_vec();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("unreasonable"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_rejects_overlapping_offsets() {
        let dir = tmp("overlap");
        let path = dir.join("overlap.mcnc");
        let header = r#"{"version":1,"entry":"e","seed":1,"step":0,"tensors":[{"name":"a","shape":[4],"offset":0},{"name":"b","shape":[4],"offset":2}]}"#;
        let mut bytes = b"MCNC1\n".to_vec();
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&[0u8; 6 * 4]);
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("overlap"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_accepts_string_seed() {
        let dir = tmp("seedstr");
        let path = dir.join("s.mcnc");
        let header = r#"{"version":1,"entry":"e","seed":"18446744073709551615","step":0,"tensors":[]}"#;
        let mut bytes = b"MCNC1\n".to_vec();
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().seed, u64::MAX);
        std::fs::remove_dir_all(&dir).ok();
    }
}
