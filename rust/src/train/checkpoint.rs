//! Compressed checkpoint format (`.mcnc`): what actually ships when a model
//! is stored or transmitted — the scalar seed (θ0 + generator are
//! re-derivable) plus the trainable tensors. Layout:
//!
//! ```text
//! magic "MCNC1\n" | u32 header_len | header JSON | f32-LE payload
//! ```
//!
//! The header records entry name, seed, and per-tensor (name, shape,
//! offset); `stored_bytes` is the paper's "model size" numerator.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::{self, Json};

const MAGIC: &[u8; 6] = b"MCNC1\n";

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub entry: String,
    pub seed: u64,
    pub step: f32,
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn stored_bytes(&self) -> usize {
        MAGIC.len() + 4 + self.header().len()
            + self.tensors.iter().map(|(_, t)| t.numel() * 4).sum::<usize>()
    }

    pub fn stored_params(&self) -> usize {
        self.tensors.iter().map(|(_, t)| t.numel()).sum()
    }

    fn header(&self) -> String {
        let mut offset = 0usize;
        let tensors: Vec<Json> = self
            .tensors
            .iter()
            .map(|(name, t)| {
                let j = Json::obj(vec![
                    ("name", Json::str(name.clone())),
                    ("shape", Json::Arr(t.dims.iter().map(|&d| Json::num(d as f64)).collect())),
                    ("offset", Json::num(offset as f64)),
                ]);
                offset += t.numel();
                j
            })
            .collect();
        json::to_string(&Json::obj(vec![
            ("version", Json::num(1.0)),
            ("entry", Json::str(self.entry.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("step", Json::num(self.step as f64)),
            ("tensors", Json::Arr(tensors)),
        ]))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let header = self.header();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, t) in &self.tensors {
            let v = t.f32s().map_err(|_| anyhow!("only f32 tensors are checkpointed"))?;
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not an .mcnc checkpoint");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow!("checkpoint header: {e}"))?;
        let mut payload = Vec::new();
        f.read_to_end(&mut payload)?;
        if payload.len() % 4 != 0 {
            bail!("payload not f32-aligned");
        }
        let floats: Vec<f32> = payload
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut tensors = Vec::new();
        for t in header.get("tensors").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = t.get("name").and_then(Json::as_str).unwrap_or("").to_string();
            let shape = t.get("shape").map(Json::usize_vec).unwrap_or_default();
            let offset = t.get("offset").and_then(Json::as_usize).unwrap_or(0);
            let n: usize = shape.iter().product();
            if offset + n > floats.len() {
                bail!("tensor {name} overruns payload");
            }
            tensors.push((name, Tensor::from_f32(floats[offset..offset + n].to_vec(), &shape)?));
        }
        Ok(Checkpoint {
            entry: header.get("entry").and_then(Json::as_str).unwrap_or("").to_string(),
            seed: header.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            step: header.get("step").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            tensors,
        })
    }

    /// Snapshot a training state's compressed representation.
    pub fn from_state(state: &super::state::TrainState) -> Checkpoint {
        Checkpoint {
            entry: state.entry.name.clone(),
            seed: state.seed,
            step: state.t,
            tensors: state
                .trainables()
                .into_iter()
                .map(|(n, t)| (n.to_string(), t.clone()))
                .collect(),
        }
    }

    /// Restore trainables into a state (entry names must match).
    pub fn restore(&self, state: &mut super::state::TrainState) -> Result<()> {
        if state.entry.name != self.entry {
            bail!("checkpoint is for {}, state is {}", self.entry, state.entry.name);
        }
        for (name, t) in &self.tensors {
            state.set(name, t.clone())?;
        }
        state.t = self.step;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            entry: "mlp_mcnc02_train".into(),
            seed: 42,
            step: 100.0,
            tensors: vec![
                ("alpha".into(), Tensor::from_f32((0..54).map(|i| i as f32 * 0.1).collect(), &[6, 9]).unwrap()),
                ("beta".into(), Tensor::ones(&[6])),
            ],
        }
    }

    #[test]
    fn roundtrip_bitwise() {
        let ck = sample();
        let dir = std::env::temp_dir().join(format!("mcnc_ck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.mcnc");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.entry, ck.entry);
        assert_eq!(back.seed, 42);
        assert_eq!(back.step, 100.0);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].1, ck.tensors[0].1);
        assert_eq!(back.tensors[1].1, ck.tensors[1].1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_accounting() {
        let ck = sample();
        assert_eq!(ck.stored_params(), 60);
        let size = ck.stored_bytes();
        assert!(size > 60 * 4, "payload plus header");
        assert!(size < 60 * 4 + 1000, "header stays small: {size}");
    }

    #[test]
    fn rejects_corrupt() {
        let dir = std::env::temp_dir().join(format!("mcnc_ck2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.mcnc");
        std::fs::write(&path, b"NOTMCNC").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
