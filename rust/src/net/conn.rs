//! Per-connection protocol state machine — pure logic, no sockets, so the
//! preamble handshake, deframing, reply routing and write backpressure are
//! all unit- and property-testable without I/O. The listener owns one
//! [`Conn`] per accepted socket and feeds it raw reads; the `Conn` answers
//! with decoded messages and accumulates encoded reply bytes for the
//! listener to flush.
//!
//! The `inflight` map is the wire-id ↔ trace-id bridge: shard reply
//! channels are keyed by the **server-minted** request id (which doubles
//! as the trace id), while clients choose their own wire ids — the map
//! records `trace → wire` at submit so each [`Response`] coming back off
//! the reply channel can be re-addressed to the client's id.
//!
//! [`Response`]: crate::coordinator::Response

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::protocol::{self, Deframer, Msg, NET_MAGIC};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// Waiting for the 6-byte `NET_MAGIC` preamble.
    Preamble,
    /// Preamble verified; frames flow.
    Open,
    /// Fatal protocol error or shutdown: no more reads or submissions;
    /// pending write bytes (e.g. a final `ConnErr`) still flush.
    Closed,
}

/// Protocol state for one client connection.
#[derive(Debug)]
pub struct Conn {
    state: State,
    pre: Vec<u8>,
    deframer: Deframer,
    /// Encoded-but-unflushed reply bytes.
    out: Vec<u8>,
    /// Flushed prefix of `out` (compacted lazily).
    sent: usize,
    /// Server trace id → client wire id for requests awaiting a reply.
    inflight: HashMap<u64, u64>,
}

impl Default for Conn {
    fn default() -> Conn {
        Conn::new()
    }
}

impl Conn {
    /// Fresh connection awaiting its preamble.
    pub fn new() -> Conn {
        Conn {
            state: State::Preamble,
            pre: Vec::with_capacity(NET_MAGIC.len()),
            deframer: Deframer::new(),
            out: Vec::new(),
            sent: 0,
            inflight: HashMap::new(),
        }
    }

    /// Feed freshly read bytes; returns every message completed by them.
    /// `Err` means a protocol violation (bad preamble, corrupt frame): the
    /// caller should [`Conn::queue`] a [`Msg::ConnErr`], [`Conn::close`],
    /// flush, and drop the socket.
    pub fn on_bytes(&mut self, mut data: &[u8]) -> Result<Vec<Msg>> {
        if self.state == State::Closed {
            return Ok(Vec::new());
        }
        if self.state == State::Preamble {
            let take = (NET_MAGIC.len() - self.pre.len()).min(data.len());
            self.pre.extend_from_slice(&data[..take]);
            data = &data[take..];
            if self.pre.len() < NET_MAGIC.len() {
                return Ok(Vec::new());
            }
            if self.pre != NET_MAGIC[..] {
                bail!("bad connection preamble (expected {:?})", protocol::NET_MAGIC);
            }
            self.state = State::Open;
        }
        self.deframer.push(data);
        let mut msgs = Vec::new();
        while let Some(m) = self.deframer.next()? {
            msgs.push(m);
        }
        Ok(msgs)
    }

    /// Encode `msg` into the write buffer (flushed by the listener).
    pub fn queue(&mut self, msg: &Msg) {
        self.out.extend_from_slice(&protocol::encode_frame(msg));
    }

    /// Bytes queued for the socket but not yet written.
    pub fn pending_write(&self) -> &[u8] {
        &self.out[self.sent..]
    }

    /// Note that `n` bytes of [`Conn::pending_write`] reached the socket.
    pub fn consume_written(&mut self, n: usize) {
        self.sent = (self.sent + n).min(self.out.len());
        if self.sent == self.out.len() {
            self.out.clear();
            self.sent = 0;
        } else if self.sent > 8192 {
            self.out.drain(..self.sent);
            self.sent = 0;
        }
    }

    /// Unflushed write-buffer depth in bytes — the listener's backpressure
    /// signal: past its threshold it stops reading this socket, which
    /// leaves further requests in the kernel buffer and ultimately pushes
    /// back on the client, mirroring the shard admission queues.
    pub fn write_backlog(&self) -> usize {
        self.out.len() - self.sent
    }

    /// Record a submitted request: server `trace` id → client `wire` id.
    pub fn note_inflight(&mut self, trace: u64, wire: u64) {
        self.inflight.insert(trace, wire);
    }

    /// Resolve (and forget) the wire id for a completed request.
    pub fn take_inflight(&mut self, trace: u64) -> Option<u64> {
        self.inflight.remove(&trace)
    }

    /// Requests submitted on this connection still awaiting replies.
    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Stop accepting input and submissions (pending writes still flush).
    pub fn close(&mut self) {
        self.state = State::Closed;
    }

    /// False once [`Conn::close`] was called.
    pub fn is_open(&self) -> bool {
        self.state != State::Closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open_conn() -> Conn {
        let mut c = Conn::new();
        assert!(c.on_bytes(NET_MAGIC).expect("preamble").is_empty());
        c
    }

    #[test]
    fn preamble_split_across_reads() {
        let mut c = Conn::new();
        assert!(c.on_bytes(&NET_MAGIC[..3]).expect("half").is_empty());
        let mut wire = NET_MAGIC[3..].to_vec();
        wire.extend_from_slice(&protocol::encode_frame(&Msg::Ping { nonce: 5 }));
        let msgs = c.on_bytes(&wire).expect("rest + frame");
        assert_eq!(msgs, vec![Msg::Ping { nonce: 5 }]);
    }

    #[test]
    fn bad_preamble_is_fatal() {
        let mut c = Conn::new();
        let err = c.on_bytes(b"MCNC2\n").expect_err("wrong magic");
        assert!(err.to_string().contains("preamble"), "{err}");
    }

    #[test]
    fn closed_conn_ignores_input_but_flushes_writes() {
        let mut c = open_conn();
        c.queue(&Msg::ConnErr { msg: "bye".into() });
        c.close();
        assert!(!c.is_open());
        assert!(c.on_bytes(&[1, 2, 3]).expect("ignored").is_empty());
        let n = c.pending_write().len();
        assert!(n > 0);
        c.consume_written(n);
        assert_eq!(c.write_backlog(), 0);
    }

    #[test]
    fn partial_writes_and_backlog_accounting() {
        let mut c = open_conn();
        c.queue(&Msg::Pong { nonce: 1 });
        c.queue(&Msg::Pong { nonce: 2 });
        let total = c.write_backlog();
        c.consume_written(3);
        assert_eq!(c.write_backlog(), total - 3);
        let rest = c.pending_write().len();
        c.consume_written(rest);
        assert_eq!(c.write_backlog(), 0);
        assert!(c.pending_write().is_empty());
    }

    #[test]
    fn inflight_maps_trace_to_wire_once() {
        let mut c = open_conn();
        c.note_inflight(1001, 7);
        c.note_inflight(1002, 8);
        assert_eq!(c.inflight(), 2);
        assert_eq!(c.take_inflight(1001), Some(7));
        assert_eq!(c.take_inflight(1001), None, "resolved exactly once");
        assert_eq!(c.inflight(), 1);
    }

    #[test]
    fn interleaved_frames_across_chunk_boundaries() {
        let mut c = open_conn();
        let frames: Vec<Msg> = (0..5).map(|i| Msg::Ping { nonce: i }).collect();
        let mut wire = Vec::new();
        for m in &frames {
            wire.extend_from_slice(&protocol::encode_frame(m));
        }
        let mut got = Vec::new();
        for chunk in wire.chunks(7) {
            got.extend(c.on_bytes(chunk).expect("chunk"));
        }
        assert_eq!(got, frames);
    }
}
