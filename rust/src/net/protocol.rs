//! MCNP1 wire codec: pure, panic-free encode/decode of the socket
//! front-end's framed request/reply protocol. `docs/PROTOCOL.md` is the
//! byte-level specification of everything here; its worked example is
//! pinned by `rust/tests/prop_net_protocol.rs::
//! protocol_spec_worked_example_decodes`.
//!
//! A connection opens with the 6-byte preamble [`NET_MAGIC`] (the `1` is
//! the protocol version, [`NET_VERSION`]), then carries frames in both
//! directions:
//!
//! ```text
//! frame:  varint body_len | body_len bytes | u32 crc32(body) LE
//! body:   msg type (u8) | type-specific fields
//! ```
//!
//! Varints and CRC-32 are exactly the MCNC2 container's
//! (`docs/FORMAT.md` §1.1/§1.2) — one repo, one framing idiom. Every
//! length a decoder allocates from is bounded ([`NET_MAX_FRAME`],
//! [`MAX_TOKENS`], [`MAX_ERR_LEN`]) and the CRC is verified before any
//! body parsing, so arbitrary bytes off a socket surface as an error,
//! never a panic or a giant allocation. This module is wall-clock-free
//! and deterministic (mcnc-lint `determinism` covers it): identical
//! messages encode to identical bytes on every host.

use anyhow::{anyhow, bail, Result};

use crate::codec::container::{crc32, put_varint, MAX_VARINT_BYTES};
use crate::coordinator::{Response, ServeError};

/// Connection preamble a client sends once after connecting; the trailing
/// digit is the protocol version ([`NET_VERSION`]).
pub const NET_MAGIC: &[u8; 6] = b"MCNP1\n";
/// Protocol version carried by the preamble (`MCNP`**`1`**).
pub const NET_VERSION: u64 = 1;
/// Frame body length bound: a corrupt length field must not stall the
/// deframer or drive a giant allocation.
pub const NET_MAX_FRAME: usize = 1 << 20;
/// Token-count bound of a request payload.
pub const MAX_TOKENS: usize = 1 << 16;
/// Byte-length bound of an error/conn-error message string.
pub const MAX_ERR_LEN: usize = 4096;

/// Message type: request (client → server).
pub const MSG_REQ: u8 = 1;
/// Message type: successful prediction reply (server → client).
pub const MSG_REPLY_OK: u8 = 2;
/// Message type: per-request typed error reply (server → client).
pub const MSG_REPLY_ERR: u8 = 3;
/// Message type: liveness probe (client → server).
pub const MSG_PING: u8 = 4;
/// Message type: probe echo (server → client).
pub const MSG_PONG: u8 = 5;
/// Message type: fatal connection-level error; the sender closes after it.
pub const MSG_CONN_ERR: u8 = 6;

/// Reply error code mirroring [`ServeError::Rejected`] (admission
/// backpressure or an open circuit breaker — retry later).
pub const ERR_REJECTED: u8 = 1;
/// Reply error code mirroring [`ServeError::Failed`] (validation or
/// execution failure — retrying the same request will not help).
pub const ERR_FAILED: u8 = 2;
/// Reply error code mirroring [`ServeError::DeadlineExceeded`].
pub const ERR_DEADLINE: u8 = 3;

/// One decoded protocol message. `id` is always the **client-chosen wire
/// id** (echoed verbatim in replies); `trace` is the server-minted request
/// id, which doubles as the trace id in `mcnc serve --trace-out` output —
/// a remote client can correlate its replies with server-side spans.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Msg {
    /// Client request: run `tokens` against `task`'s adapter.
    Req {
        /// Client-chosen wire id, echoed in the reply.
        id: u64,
        /// Target task (adapter) id.
        task: u64,
        /// Token payload (i32 little-endian on the wire).
        tokens: Vec<i32>,
        /// Relative deadline in µs from server receipt; 0 = none.
        deadline_us: u64,
    },
    /// Successful prediction.
    ReplyOk {
        /// Echoed wire id.
        id: u64,
        /// Server-minted trace id.
        trace: u64,
        /// Predicted next token.
        token: i32,
        /// Rows in the batch that served this request.
        batch_rows: u64,
        /// Server-side submit → response latency in µs.
        latency_us: u64,
    },
    /// Typed per-request error ([`ERR_REJECTED`] / [`ERR_FAILED`] /
    /// [`ERR_DEADLINE`]); the connection stays open.
    ReplyErr {
        /// Echoed wire id.
        id: u64,
        /// Server-minted trace id.
        trace: u64,
        /// One of the `ERR_*` codes.
        code: u8,
        /// Human-readable detail (≤ [`MAX_ERR_LEN`] bytes, may be empty).
        msg: String,
    },
    /// Liveness probe.
    Ping {
        /// Opaque nonce echoed by the pong.
        nonce: u64,
    },
    /// Probe echo.
    Pong {
        /// The ping's nonce.
        nonce: u64,
    },
    /// Fatal connection error (bad preamble, corrupt frame, unknown
    /// message type); the peer closes the connection after sending it.
    ConnErr {
        /// Human-readable reason (≤ [`MAX_ERR_LEN`] bytes).
        msg: String,
    },
}

/// Encode a message body (everything inside the frame, no length/CRC).
pub fn encode_body(msg: &Msg) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Msg::Req { id, task, tokens, deadline_us } => {
            out.push(MSG_REQ);
            put_varint(&mut out, *id);
            put_varint(&mut out, *task);
            put_varint(&mut out, tokens.len() as u64);
            for t in tokens {
                out.extend_from_slice(&t.to_le_bytes());
            }
            put_varint(&mut out, *deadline_us);
        }
        Msg::ReplyOk { id, trace, token, batch_rows, latency_us } => {
            out.push(MSG_REPLY_OK);
            put_varint(&mut out, *id);
            put_varint(&mut out, *trace);
            out.extend_from_slice(&token.to_le_bytes());
            put_varint(&mut out, *batch_rows);
            put_varint(&mut out, *latency_us);
        }
        Msg::ReplyErr { id, trace, code, msg } => {
            out.push(MSG_REPLY_ERR);
            put_varint(&mut out, *id);
            put_varint(&mut out, *trace);
            out.push(*code);
            put_string(&mut out, msg);
        }
        Msg::Ping { nonce } => {
            out.push(MSG_PING);
            put_varint(&mut out, *nonce);
        }
        Msg::Pong { nonce } => {
            out.push(MSG_PONG);
            put_varint(&mut out, *nonce);
        }
        Msg::ConnErr { msg } => {
            out.push(MSG_CONN_ERR);
            put_string(&mut out, msg);
        }
    }
    out
}

/// Encode one complete frame: `varint body_len | body | crc32(body) LE`.
pub fn encode_frame(msg: &Msg) -> Vec<u8> {
    let body = encode_body(msg);
    let mut out = Vec::with_capacity(body.len() + MAX_VARINT_BYTES + 4);
    put_varint(&mut out, body.len() as u64);
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc32(&body).to_le_bytes());
    out
}

/// Decode a frame body (the deframer has already verified the CRC).
/// Rejects unknown message types, out-of-bound lengths, unknown error
/// codes, non-UTF-8 strings and trailing bytes.
pub fn decode_body(body: &[u8]) -> Result<Msg> {
    let mut pos = 0usize;
    let ty = *body.get(pos).ok_or_else(|| anyhow!("empty frame body"))?;
    pos += 1;
    let msg = match ty {
        MSG_REQ => {
            let id = get_varint(body, &mut pos)?;
            let task = get_varint(body, &mut pos)?;
            let n = get_varint(body, &mut pos)?;
            if n > MAX_TOKENS as u64 {
                bail!("request carries {n} tokens, limit {MAX_TOKENS}");
            }
            let mut tokens = Vec::with_capacity(n as usize);
            for _ in 0..n {
                tokens.push(get_i32(body, &mut pos)?);
            }
            let deadline_us = get_varint(body, &mut pos)?;
            Msg::Req { id, task, tokens, deadline_us }
        }
        MSG_REPLY_OK => {
            let id = get_varint(body, &mut pos)?;
            let trace = get_varint(body, &mut pos)?;
            let token = get_i32(body, &mut pos)?;
            let batch_rows = get_varint(body, &mut pos)?;
            let latency_us = get_varint(body, &mut pos)?;
            Msg::ReplyOk { id, trace, token, batch_rows, latency_us }
        }
        MSG_REPLY_ERR => {
            let id = get_varint(body, &mut pos)?;
            let trace = get_varint(body, &mut pos)?;
            let code = *body.get(pos).ok_or_else(|| anyhow!("error code truncated"))?;
            pos += 1;
            if !(ERR_REJECTED..=ERR_DEADLINE).contains(&code) {
                bail!("unknown reply error code {code}");
            }
            let msg = get_string(body, &mut pos, "error message")?;
            Msg::ReplyErr { id, trace, code, msg }
        }
        MSG_PING => Msg::Ping { nonce: get_varint(body, &mut pos)? },
        MSG_PONG => Msg::Pong { nonce: get_varint(body, &mut pos)? },
        MSG_CONN_ERR => Msg::ConnErr { msg: get_string(body, &mut pos, "conn-error message")? },
        _ => bail!("unknown message type {ty}"),
    };
    if pos != body.len() {
        bail!("{} trailing bytes after message", body.len() - pos);
    }
    Ok(msg)
}

/// Incremental frame extractor for a byte stream arriving in arbitrary
/// chunks. Feed reads with [`Deframer::push`]; [`Deframer::next`] yields
/// complete messages, `Ok(None)` while a frame is still partial, and
/// `Err` on corruption (bad length, CRC mismatch, malformed body) — a
/// fatal condition for the connection. Buffering is bounded: a frame
/// length beyond [`NET_MAX_FRAME`] errors before any body bytes are
/// awaited, so a hostile peer cannot grow the buffer past one frame.
#[derive(Debug, Default)]
pub struct Deframer {
    buf: Vec<u8>,
    read: usize,
}

impl Deframer {
    /// Empty deframer.
    pub fn new() -> Deframer {
        Deframer::default()
    }

    /// Append freshly received bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        // reclaim the consumed prefix before growing
        if self.read > 0 {
            self.buf.drain(..self.read);
            self.read = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a complete frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.read
    }

    /// Extract the next complete message, if one is fully buffered.
    pub fn next(&mut self) -> Result<Option<Msg>> {
        let avail = &self.buf[self.read..];
        let mut pos = 0usize;
        let body_len = match peek_varint(avail, &mut pos)? {
            None => return Ok(None),
            Some(v) => v,
        };
        if body_len == 0 {
            bail!("zero-length frame body");
        }
        if body_len > NET_MAX_FRAME as u64 {
            bail!("frame body of {body_len} bytes exceeds the {NET_MAX_FRAME}-byte limit");
        }
        let body_len = body_len as usize;
        let need = pos + body_len + 4;
        if avail.len() < need {
            return Ok(None);
        }
        let body = &avail[pos..pos + body_len];
        let c = &avail[pos + body_len..need];
        let stored = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        let computed = crc32(body);
        if stored != computed {
            bail!("frame CRC mismatch: stored {stored:#010x}, computed {computed:#010x}");
        }
        let msg = decode_body(body)?;
        self.read += need;
        Ok(Some(msg))
    }
}

/// Build the reply message for a coordinator [`Response`], echoing the
/// connection's `wire_id` and exposing the server trace id alongside.
pub fn reply_msg(wire_id: u64, resp: &Response) -> Msg {
    match &resp.result {
        Ok(token) => Msg::ReplyOk {
            id: wire_id,
            trace: resp.id,
            token: *token,
            batch_rows: resp.batch_rows as u64,
            latency_us: resp.latency.as_micros() as u64,
        },
        Err(e) => {
            let (code, msg) = match e {
                ServeError::Rejected(m) => (ERR_REJECTED, m.clone()),
                ServeError::Failed(m) => (ERR_FAILED, m.clone()),
                ServeError::DeadlineExceeded => (ERR_DEADLINE, String::new()),
            };
            Msg::ReplyErr { id: wire_id, trace: resp.id, code, msg: clip(msg) }
        }
    }
}

/// Map a reply error code back to the [`ServeError`] it mirrors (the
/// client-side inverse of [`reply_msg`]). Unknown codes were already
/// rejected by [`decode_body`].
pub fn wire_error(code: u8, msg: &str) -> ServeError {
    match code {
        ERR_REJECTED => ServeError::Rejected(msg.to_string()),
        ERR_DEADLINE => ServeError::DeadlineExceeded,
        _ => ServeError::Failed(msg.to_string()),
    }
}

/// Clip a message string to [`MAX_ERR_LEN`] bytes on a char boundary.
pub fn clip(mut msg: String) -> String {
    if msg.len() > MAX_ERR_LEN {
        let mut n = MAX_ERR_LEN;
        while n > 0 && !msg.is_char_boundary(n) {
            n -= 1;
        }
        msg.truncate(n);
    }
    msg
}

/// Varint string: `varint byte_len | UTF-8 bytes`, clipped on encode.
fn put_string(out: &mut Vec<u8>, s: &str) {
    let s = clip(s.to_string());
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn get_string(buf: &[u8], pos: &mut usize, what: &str) -> Result<String> {
    let n = get_varint(buf, pos)?;
    if n > MAX_ERR_LEN as u64 {
        bail!("{what} of {n} bytes exceeds the {MAX_ERR_LEN}-byte limit");
    }
    let n = n as usize;
    let b = buf.get(*pos..*pos + n).ok_or_else(|| anyhow!("{what} truncated"))?;
    *pos += n;
    String::from_utf8(b.to_vec()).map_err(|_| anyhow!("{what} is not UTF-8"))
}

fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    crate::codec::container::get_varint(buf, pos)
}

fn get_i32(buf: &[u8], pos: &mut usize) -> Result<i32> {
    let b = buf.get(*pos..*pos + 4).ok_or_else(|| anyhow!("i32 field truncated"))?;
    *pos += 4;
    Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

/// Varint peek that distinguishes "not enough bytes yet" (`Ok(None)`)
/// from a malformed varint (`Err`), for the deframer's incremental parse.
fn peek_varint(buf: &[u8], pos: &mut usize) -> Result<Option<u64>> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&b) = buf.get(*pos) else {
            return Ok(None);
        };
        *pos += 1;
        if shift == 63 && (b & 0x7f) > 1 {
            bail!("frame length varint overflows u64");
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(Some(v));
        }
        shift += 7;
        if shift >= 7 * MAX_VARINT_BYTES as u32 {
            bail!("frame length varint too long");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn variants() -> Vec<Msg> {
        vec![
            Msg::Req { id: 1, task: 0, tokens: vec![], deadline_us: 0 },
            Msg::Req { id: u64::MAX, task: 999, tokens: vec![i32::MIN, -1, 0, i32::MAX], deadline_us: 50_000 },
            Msg::ReplyOk { id: 17, trace: 300, token: -7, batch_rows: 16, latency_us: 1234 },
            Msg::ReplyErr { id: 2, trace: 3, code: ERR_REJECTED, msg: "queue full".into() },
            Msg::ReplyErr { id: 2, trace: 3, code: ERR_DEADLINE, msg: String::new() },
            Msg::Ping { nonce: 42 },
            Msg::Pong { nonce: 42 },
            Msg::ConnErr { msg: "bad preamble".into() },
        ]
    }

    #[test]
    fn body_roundtrip_all_variants() {
        for m in variants() {
            let body = encode_body(&m);
            let back = decode_body(&body).expect("decode");
            assert_eq!(back, m);
            // bit-exact re-encode
            assert_eq!(encode_body(&back), body);
        }
    }

    #[test]
    fn frame_roundtrip_through_deframer() {
        let mut d = Deframer::new();
        let mut wire = Vec::new();
        for m in variants() {
            wire.extend_from_slice(&encode_frame(&m));
        }
        d.push(&wire);
        let mut got = Vec::new();
        while let Some(m) = d.next().expect("frame") {
            got.push(m);
        }
        assert_eq!(got, variants());
        assert_eq!(d.buffered(), 0);
    }

    #[test]
    fn deframer_waits_on_partial_frames() {
        let frame = encode_frame(&Msg::Ping { nonce: 7 });
        let mut d = Deframer::new();
        for (i, b) in frame.iter().enumerate() {
            if i + 1 < frame.len() {
                d.push(&[*b]);
                assert!(d.next().expect("partial").is_none(), "byte {i}");
            } else {
                d.push(&[*b]);
                assert_eq!(d.next().expect("full"), Some(Msg::Ping { nonce: 7 }));
            }
        }
    }

    #[test]
    fn deframer_rejects_oversized_and_zero_lengths() {
        let mut d = Deframer::new();
        let mut wire = Vec::new();
        put_varint(&mut wire, (NET_MAX_FRAME + 1) as u64);
        d.push(&wire);
        assert!(d.next().is_err(), "oversized length must fail before body bytes arrive");
        let mut d = Deframer::new();
        d.push(&[0x00]);
        assert!(d.next().is_err(), "zero body length");
        let mut d = Deframer::new();
        d.push(&[0xff; 11]);
        assert!(d.next().is_err(), "runaway length varint");
    }

    #[test]
    fn crc_mismatch_is_fatal() {
        let mut frame = encode_frame(&Msg::Ping { nonce: 9 });
        let n = frame.len();
        frame[n - 1] ^= 0x01;
        let mut d = Deframer::new();
        d.push(&frame);
        let err = d.next().expect_err("corrupt CRC").to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn decode_rejects_trailing_and_unknown() {
        let mut body = encode_body(&Msg::Ping { nonce: 1 });
        body.push(0);
        assert!(decode_body(&body).unwrap_err().to_string().contains("trailing"));
        assert!(decode_body(&[0x7f]).unwrap_err().to_string().contains("unknown message type"));
        assert!(decode_body(&[]).is_err());
        // unknown error code
        let mut b = vec![MSG_REPLY_ERR];
        put_varint(&mut b, 1);
        put_varint(&mut b, 2);
        b.push(9); // not an ERR_* code
        put_varint(&mut b, 0);
        assert!(decode_body(&b).unwrap_err().to_string().contains("error code"));
    }

    #[test]
    fn token_count_is_bounded() {
        let mut b = vec![MSG_REQ];
        put_varint(&mut b, 1);
        put_varint(&mut b, 0);
        put_varint(&mut b, (MAX_TOKENS + 1) as u64);
        let err = decode_body(&b).unwrap_err().to_string();
        assert!(err.contains("tokens"), "{err}");
    }

    #[test]
    fn reply_msg_mirrors_serve_errors() {
        let mk = |result| Response {
            id: 55,
            task: 3,
            result,
            latency: Duration::from_micros(250),
            batch_rows: 4,
        };
        match reply_msg(9, &mk(Ok(31))) {
            Msg::ReplyOk { id, trace, token, batch_rows, latency_us } => {
                assert_eq!((id, trace, token, batch_rows, latency_us), (9, 55, 31, 4, 250));
            }
            other => panic!("{other:?}"),
        }
        for (err, code) in [
            (ServeError::Rejected("full".into()), ERR_REJECTED),
            (ServeError::Failed("boom".into()), ERR_FAILED),
            (ServeError::DeadlineExceeded, ERR_DEADLINE),
        ] {
            match reply_msg(9, &mk(Err(err.clone()))) {
                Msg::ReplyErr { code: c, msg, .. } => {
                    assert_eq!(c, code);
                    assert!(matches!(wire_error(c, &msg), e if std::mem::discriminant(&e)
                        == std::mem::discriminant(&err)));
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn clip_respects_char_boundaries() {
        let long = "é".repeat(MAX_ERR_LEN); // 2 bytes per char
        let clipped = clip(long);
        assert!(clipped.len() <= MAX_ERR_LEN);
        assert!(clipped.is_char_boundary(clipped.len()));
        assert_eq!(clip("short".into()), "short");
    }
}
