//! Network front-end: the MCNP1 framed wire protocol and the socket
//! serving loop that exposes the coordinator to remote clients.
//!
//! Layered bottom-up, each layer pure with respect to the one below so
//! the protocol battery in `rust/tests/prop_net_protocol.rs` can hammer
//! the byte-level behaviour without opening a socket:
//!
//! * [`protocol`] — frame/message codec: varint length prefix, CRC-32
//!   trailer, typed request/reply/error messages mirroring
//!   [`ServeError`](crate::coordinator::ServeError). Byte-level spec in
//!   `docs/PROTOCOL.md` (cross-checked by `mcnc-lint wire-format`).
//! * [`conn`] — per-connection state machine: preamble handshake,
//!   incremental deframing, reply write buffer, trace-id ↔ wire-id map.
//! * [`listener`] — dependency-free nonblocking accept/readiness loop
//!   multiplexing every connection onto the shard dispatcher via
//!   [`Server::submit_routed`](crate::coordinator::Server::submit_routed),
//!   with write backpressure mapped onto the bounded admission queues.

#![warn(missing_docs)]

pub mod conn;
pub mod listener;
pub mod protocol;

pub use conn::Conn;
pub use listener::{NetCfg, NetListener, NetReport};
pub use protocol::{Deframer, Msg};
