//! Dependency-free nonblocking socket front-end: one poll loop
//! multiplexes every client connection onto the sharded dispatcher.
//!
//! Everything socket-shaped lives in this file — `protocol.rs` and
//! `conn.rs` stay pure so the byte-level behaviour is testable without
//! I/O. The loop is plain `std::net` readiness polling: the listener and
//! every stream are nonblocking, each iteration accepts, reads, submits,
//! drains reply channels and flushes writes until `WouldBlock`, and an
//! idle iteration sleeps briefly instead of spinning.
//!
//! Backpressure is two-layered, both bounded:
//!
//! * **admission** — requests go through [`Server::submit_routed`] with no
//!   retry sleeps, so a full shard queue answers `Rejected` immediately
//!   (the poll loop must never block on a shard);
//! * **write** — a connection whose unflushed reply bytes exceed
//!   [`NetCfg::max_backlog`] stops being read until the client drains its
//!   side, pushing the overload back into the kernel socket buffers.
//!
//! Shutdown is a drain: once the stop flag is observed the listener stops
//! accepting and reading, finishes every in-flight request, flushes every
//! reply, and only then closes — bounded by [`NetCfg::drain`].

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{Response, Server};
use crate::obs::{self, Kind, NetObs};

use super::conn::Conn;
use super::protocol::{self, Msg};

/// Socket front-end configuration.
#[derive(Debug, Clone)]
pub struct NetCfg {
    /// Listen address, e.g. `127.0.0.1:7433` (`:0` = ephemeral port).
    pub addr: String,
    /// Connection cap; accepts beyond it get a `ConnErr` and a close.
    pub max_conns: usize,
    /// Per-connection unflushed-reply-bytes threshold past which the
    /// connection stops being read (write backpressure).
    pub max_backlog: usize,
    /// Shutdown drain budget: how long to keep flushing in-flight replies
    /// after the stop flag before closing regardless.
    pub drain: Duration,
}

impl Default for NetCfg {
    fn default() -> NetCfg {
        NetCfg {
            addr: "127.0.0.1:0".into(),
            max_conns: 1024,
            max_backlog: 256 << 10,
            drain: Duration::from_secs(5),
        }
    }
}

/// What the poll loop did over its lifetime (returned by
/// [`NetListener::run`]; the obs registry carries the live view).
#[derive(Debug, Clone, Default)]
pub struct NetReport {
    /// Connections accepted (including ones later refused for capacity).
    pub accepted: u64,
    /// Connections closed (EOF, error, shutdown drain).
    pub closed: u64,
    /// Accepts refused because [`NetCfg::max_conns`] was reached.
    pub refused: u64,
    /// Complete frames decoded off client sockets.
    pub frames_in: u64,
    /// Reply/pong frames queued to clients.
    pub frames_out: u64,
    /// Requests submitted into the dispatcher.
    pub requests: u64,
    /// Connections dropped for protocol violations.
    pub protocol_errors: u64,
    /// Raw bytes read off sockets.
    pub bytes_read: u64,
    /// Raw bytes written to sockets.
    pub bytes_written: u64,
}

/// One accepted client connection and its reply plumbing.
struct ConnSlot {
    stream: TcpStream,
    conn: Conn,
    /// Cloned into every `submit_routed` so this connection's responses
    /// funnel into one channel, drained by the poll loop.
    reply_tx: mpsc::Sender<Response>,
    reply_rx: mpsc::Receiver<Response>,
    /// Monotonic connection number (trace-span track id, mod 2¹⁶).
    id: usize,
    /// Client half-closed its write side: stop reading, keep replying.
    eof: bool,
    /// Socket is unusable (reset / write error): drop without flushing.
    dead: bool,
}

impl ConnSlot {
    /// Finished when nothing can ever flow again: the socket died, or the
    /// conn is closed/EOF with no replies pending and nothing to flush.
    fn finished(&self) -> bool {
        self.dead
            || (!self.conn.is_open() && self.conn.write_backlog() == 0)
            || (self.eof && self.conn.inflight() == 0 && self.conn.write_backlog() == 0)
    }

    /// Nothing in flight and nothing buffered — safe to close in a drain.
    fn drained(&self) -> bool {
        self.conn.inflight() == 0 && self.conn.write_backlog() == 0
    }
}

/// A bound (but not yet running) socket front-end. Binding is separate
/// from [`NetListener::run`] so callers can bind `:0`, read the ephemeral
/// port with [`NetListener::local_addr`], and hand the run loop to a
/// thread — the pattern the loopback tests and table4's socket sweep use.
pub struct NetListener {
    listener: TcpListener,
    cfg: NetCfg,
}

impl NetListener {
    /// Bind `cfg.addr` and switch the listener to nonblocking mode.
    pub fn bind(cfg: NetCfg) -> Result<NetListener> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        Ok(NetListener { listener, cfg })
    }

    /// The bound address (the real port when `cfg.addr` ended in `:0`).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("listener local_addr")
    }

    /// Run the poll loop until `stop` is set, then drain and return the
    /// lifetime totals. Every request submitted on any connection is
    /// answered before its socket closes (the coordinator's exactly-one-
    /// `Response` invariant carries over the wire), bounded only by the
    /// configured drain budget.
    pub fn run(self, server: &Server, stop: &AtomicBool) -> Result<NetReport> {
        let net_obs = NetObs::register();
        let mut conns: Vec<ConnSlot> = Vec::new();
        let mut report = NetReport::default();
        let mut next_id = 0usize;
        let mut buf = vec![0u8; 16 * 1024];
        let mut drain_deadline: Option<Instant> = None;

        loop {
            let mut progressed = false;
            if drain_deadline.is_none() && stop.load(Ordering::Relaxed) {
                drain_deadline = Some(Instant::now() + self.cfg.drain);
            }
            let draining = drain_deadline.is_some();

            // -- accept ------------------------------------------------
            while !draining {
                let t0 = Instant::now();
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        progressed = true;
                        report.accepted += 1;
                        net_obs.accepted.inc();
                        let id = next_id;
                        next_id += 1;
                        obs::trace::span(0, id & 0xFFFF, 0, Kind::Accept, t0, Instant::now());
                        if stream.set_nonblocking(true).is_err() {
                            report.closed += 1;
                            net_obs.closed.inc();
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        if conns.len() >= self.cfg.max_conns {
                            // best-effort refusal notice, then drop
                            report.refused += 1;
                            report.closed += 1;
                            net_obs.closed.inc();
                            let frame = protocol::encode_frame(&Msg::ConnErr {
                                msg: format!("server at capacity ({} connections)", conns.len()),
                            });
                            let mut s = stream;
                            let _ = s.write_all(&frame);
                            continue;
                        }
                        let (reply_tx, reply_rx) = mpsc::channel();
                        net_obs.connections.add(1);
                        conns.push(ConnSlot {
                            stream,
                            conn: Conn::new(),
                            reply_tx,
                            reply_rx,
                            id,
                            eof: false,
                            dead: false,
                        });
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => break, // transient accept failure; retry next tick
                }
            }

            // -- per-connection read / submit / reply / write ----------
            for slot in conns.iter_mut() {
                // read (suppressed under write backpressure and in drain)
                if !slot.dead
                    && !slot.eof
                    && slot.conn.is_open()
                    && !draining
                    && slot.conn.write_backlog() <= self.cfg.max_backlog
                {
                    let t0 = Instant::now();
                    let mut read_bytes = 0u64;
                    loop {
                        match slot.stream.read(&mut buf) {
                            Ok(0) => {
                                slot.eof = true;
                                break;
                            }
                            Ok(n) => {
                                progressed = true;
                                read_bytes += n as u64;
                                match slot.conn.on_bytes(&buf[..n]) {
                                    Ok(msgs) => {
                                        report.frames_in += msgs.len() as u64;
                                        net_obs.frames_in.add(msgs.len() as u64);
                                        let mut violation = None;
                                        for m in msgs {
                                            if let Err(e) =
                                                handle_msg(server, slot, m, &mut report, &net_obs)
                                            {
                                                violation = Some(e);
                                                break;
                                            }
                                        }
                                        if let Some(e) = violation {
                                            protocol_error(slot, &e, &mut report, &net_obs);
                                            break;
                                        }
                                    }
                                    Err(e) => {
                                        protocol_error(slot, &e, &mut report, &net_obs);
                                        break;
                                    }
                                }
                                if n < buf.len() {
                                    break;
                                }
                            }
                            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                            Err(_) => {
                                slot.dead = true;
                                break;
                            }
                        }
                    }
                    if read_bytes > 0 {
                        report.bytes_read += read_bytes;
                        net_obs.bytes_read.add(read_bytes);
                        obs::trace::span(0, slot.id & 0xFFFF, 0, Kind::NetRead, t0, Instant::now());
                    }
                }

                // drain this connection's reply channel
                while let Ok(resp) = slot.reply_rx.try_recv() {
                    progressed = true;
                    if let Some(wire) = slot.conn.take_inflight(resp.id) {
                        slot.conn.queue(&protocol::reply_msg(wire, &resp));
                        report.frames_out += 1;
                        net_obs.frames_out.inc();
                    }
                }

                // flush
                if !slot.dead && slot.conn.write_backlog() > 0 {
                    let t0 = Instant::now();
                    let mut wrote = 0u64;
                    while !slot.conn.pending_write().is_empty() {
                        match slot.stream.write(slot.conn.pending_write()) {
                            Ok(0) => {
                                slot.dead = true;
                                break;
                            }
                            Ok(n) => {
                                progressed = true;
                                wrote += n as u64;
                                slot.conn.consume_written(n);
                            }
                            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                            Err(_) => {
                                slot.dead = true;
                                break;
                            }
                        }
                    }
                    if wrote > 0 {
                        report.bytes_written += wrote;
                        net_obs.bytes_written.add(wrote);
                        obs::trace::span(0, slot.id & 0xFFFF, 0, Kind::NetWrite, t0, Instant::now());
                    }
                }

                if draining && slot.drained() {
                    slot.conn.close();
                }
            }

            // -- reap finished connections -----------------------------
            conns.retain(|s| {
                if s.finished() {
                    report.closed += 1;
                    net_obs.closed.inc();
                    net_obs.connections.add(-1);
                    false
                } else {
                    true
                }
            });

            if let Some(deadline) = drain_deadline {
                if conns.is_empty() || Instant::now() >= deadline {
                    break;
                }
            }
            if !progressed {
                std::thread::sleep(Duration::from_micros(300));
            }
        }

        report.closed += conns.len() as u64;
        for _ in &conns {
            net_obs.closed.inc();
            net_obs.connections.add(-1);
        }
        Ok(report)
    }
}

/// Dispatch one decoded client message. `Err` = protocol violation (the
/// client sent a server-only message): the caller answers `ConnErr` and
/// closes the connection.
fn handle_msg(
    server: &Server,
    slot: &mut ConnSlot,
    msg: Msg,
    report: &mut NetReport,
    net_obs: &NetObs,
) -> Result<()> {
    match msg {
        Msg::Req { id, task, tokens, deadline_us } => {
            let deadline = if deadline_us == 0 {
                None
            } else {
                Some(Instant::now() + Duration::from_micros(deadline_us))
            };
            let task = usize::try_from(task).unwrap_or(usize::MAX);
            let trace = server.submit_routed(task, tokens, deadline, &slot.reply_tx);
            slot.conn.note_inflight(trace, id);
            report.requests += 1;
            net_obs.requests.inc();
            Ok(())
        }
        Msg::Ping { nonce } => {
            slot.conn.queue(&Msg::Pong { nonce });
            report.frames_out += 1;
            net_obs.frames_out.inc();
            Ok(())
        }
        other => anyhow::bail!("client sent a server-only message: {other:?}"),
    }
}

/// Answer a protocol violation: queue a final `ConnErr` (flushed before
/// the socket drops) and close the connection to further input.
fn protocol_error(slot: &mut ConnSlot, err: &anyhow::Error, report: &mut NetReport, o: &NetObs) {
    report.protocol_errors += 1;
    o.protocol_errors.inc();
    slot.conn.queue(&Msg::ConnErr { msg: protocol::clip(format!("{err:#}")) });
    slot.conn.close();
}
