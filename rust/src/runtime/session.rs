//! PJRT session: load HLO-text artifacts, compile once, execute many.
//!
//! The session is manifest-driven: `run("mlp_mcnc02_train", &inputs)`
//! validates every tensor against the manifest spec, marshals to XLA
//! literals, executes on the CPU PJRT client and unpacks the result tuple.
//! Compiled executables are cached per session (compile happens on first
//! use, so benches only pay for what they touch).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::{Data, Tensor};
use crate::util::json::Json;

#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

use super::manifest::{Entry, Manifest};

pub struct Session {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    pub stats: Mutex<SessionStats>,
}

#[derive(Debug, Default, Clone)]
pub struct SessionStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
    pub bytes_to_device: usize,
}

impl Session {
    pub fn open(artifacts: &Path) -> Result<Session> {
        let manifest = Manifest::load(artifacts)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Session {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(SessionStats::default()),
        })
    }

    pub fn open_default() -> Result<Session> {
        Session::open(&super::manifest::artifacts_dir())
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.manifest.get(name)
    }

    /// Compile (or fetch the cached) executable for a manifest entry.
    pub fn load(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(exe));
        }
        let entry = self.manifest.get(name)?;
        let path = self.manifest.hlo_path(entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        {
            let mut st = self.stats.lock().unwrap();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache.lock().unwrap().insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Validate + execute: the main entry point for everything above.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(name, &refs)
    }

    /// `run` over borrowed inputs: callers that assemble a batch from
    /// long-lived tensors (the serving engine's statics + cached merged θ)
    /// marshal straight from the originals instead of deep-copying every
    /// input into an owned `Vec<Tensor>` per call.
    pub fn run_refs(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.manifest.get(name)?;
        validate_inputs(entry, inputs)?;
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|&t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        let parts = root.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let out: Vec<Tensor> = parts
            .into_iter()
            .map(|l| literal_to_tensor(&l))
            .collect::<Result<_>>()?;
        {
            let mut st = self.stats.lock().unwrap();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
            st.bytes_to_device += inputs.iter().map(|t| t.size_bytes()).sum::<usize>();
        }
        if out.len() != entry.outputs.len() {
            bail!("{name}: manifest declares {} outputs, executable returned {}",
                  entry.outputs.len(), out.len());
        }
        Ok(out)
    }

    /// Execute with pre-marshaled literals (hot training loop: static
    /// inputs are converted once and reused across steps — see
    /// `TrainState`). The caller is responsible for shape correctness.
    pub fn run_literals(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let entry = self.manifest.get(name)?;
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        let parts = root.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        let out: Vec<Tensor> = parts
            .into_iter()
            .map(|l| literal_to_tensor(&l))
            .collect::<Result<_>>()?;
        {
            let mut st = self.stats.lock().unwrap();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        if out.len() != entry.outputs.len() {
            bail!("{name}: manifest declares {} outputs, executable returned {}",
                  entry.outputs.len(), out.len());
        }
        Ok(out)
    }

    /// Stage inputs as device buffers (used by the transfer benchmark and
    /// the buffer-resident training loop).
    pub fn to_device(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let lit = tensor_to_literal(t)?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .context("host->device transfer")?;
        Ok(buf)
    }

    /// Execute with device-resident buffers (no host marshaling).
    pub fn run_buffers(
        &self,
        name: &str,
        inputs: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let mut result = exe
            .execute_b::<xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing {name} (buffers)"))?;
        {
            let mut st = self.stats.lock().unwrap();
            st.executions += 1;
            st.execute_secs += t0.elapsed().as_secs_f64();
        }
        Ok(result.swap_remove(0))
    }

    pub fn stats(&self) -> SessionStats {
        self.stats.lock().unwrap().clone()
    }

    /// Per-entry metadata passthrough for bench reporting.
    pub fn meta(&self, name: &str) -> Json {
        self.manifest
            .get(name)
            .map(|e| e.meta.clone())
            .unwrap_or(Json::Null)
    }
}

fn validate_inputs(entry: &Entry, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != entry.inputs.len() {
        bail!(
            "{}: expected {} inputs ({}…), got {}",
            entry.name,
            entry.inputs.len(),
            entry
                .inputs
                .iter()
                .take(4)
                .map(|s| s.name.as_str())
                .collect::<Vec<_>>()
                .join(","),
            inputs.len()
        );
    }
    for (spec, t) in entry.inputs.iter().zip(inputs) {
        if t.dims != spec.shape {
            bail!("{}:{}: shape {:?} != manifest {:?}",
                  entry.name, spec.name, t.dims, spec.shape);
        }
        if t.dtype() != spec.dtype {
            bail!("{}:{}: dtype mismatch", entry.name, spec.name);
        }
    }
    Ok(())
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let (ty, bytes): (xla::ElementType, &[u8]) = match &t.data {
        Data::F32(v) => (xla::ElementType::F32, bytemuck_f32(v)),
        Data::I32(v) => (xla::ElementType::S32, bytemuck_i32(v)),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.dims, bytes)
        .map_err(|e| anyhow!("literal create: {e:?}"))
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.element_type() {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
            Tensor::from_f32(v, &dims)
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
            Tensor::from_i32(v, &dims)
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding or invalid bit patterns as bytes, u8 has
    // alignment 1, and the byte view borrows `v` for the same lifetime.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    // SAFETY: as above — plain-old-data element type viewed as bytes, same
    // length in bytes, same borrow lifetime.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

// Literal round-trips need a real XLA; without `pjrt` the stub errors by
// design, so these tests only build when the feature is on.
#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::manifest::artifacts_dir;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_f32(vec![1.0, -2.5, 3.25, 0.0, 9.0, 7.5], &[2, 3]).unwrap();
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::from_i32(vec![1, -2, 3, 4], &[4]).unwrap();
        let back = literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn run_generator_artifact_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let sess = Session::open(&dir).unwrap();
        let entry = sess.entry("gen_mlp02_fwd").unwrap().clone();
        let inputs: Vec<Tensor> = entry
            .inputs
            .iter()
            .map(|s| Tensor::zeros(&s.shape))
            .collect();
        let out = sess.run("gen_mlp02_fwd", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, entry.outputs[0].shape);
        // zero α, zero β ⇒ zero output (φ(0) = 0 for the sine generator)
        assert_eq!(out[0].f32s().unwrap().iter().filter(|&&x| x != 0.0).count(), 0);
        assert_eq!(sess.stats().compiles, 1);
        // second run hits the executable cache
        sess.run("gen_mlp02_fwd", &inputs).unwrap();
        assert_eq!(sess.stats().compiles, 1);
        assert_eq!(sess.stats().executions, 2);
    }

    #[test]
    fn input_validation_errors() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let sess = Session::open(&dir).unwrap();
        assert!(sess.run("gen_mlp02_fwd", &[]).is_err());
        assert!(sess.run("no_such_exec", &[]).is_err());
    }
}
