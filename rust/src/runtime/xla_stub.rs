//! Offline stub of the `xla` crate surface used by `session.rs` /
//! `train/state.rs`. Compiled when the `pjrt` feature is off (the default:
//! the offline vendor set has no XLA). Every operation fails with a
//! descriptive error, so `Session::open` errors gracefully, `exp::Ctx`
//! returns `None`, and all PJRT-dependent benches/tests skip — the native
//! reconstruction engine (`mcnc::kernel`) is the only execution path.
#![allow(dead_code)]

use std::fmt;

#[derive(Debug, Clone)]
pub struct XlaError(pub &'static str);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} (built without the `pjrt` feature)", self.0)
    }
}

impl std::error::Error for XlaError {}

fn off<T>(what: &'static str) -> Result<T, XlaError> {
    Err(XlaError(what))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Unsupported,
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _bytes: &[u8],
    ) -> Result<Literal, XlaError> {
        off("creating literal")
    }

    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        off("literal shape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        off("literal to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        off("literal to_tuple")
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<HloModuleProto, XlaError> {
        off("parsing HLO text")
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        off("device->host transfer")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        off("executing")
    }

    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        off("executing (buffers)")
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        off("creating PJRT CPU client")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        off("compiling")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _l: &Literal,
    ) -> Result<PjRtBuffer, XlaError> {
        off("host->device transfer")
    }
}
