//! PJRT runtime: manifest-driven artifact loading and execution.
//!
//! `Session` wraps the `xla` crate (PJRT C API, CPU client): HLO text →
//! `HloModuleProto::from_text_file` → compile → execute. `init` synthesizes
//! every initial tensor from a scalar seed (twin of python `initlib`).

pub mod init;
pub mod manifest;
pub mod session;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla_stub;

pub use manifest::{artifacts_dir, Entry, IoSpec, Manifest, RegistryMeta, Role};
pub use session::Session;
