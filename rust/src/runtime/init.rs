//! Synthesize initial tensors from manifest init laws + a scalar seed —
//! the Rust twin of `python/compile/initlib.py` (golden-tested on both
//! sides). Given an executable's manifest entry and a seed, `init_all`
//! produces every static + trainable input; opt-state tensors are zeros.

use anyhow::{anyhow, bail, Result};

use crate::mcnc::generator::GenCfg;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::prng::{tag, Stream};

use super::manifest::{Entry, IoSpec, RegistryMeta, Role};

fn draw(dist: &str, param: f32, n: usize, stream: u64) -> Result<Vec<f32>> {
    let mut s = Stream::new(stream);
    Ok(match dist {
        "zeros" => vec![0.0; n],
        "ones" => vec![1.0; n],
        "sym_uniform" => s.symmetric_f32(n, param),
        "normal" => s.normal_f32(n, param),
        _ => bail!("unknown dist {dist:?}"),
    })
}

fn lora_rank(init: &Json) -> usize {
    init.get("rank").and_then(Json::as_usize).unwrap_or(1)
}

fn lora_a_vec(reg: &RegistryMeta, rank: usize, seed: u64) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    for (j, leaf) in reg.lora_targets().enumerate() {
        let (a, _) = leaf.lora.unwrap();
        let s = crate::util::prng::substream(seed, tag::LORA + j as u64);
        out.extend(draw("sym_uniform", 1.0 / (a as f32).sqrt(), a * rank, s)?);
    }
    Ok(out)
}

/// Build one tensor per its init law.
pub fn init_tensor(
    init: &Json,
    shape: &[usize],
    reg: &RegistryMeta,
    seed: u64,
) -> Result<Tensor> {
    let kind = init
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("init law without kind: {init:?}"))?;
    let n: usize = shape.iter().product();
    let data: Vec<f32> = match kind {
        "zeros" => vec![0.0; n],
        "ones" => vec![1.0; n],
        "sym_uniform" => {
            let bound = init.get("bound").and_then(Json::as_f64).unwrap_or(1.0) as f32;
            let t = init.get("tag").and_then(Json::as_f64).map(|f| f as u64).unwrap_or(tag::COEF);
            draw("sym_uniform", bound, n, crate::util::prng::substream(seed, t))?
        }
        "comp_leaves" => {
            let mut out = Vec::with_capacity(reg.dc);
            for (i, leaf) in reg.comp_leaves().enumerate() {
                let s = crate::util::prng::substream(seed, tag::THETA0 + i as u64);
                out.extend(draw(&leaf.dist, leaf.param, leaf.size(), s)?);
            }
            out
        }
        "raw_leaves" => {
            let mut out = Vec::with_capacity(reg.r);
            for (i, leaf) in reg.raw_leaves().enumerate() {
                let s = crate::util::prng::substream(seed, tag::RAW + i as u64);
                out.extend(draw(&leaf.dist, leaf.param, leaf.size(), s)?);
            }
            if out.is_empty() {
                out.push(0.0); // methods pad empty raw to size 1
            }
            out
        }
        "gen_layer" => {
            let cfg = GenCfg::from_json(
                init.get("gen").ok_or_else(|| anyhow!("gen_layer without gen cfg"))?,
            )?;
            let layer = init.get("layer").and_then(Json::as_usize).unwrap_or(0);
            cfg.make_weights(seed)
                .into_iter()
                .nth(layer)
                .ok_or_else(|| anyhow!("gen layer {layer} out of range"))?
        }
        "lora_a" => lora_a_vec(reg, lora_rank(init), seed)?,
        "lora0" => {
            let rank = lora_rank(init);
            let mut out = lora_a_vec(reg, rank, seed)?;
            let db: usize =
                reg.lora_targets().map(|l| rank * l.lora.unwrap().1).sum();
            out.extend(std::iter::repeat(0.0).take(db));
            out
        }
        "nola_basis" => {
            let m = init.get("m").and_then(Json::as_usize).unwrap_or(1);
            let rank = lora_rank(init);
            let side = init.get("side").and_then(Json::as_str).unwrap_or("a");
            let mut out = Vec::new();
            for (j, leaf) in reg.lora_targets().enumerate() {
                let (a, b) = leaf.lora.unwrap();
                if side == "a" {
                    let s = crate::util::prng::substream(
                        seed, tag::NOLA_BASIS + 2 * j as u64);
                    out.extend(draw("sym_uniform", 1.0 / (a as f32).sqrt(),
                                    m * a * rank, s)?);
                } else {
                    let s = crate::util::prng::substream(
                        seed, tag::NOLA_BASIS + 2 * j as u64 + 1);
                    out.extend(draw("sym_uniform", 1.0 / (rank as f32).sqrt(),
                                    m * rank * b, s)?);
                }
            }
            out
        }
        "nola_coef" => {
            let m = init.get("m").and_then(Json::as_usize).unwrap_or(1);
            let s = crate::util::prng::substream(seed, tag::COEF);
            draw("sym_uniform", 1.0 / (m as f32).sqrt(), n, s)?
        }
        _ => bail!("unknown init kind {kind:?}"),
    };
    if data.len() != n && !shape.is_empty() {
        bail!("init {kind} produced {} values for shape {:?}", data.len(), shape);
    }
    Tensor::from_f32(data, shape)
}

/// Initial values for every static + trainable input of an entry, plus
/// zeroed opt-state tensors, in manifest positional order (hyper/data slots
/// are the caller's).
pub fn init_inputs(entry: &Entry, seed: u64) -> Result<Vec<(IoSpec, Option<Tensor>)>> {
    let reg = entry.registry().unwrap_or_default();
    entry
        .inputs
        .iter()
        .map(|spec| {
            let t = match spec.role {
                Role::Static | Role::Trainable => {
                    let law = spec
                        .init
                        .as_ref()
                        .ok_or_else(|| anyhow!("{}:{} has no init law", entry.name, spec.name))?;
                    Some(init_tensor(law, &spec.shape, &reg, seed)?)
                }
                Role::Opt => Some(Tensor::zeros(&spec.shape)),
                Role::Hyper | Role::Data => None,
            };
            Ok((spec.clone(), t))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LeafMeta;
    use crate::util::json::parse;

    fn reg() -> RegistryMeta {
        RegistryMeta {
            dc: 16,
            r: 3,
            leaves: vec![
                LeafMeta {
                    name: "w".into(),
                    shape: vec![4, 4],
                    compress: true,
                    dist: "sym_uniform".into(),
                    param: 0.5,
                    lora: Some((4, 4)),
                },
                LeafMeta {
                    name: "b".into(),
                    shape: vec![3],
                    compress: false,
                    dist: "zeros".into(),
                    param: 0.0,
                    lora: None,
                },
            ],
        }
    }

    #[test]
    fn comp_leaves_deterministic() {
        let law = parse(r#"{"kind":"comp_leaves"}"#).unwrap();
        let a = init_tensor(&law, &[16], &reg(), 5).unwrap();
        let b = init_tensor(&law, &[16], &reg(), 5).unwrap();
        let c = init_tensor(&law, &[16], &reg(), 6).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.f32s().unwrap().iter().all(|v| v.abs() <= 0.5));
    }

    #[test]
    fn raw_leaves_zero_biases() {
        let law = parse(r#"{"kind":"raw_leaves"}"#).unwrap();
        let t = init_tensor(&law, &[3], &reg(), 1).unwrap();
        assert!(t.f32s().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lora0_a_random_b_zero() {
        let law = parse(r#"{"kind":"lora0","rank":2}"#).unwrap();
        let t = init_tensor(&law, &[16], &reg(), 9).unwrap();
        let v = t.f32s().unwrap();
        assert!(v[..8].iter().any(|&x| x != 0.0)); // A part: 4*2
        assert!(v[8..].iter().all(|&x| x == 0.0)); // B part: 2*4
    }

    #[test]
    fn nola_basis_sides_differ() {
        let a = init_tensor(&parse(r#"{"kind":"nola_basis","side":"a","m":2,"rank":2}"#).unwrap(),
                            &[16], &reg(), 3).unwrap();
        let b = init_tensor(&parse(r#"{"kind":"nola_basis","side":"b","m":2,"rank":2}"#).unwrap(),
                            &[16], &reg(), 3).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn gen_layer_matches_generator() {
        let cfg = GenCfg { k: 3, d: 11, width: 5, depth: 3, ..GenCfg::default() };
        let law = parse(
            r#"{"kind":"gen_layer","layer":1,
                "gen":{"k":3,"d":11,"width":5,"depth":3,"freq":4.5,"act":"sine",
                       "normalize":false,"residual":false,"init":"uniform","init_scale":1.0}}"#,
        )
        .unwrap();
        let t = init_tensor(&law, &[5, 5], &reg(), 21).unwrap();
        assert_eq!(t.f32s().unwrap(), &cfg.make_weights(21)[1][..]);
    }

    #[test]
    fn size_mismatch_rejected() {
        let law = parse(r#"{"kind":"comp_leaves"}"#).unwrap();
        assert!(init_tensor(&law, &[7], &reg(), 5).is_err());
    }

    #[test]
    fn unknown_kind_rejected() {
        let law = parse(r#"{"kind":"wat"}"#).unwrap();
        assert!(init_tensor(&law, &[1], &reg(), 0).is_err());
    }
}
