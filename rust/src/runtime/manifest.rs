//! Typed view of `artifacts/manifest.json` — the contract between the AOT
//! compile path (`python/compile/aot.py`) and the Rust runtime. Every
//! executable's positional inputs/outputs, init laws and experiment
//! metadata come from here; nothing about tensor layouts is hard-coded.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::DType;
use crate::util::json::{self, Json};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Static,
    Trainable,
    Opt,
    Hyper,
    Data,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "static" => Role::Static,
            "trainable" => Role::Trainable,
            "opt" => Role::Opt,
            "hyper" => Role::Hyper,
            "data" => Role::Data,
            _ => bail!("unknown role {s:?}"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub role: Role,
    pub init: Option<Json>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct OutSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Per-leaf metadata of the model the executable was built for.
#[derive(Debug, Clone)]
pub struct LeafMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub compress: bool,
    pub dist: String,
    pub param: f32,
    pub lora: Option<(usize, usize)>,
}

impl LeafMeta {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, Default)]
pub struct RegistryMeta {
    pub dc: usize,
    pub r: usize,
    pub leaves: Vec<LeafMeta>,
}

impl RegistryMeta {
    pub fn comp_leaves(&self) -> impl Iterator<Item = &LeafMeta> {
        self.leaves.iter().filter(|l| l.compress)
    }

    pub fn raw_leaves(&self) -> impl Iterator<Item = &LeafMeta> {
        self.leaves.iter().filter(|l| !l.compress)
    }

    pub fn lora_targets(&self) -> impl Iterator<Item = &LeafMeta> {
        self.leaves.iter().filter(|l| l.compress && l.lora.is_some())
    }
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub file: String,
    pub group: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<OutSpec>,
    pub meta: Json,
}

impl Entry {
    pub fn kind(&self) -> &str {
        self.meta.get("kind").and_then(Json::as_str).unwrap_or("")
    }

    pub fn count_role(&self, role: Role) -> usize {
        self.inputs.iter().filter(|s| s.role == role).count()
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    pub fn registry(&self) -> Result<RegistryMeta> {
        let reg = self
            .meta
            .get("registry")
            .ok_or_else(|| anyhow!("{}: no registry in meta", self.name))?;
        let mut leaves = Vec::new();
        for l in reg.get("leaves").and_then(Json::as_arr).unwrap_or(&[]) {
            let lora = match l.get("lora") {
                Some(Json::Arr(a)) if a.len() == 2 => Some((
                    a[0].as_usize().unwrap_or(0),
                    a[1].as_usize().unwrap_or(0),
                )),
                _ => None,
            };
            leaves.push(LeafMeta {
                name: l.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                shape: l.get("shape").map(Json::usize_vec).unwrap_or_default(),
                compress: l.get("compress").and_then(Json::as_bool).unwrap_or(false),
                dist: l.get("dist").and_then(Json::as_str).unwrap_or("zeros").to_string(),
                param: l.get("param").and_then(Json::as_f64).unwrap_or(0.0) as f32,
                lora,
            });
        }
        Ok(RegistryMeta {
            dc: reg.get("Dc").and_then(Json::as_usize).unwrap_or(0),
            r: reg.get("R").and_then(Json::as_usize).unwrap_or(0),
            leaves,
        })
    }

    /// Experiment accounting from the compile-time meta.
    pub fn rate(&self) -> f64 {
        self.meta.get("rate").and_then(Json::as_f64).unwrap_or(f64::NAN)
    }

    pub fn trainable_comp(&self) -> usize {
        self.meta.get("trainable_comp").and_then(Json::as_usize).unwrap_or(0)
    }

    pub fn recon_flops(&self) -> usize {
        self.meta.get("recon_flops").and_then(Json::as_usize).unwrap_or(0)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: HashMap<String, Entry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} (run `make artifacts` first)", path.display())
        })?;
        let j = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut entries = HashMap::new();
        for (name, e) in j.get("entries").and_then(Json::as_obj).unwrap_or(&[]) {
            entries.insert(name.clone(), parse_entry(name, e)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("executable {name:?} not in manifest"))
    }

    pub fn names_in_group(&self, group: &str) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .entries
            .values()
            .filter(|e| e.group == group)
            .map(|e| e.name.as_str())
            .collect();
        v.sort();
        v
    }

    pub fn hlo_path(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

fn parse_entry(name: &str, e: &Json) -> Result<Entry> {
    let mut inputs = Vec::new();
    for s in e.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
        inputs.push(IoSpec {
            name: s.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            shape: s.get("shape").map(Json::usize_vec).unwrap_or_default(),
            dtype: DType::parse(s.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?,
            role: Role::parse(s.get("role").and_then(Json::as_str).unwrap_or("static"))?,
            init: s.get("init").filter(|v| !v.is_null()).cloned(),
        });
    }
    let mut outputs = Vec::new();
    for s in e.get("outputs").and_then(Json::as_arr).unwrap_or(&[]) {
        outputs.push(OutSpec {
            name: s.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            shape: s.get("shape").map(Json::usize_vec).unwrap_or_default(),
            dtype: DType::parse(s.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?,
        });
    }
    Ok(Entry {
        name: name.to_string(),
        file: e
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("{name}: no file"))?
            .to_string(),
        group: e.get("group").and_then(Json::as_str).unwrap_or("").to_string(),
        inputs,
        outputs,
        meta: e.get("meta").cloned().unwrap_or(Json::Null),
    })
}

/// Artifact directory resolution: `MCNC_ARTIFACTS` env or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("MCNC_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        json::parse(
            r#"{"entries": {"x_train": {"file": "x.hlo.txt", "group": "core",
            "inputs": [
              {"name":"theta0_c","shape":[10],"dtype":"f32","role":"static","init":{"kind":"comp_leaves"}},
              {"name":"alpha","shape":[2,3],"dtype":"f32","role":"trainable","init":{"kind":"zeros"}},
              {"name":"y","shape":[4],"dtype":"i32","role":"data","init":null}],
            "outputs": [{"name":"loss","shape":[],"dtype":"f32"}],
            "meta": {"kind":"train_step","rate":0.01,"trainable_comp":8,
                     "registry":{"Dc":10,"R":2,"leaves":[
                       {"name":"w","shape":[2,5],"compress":true,"dist":"sym_uniform","param":0.5,"lora":[2,5]},
                       {"name":"b","shape":[2],"compress":false,"dist":"zeros","param":0.0,"lora":null}]}}}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_entry() {
        let j = sample();
        let (name, e) = &j.get("entries").unwrap().as_obj().unwrap()[0];
        let entry = parse_entry(name, e).unwrap();
        assert_eq!(entry.kind(), "train_step");
        assert_eq!(entry.inputs.len(), 3);
        assert_eq!(entry.inputs[1].shape, vec![2, 3]);
        assert_eq!(entry.inputs[2].dtype, DType::I32);
        assert_eq!(entry.count_role(Role::Trainable), 1);
        assert!(entry.inputs[2].init.is_none());
        assert_eq!(entry.input_index("alpha"), Some(1));
        assert!((entry.rate() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn registry_parses() {
        let j = sample();
        let (name, e) = &j.get("entries").unwrap().as_obj().unwrap()[0];
        let reg = parse_entry(name, e).unwrap().registry().unwrap();
        assert_eq!(reg.dc, 10);
        assert_eq!(reg.r, 2);
        assert_eq!(reg.comp_leaves().count(), 1);
        assert_eq!(reg.lora_targets().next().unwrap().lora, Some((2, 5)));
        assert_eq!(reg.leaves[0].size(), 10);
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.len() > 50, "expected the full catalog");
        let e = m.get("mlp_mcnc02_train").unwrap();
        assert_eq!(e.kind(), "train_step");
        assert!(m.hlo_path(e).exists());
        let reg = e.registry().unwrap();
        assert_eq!(reg.dc, 268800);
    }
}
