//! SplitMix64 deterministic parameter streams — the Rust twin of
//! `python/compile/rng.py`. Both sides must produce **bit-identical** f32
//! streams from the same seed: all θ0 / generator-weight / basis tensors fed
//! to the PJRT executables are synthesized here, and the Python tests pin
//! the same constants.
//!
//! Output `i` of stream `s` is `mix(s + (i+1)·GAMMA)` — counter-based, so
//! any range of a stream can be generated independently and in parallel.
//! f32 uniforms take the top 24 bits (`(x >> 40) * 2^-24`) so the f32 math
//! is exact across numpy and Rust.

pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;
pub const TAG_MUL: u64 = 0xBF58_476D_1CE4_E5B9;

/// Well-known stream tags shared with `python/compile/rng.py`. Keep in sync.
pub mod tag {
    pub const GEN_LAYER: u64 = 0x4745_4E00; // + layer index
    pub const THETA0: u64 = 0x5448_0000; // + compressed-leaf index
    pub const RAW: u64 = 0x5241_5700; // + raw-leaf index
    pub const LORA: u64 = 0x4C4F_5200; // + lora-target index (A factors)
    pub const NOLA_BASIS: u64 = 0x4E4F_4C00; // + 2*target (A) / 2*target+1 (B)
    pub const COEF: u64 = 0x434F_4500;
    pub const DATA: u64 = 0x4441_5400;
    pub const SPHERE: u64 = 0x5350_4800;
    pub const ALPHA: u64 = 0x414C_5000;
    pub const PROJ: u64 = 0x5052_4A00;
}

/// The splitmix64 finalizer.
#[inline]
pub fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive an independent stream seed for (seed, tag).
#[inline]
pub fn substream(seed: u64, tag: u64) -> u64 {
    mix(seed ^ tag.wrapping_mul(TAG_MUL))
}

/// The `i`-th raw u64 of stream `seed` (0-based).
#[inline]
pub fn raw_at(seed: u64, i: u64) -> u64 {
    mix(seed.wrapping_add((i + 1).wrapping_mul(GAMMA)))
}

/// A cheap iterator-style handle over one stream.
#[derive(Debug, Clone, Copy)]
pub struct Stream {
    seed: u64,
    i: u64,
}

impl Stream {
    pub fn new(seed: u64) -> Self {
        Stream { seed, i: 0 }
    }

    pub fn sub(seed: u64, t: u64) -> Self {
        Stream::new(substream(seed, t))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let v = raw_at(self.seed, self.i);
        self.i += 1;
        v
    }

    /// f32 uniform in [0, 1) — bit-identical to the Python twin.
    #[inline]
    pub fn next_unit_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / 16_777_216.0)
    }

    pub fn uniform_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_unit_f32() * (hi - lo) + lo).collect()
    }

    /// f32 uniform in [-bound, bound) — the generator-weight law.
    pub fn symmetric_f32(&mut self, n: usize, bound: f32) -> Vec<f32> {
        (0..n).map(|_| (2.0f32 * self.next_unit_f32() - 1.0) * bound).collect()
    }

    /// Box–Muller normals; matches Python to ~1e-5 (libm sin/cos ulp).
    pub fn normal_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        let m = (n + 1) / 2;
        let u: Vec<u64> = (0..2 * m).map(|_| self.next_u64()).collect();
        let mut out = Vec::with_capacity(2 * m);
        for j in 0..m {
            let u1 = ((u[j] >> 40) as f64 + 1.0) * (1.0 / 16_777_216.0);
            let u2 = (u[m + j] >> 40) as f64 * (1.0 / 16_777_216.0);
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            out.push((r * th.cos()) as f32 * std);
            out.push((r * th.sin()) as f32 * std);
        }
        out.truncate(n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_seed0() {
        // Canonical splitmix64 outputs; same constants live in
        // python/tests/test_rng.py — if either side changes, both fail.
        assert_eq!(raw_at(0, 0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(raw_at(0, 1), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(raw_at(0, 2), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn golden_seed42() {
        assert_eq!(raw_at(42, 0), 0xBDD7_3226_2FEB_6E95);
        assert_eq!(raw_at(42, 1), 0x28EF_E333_B266_F103);
    }

    #[test]
    fn stream_matches_raw_at() {
        let mut s = Stream::new(7);
        for i in 0..10 {
            assert_eq!(s.next_u64(), raw_at(7, i));
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let u = Stream::new(123).uniform_f32(10_000, 0.0, 1.0);
        assert!(u.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean: f32 = u.iter().sum::<f32>() / u.len() as f32;
        assert!((mean - 0.5).abs() < 0.02);
    }

    #[test]
    fn symmetric_bounds() {
        let s = Stream::new(9).symmetric_f32(5000, 0.25);
        assert!(s.iter().all(|&x| x.abs() <= 0.25));
        assert!(s.iter().cloned().fold(f32::MIN, f32::max) > 0.2);
        assert!(s.iter().cloned().fold(f32::MAX, f32::min) < -0.2);
    }

    #[test]
    fn normal_moments() {
        let z = Stream::new(11).normal_f32(100_000, 2.0);
        let mean: f64 = z.iter().map(|&x| x as f64).sum::<f64>() / z.len() as f64;
        let var: f64 =
            z.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / z.len() as f64;
        assert!(mean.abs() < 0.05);
        assert!((var.sqrt() - 2.0).abs() < 0.05);
    }

    #[test]
    fn substream_independence() {
        assert_ne!(substream(7, tag::THETA0), substream(7, tag::THETA0 + 1));
        assert_eq!(substream(7, tag::THETA0), substream(7, tag::THETA0));
        assert_ne!(substream(7, tag::THETA0), substream(8, tag::THETA0));
    }

    #[test]
    fn prefix_stability() {
        let mut a = Stream::new(5);
        let long = a.uniform_f32(1000, 0.0, 1.0);
        let mut b = Stream::new(5);
        let short = b.uniform_f32(10, 0.0, 1.0);
        assert_eq!(&long[..10], &short[..]);
    }

    #[test]
    fn normal_odd_lengths() {
        for n in [0usize, 1, 2, 7] {
            assert_eq!(Stream::new(3).normal_f32(n, 1.0).len(), n);
        }
    }
}
