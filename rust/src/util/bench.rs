//! Benchmark harness (in-tree substrate; no criterion offline).
//!
//! `time_it` measures a closure with warmup + repeated samples and returns
//! robust statistics; `Table` renders paper-style result tables to stdout
//! and CSV (EXPERIMENTS.md records the CSV outputs).

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: Vec<f64>, // seconds
}

impl Stats {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / self.samples.len().max(1) as f64)
            .sqrt()
    }
}

/// Time `f` with `warmup` discarded runs then `samples` measured runs.
pub fn time_it(warmup: usize, samples: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    Stats { samples: out }
}

/// Throughput helper: items/sec from a stats object.
pub fn throughput(items: usize, s: &Stats) -> f64 {
    items as f64 / s.mean().max(1e-12)
}

pub fn fmt_si(x: f64) -> String {
    let a = x.abs();
    if a >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if a >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{:.3}", x)
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.2}s", secs)
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}µs", secs * 1e6)
    }
}

/// A paper-style results table: header + rows, markdown to stdout + CSV.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let hdr: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{:w$}", c, w = w))
            .collect();
        println!("| {} |", hdr.join(" | "));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for r in &self.rows {
            let cells: Vec<String> = r
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{:w$}", c, w = w))
                .collect();
            println!("| {} |", cells.join(" | "));
        }
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",") + "\n";
        for r in &self.rows {
            s += &r.join(",");
            s += "\n";
        }
        s
    }

    /// Write CSV next to the bench outputs (`results/<slug>.csv`).
    pub fn save_csv(&self, slug: &str) {
        let dir = std::path::Path::new("results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{slug}.csv"));
        if std::fs::write(&path, self.to_csv()).is_ok() {
            println!("[bench] wrote {}", path.display());
        }
    }

    pub fn to_json(&self) -> String {
        use crate::util::json::{self, Json};
        let cols = Json::Arr(self.columns.iter().map(|c| Json::str(c.clone())).collect());
        let rows = Json::Arr(
            self.rows
                .iter()
                .map(|r| Json::Arr(r.iter().map(|c| Json::str(c.clone())).collect()))
                .collect(),
        );
        json::to_string(&Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("columns", cols),
            ("rows", rows),
        ]))
    }

    /// Machine-readable bench trajectory: `BENCH_<slug>.json` at the repo
    /// root, so successive PRs can diff perf without parsing stdout/CSV.
    pub fn save_json(&self, slug: &str) {
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{slug}.json"));
        match std::fs::write(&path, self.to_json()) {
            Ok(()) => println!("[bench] wrote {}", path.display()),
            Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
        }
    }
}

/// Env-tunable step counts so quick CI runs and full reproductions share one
/// binary: `MCNC_BENCH_STEPS` scales everything, `MCNC_BENCH_FULL=1` uses
/// the paper-fidelity defaults.
pub fn bench_steps(quick_default: usize, full: usize) -> usize {
    if std::env::var("MCNC_BENCH_FULL").map(|v| v == "1").unwrap_or(false) {
        return full;
    }
    std::env::var("MCNC_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(quick_default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats { samples: vec![1.0, 2.0, 3.0, 4.0, 5.0] };
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.median() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert!((s.percentile(100.0) - 5.0).abs() < 1e-12);
        assert!(s.std() > 1.0 && s.std() < 2.0);
    }

    #[test]
    fn time_it_runs() {
        let mut n = 0usize;
        let s = time_it(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.samples.len(), 5);
        assert!(throughput(10, &s) > 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_si(2_500_000.0), "2.50M");
        assert_eq!(fmt_si(1.5e10), "15.00G");
        assert!(fmt_time(0.002).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }

    #[test]
    fn table_csv() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,x\n");
        t.print(); // smoke: must not panic
    }

    #[test]
    fn table_json_roundtrips() {
        let mut t = Table::new("perf", &["target", "value"]);
        t.row(vec!["gen \"fast\"".into(), "1.5M".into()]);
        let j = crate::util::json::parse(&t.to_json()).unwrap();
        assert_eq!(j.get("title").unwrap().as_str().unwrap(), "perf");
        assert_eq!(j.get("columns").unwrap().as_arr().unwrap().len(), 2);
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].as_arr().unwrap()[0].as_str().unwrap(), "gen \"fast\"");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
