//! Leveled stderr logger (in-tree substrate). `MCNC_LOG=debug|info|warn`.

use std::sync::atomic::{AtomicU8, Ordering};

pub const DEBUG: u8 = 0;
pub const INFO: u8 = 1;
pub const WARN: u8 = 2;

static LEVEL: AtomicU8 = AtomicU8::new(1);

pub fn init_from_env() {
    let lvl = match std::env::var("MCNC_LOG").as_deref() {
        Ok("debug") => DEBUG,
        Ok("warn") => WARN,
        _ => INFO,
    };
    LEVEL.store(lvl, Ordering::Relaxed);
}

pub fn set_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

pub fn enabled(l: u8) -> bool {
    l >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: u8, tag: &str, msg: std::fmt::Arguments) {
    if enabled(level) {
        let name = match level {
            DEBUG => "DBG",
            INFO => "INF",
            _ => "WRN",
        };
        eprintln!("[{name}][{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! debug {
    ($tag:expr, $($arg:tt)+) => {
        $crate::util::logging::log($crate::util::logging::DEBUG, $tag, format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($tag:expr, $($arg:tt)+) => {
        $crate::util::logging::log($crate::util::logging::INFO, $tag, format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($tag:expr, $($arg:tt)+) => {
        $crate::util::logging::log($crate::util::logging::WARN, $tag, format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(WARN);
        assert!(!enabled(INFO));
        assert!(enabled(WARN));
        set_level(INFO);
        assert!(enabled(INFO));
        crate::info!("test", "hello {}", 1); // smoke
    }
}
