//! Leveled stderr logger (in-tree substrate).
//! `MCNC_LOG=debug|info|warn|off`.
//!
//! Lines carry a monotonic process-uptime timestamp and an optional
//! per-thread context prefix (shard id, trace id) set by the owning
//! loop, e.g.:
//!
//! ```text
//! [   12.042s][WRN][shard 2][obs] shard 2: restart cause: crashed
//! ```
//!
//! WARN-worthy *structured* events on the serving path (breaker open,
//! shard restart, drain of a dead shard) are routed through
//! `crate::obs::trace::event`, which logs here at WARN **and** drops an
//! instant record into the trace ring so the event shows up on the
//! shard's trace track.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

pub const DEBUG: u8 = 0;
pub const INFO: u8 = 1;
pub const WARN: u8 = 2;
/// Sentinel level above WARN: nothing is emitted.
pub const OFF: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(1);

static START: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static CONTEXT: std::cell::RefCell<String> = const { std::cell::RefCell::new(String::new()) };
}

/// Monotonic elapsed time since the logger first ran (process uptime for
/// all practical purposes; `init_from_env` pins it at startup).
pub fn uptime() -> Duration {
    START.get_or_init(Instant::now).elapsed()
}

pub fn init_from_env() {
    uptime(); // pin the epoch so timestamps start near zero
    let lvl = match std::env::var("MCNC_LOG").as_deref() {
        Ok("debug") => DEBUG,
        Ok("warn") => WARN,
        Ok("off") => OFF,
        _ => INFO,
    };
    LEVEL.store(lvl, Ordering::Relaxed);
}

pub fn set_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

pub fn enabled(l: u8) -> bool {
    l >= LEVEL.load(Ordering::Relaxed) && LEVEL.load(Ordering::Relaxed) != OFF
}

/// Install this thread's context prefix (e.g. `"shard 2"` from the shard
/// loop, `"shard 2 trace 17"` while holding a request). Empty clears it.
pub fn set_thread_context(ctx: &str) {
    CONTEXT.with(|c| {
        let mut c = c.borrow_mut();
        c.clear();
        c.push_str(ctx);
    });
}

pub fn log(level: u8, tag: &str, msg: std::fmt::Arguments) {
    if enabled(level) {
        let name = match level {
            DEBUG => "DBG",
            INFO => "INF",
            _ => "WRN",
        };
        let t = uptime().as_secs_f64();
        CONTEXT.with(|c| {
            let c = c.borrow();
            if c.is_empty() {
                eprintln!("[{t:>9.3}s][{name}][{tag}] {msg}");
            } else {
                eprintln!("[{t:>9.3}s][{name}][{c}][{tag}] {msg}");
            }
        });
    }
}

#[macro_export]
macro_rules! debug {
    ($tag:expr, $($arg:tt)+) => {
        $crate::util::logging::log($crate::util::logging::DEBUG, $tag, format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($tag:expr, $($arg:tt)+) => {
        $crate::util::logging::log($crate::util::logging::INFO, $tag, format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($tag:expr, $($arg:tt)+) => {
        $crate::util::logging::log($crate::util::logging::WARN, $tag, format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(WARN);
        assert!(!enabled(INFO));
        assert!(enabled(WARN));
        set_level(OFF);
        assert!(!enabled(WARN), "off silences everything");
        assert!(!enabled(OFF));
        set_level(INFO);
        assert!(enabled(INFO));
        crate::info!("test", "hello {}", 1); // smoke
    }

    #[test]
    fn uptime_is_monotone() {
        let a = uptime();
        let b = uptime();
        assert!(b >= a);
    }

    #[test]
    fn thread_context_is_thread_local() {
        set_thread_context("shard 9");
        CONTEXT.with(|c| assert_eq!(&*c.borrow(), "shard 9"));
        let h = std::thread::spawn(|| CONTEXT.with(|c| c.borrow().clone()));
        assert_eq!(h.join().expect("ctx thread"), "", "fresh thread has no context");
        set_thread_context("");
        CONTEXT.with(|c| assert!(c.borrow().is_empty()));
        crate::warn!("test", "context smoke"); // smoke: prints with no prefix
    }
}
