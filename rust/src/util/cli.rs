//! Tiny CLI argument parser (in-tree substrate; no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    order: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                // `next_if` both peeks and consumes, so a flag at the end
                // of the line can never hit a panicking `next().unwrap()`
                let (key, val) = if let Some((k, v)) = rest.split_once('=') {
                    (k.to_string(), v.to_string())
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    (rest.to_string(), v)
                } else {
                    (rest.to_string(), "true".to_string())
                };
                out.order.push(key.clone());
                out.flags.insert(key, val);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Value of `--key`, or an error naming the missing flag — commands
    /// with mandatory flags should use this instead of panicking accessors.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u32_or(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }

    /// Keys in first-seen order (for help/debug output).
    pub fn keys(&self) -> &[String] {
        &self.order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_forms() {
        // NB: a bare `--flag` greedily consumes a following non-`--` token
        // as its value; pass `--flag=true` or put bare flags last.
        let a = args("train extra --steps 100 --lr=0.01 --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!((a.f32_or("lr", 0.0) - 0.01).abs() < 1e-9);
        assert!(a.bool_or("verbose", false));
        assert!(!a.has("missing"));
    }

    #[test]
    fn bare_flag_consumes_next_token() {
        let a = args("--verbose extra");
        assert_eq!(a.get("verbose"), Some("extra"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn flag_before_flag_is_bare() {
        let a = args("--fast --steps 5");
        assert!(a.bool_or("fast", false));
        assert_eq!(a.usize_or("steps", 0), 5);
    }

    #[test]
    fn defaults_apply() {
        let a = args("");
        assert_eq!(a.str_or("name", "dflt"), "dflt");
        assert_eq!(a.u64_or("seed", 42), 42);
        assert_eq!(a.u32_or("retry", 3), 3);
        assert!(!a.bool_or("x", false));
    }

    #[test]
    fn u32_parses_and_falls_back() {
        let a = args("--retry 5 --breaker not-a-number");
        assert_eq!(a.u32_or("retry", 0), 5);
        assert_eq!(a.u32_or("breaker", 2), 2);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args("--bias=-0.5");
        assert!((a.f32_or("bias", 0.0) + 0.5).abs() < 1e-9);
    }

    #[test]
    fn trailing_bare_flag_never_panics() {
        let a = args("run --steps 5 --verbose");
        assert_eq!(a.usize_or("steps", 0), 5);
        assert!(a.bool_or("verbose", false));
    }

    #[test]
    fn require_names_the_flag() {
        let a = args("--exec mlp");
        assert_eq!(a.require("exec").unwrap(), "mlp");
        let err = a.require("ckpt").unwrap_err();
        assert!(err.to_string().contains("--ckpt"), "{err}");
    }
}
