//! Minimal JSON: recursive-descent parser + serializer.
//!
//! In-tree substrate (the offline vendor set has no serde facade). Covers
//! the full JSON grammar the artifact manifest, checkpoints and metric logs
//! use: objects, arrays, strings (with escapes), f64 numbers, bools, null.
//! Object key order is preserved (Vec of pairs) so round-trips are stable.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["meta", "gen", "k"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: `[1,2,3]` → `vec![1,2,3]` (empty on type mismatch).
    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected eof")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or("eof in string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or("eof in escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            // (surrogate pairs unsupported — not emitted by our writers)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // copy raw utf-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf-8 in string")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad num")?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {:?} at byte {}", s, start))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{}", n);
            }
        }
        Json::Str(s) => write_str(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(v, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_json(v, out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a": [1, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("x")
        );
        assert!(j.get("c").unwrap().is_null());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let orig = Json::Str("a\"b\\c\nd\te\u{1}f".into());
        let back = parse(&to_string(&orig)).unwrap();
        assert_eq!(orig, back);
    }

    #[test]
    fn unicode_passthrough() {
        let j = parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
        assert_eq!(parse(&to_string(&j)).unwrap(), j);
    }

    #[test]
    fn roundtrip_preserves_key_order() {
        let src = r#"{"z":1,"a":2,"m":[true,false]}"#;
        assert_eq!(to_string(&parse(src).unwrap()), src);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Json::Num(5.0)), "5");
        assert_eq!(to_string(&Json::Num(5.5)), "5.5");
    }

    #[test]
    fn whitespace_tolerated() {
        let j = parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(j.at(&["a"]).unwrap().usize_vec(), vec![1, 2]);
    }
}
