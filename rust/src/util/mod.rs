//! In-tree substrates: this workspace builds fully offline against a small
//! vendored crate set, so JSON, config parsing, CLI, PRNG, thread pool,
//! property testing, benchmarking and logging are implemented here.

pub mod bench;
pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod prng;
pub mod prop;
pub mod threadpool;
