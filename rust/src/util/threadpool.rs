//! Fixed-size thread pool over std::sync::mpsc (in-tree substrate; no tokio
//! offline), plus a lazily-initialized process-wide pool with a scoped
//! `parallel_for` — the substrate under the generator's blocked-GEMM
//! reconstruction hot path (no per-call thread spawns).

use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, built on first use with one worker per core
/// (`MCNC_THREADS` overrides the size; [`configure_global`] overrides both
/// if it runs before first use).
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| {
        let n = std::env::var("MCNC_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n: &usize| n > 0)
            .unwrap_or_else(|| thread::available_parallelism().map(|p| p.get()).unwrap_or(1));
        ThreadPool::new(n)
    })
}

/// Explicitly size the global pool (the `--threads` flag). Must run before
/// the first [`global`] call; returns `false` (and changes nothing) if the
/// pool was already built — callers should warn, since a pinned bench run
/// that silently used core-count workers is not reproducible.
pub fn configure_global(n: usize) -> bool {
    if GLOBAL.get().is_some() {
        return false;
    }
    GLOBAL.set(ThreadPool::new(n.max(1))).is_ok()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("mcnc-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }

    /// Run `f` over `items` in parallel, preserving order of results.
    /// A panic in `f` is caught on the worker (keeping the pool intact)
    /// and resumed on the caller with its original payload, mirroring
    /// [`ThreadPool::parallel_for`].
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<R>)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut first_panic = None;
        for (i, r) in rx {
            match r {
                Ok(v) => out[i] = Some(v),
                Err(p) => {
                    first_panic.get_or_insert(p);
                }
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
        out.into_iter().map(|o| o.expect("worker result lost")).collect()
    }

    /// Scoped data-parallel loop: split `[0, n)` into contiguous blocks of
    /// at least `min_block` items (at most one block per worker), run
    /// `f(start, end)` on the pool, and return once every block completes.
    /// Degenerates to an inline call when one block suffices, so callers
    /// can use it unconditionally on tiny inputs.
    ///
    /// Blocks until completion, which is what makes the lifetime erasure
    /// below sound: no worker can touch `f` (or anything it borrows) after
    /// this function returns.
    pub fn parallel_for(&self, n: usize, min_block: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if n == 0 {
            return;
        }
        let blocks = (n / min_block.max(1)).clamp(1, self.len().max(1));
        if blocks <= 1 {
            f(0, n);
            return;
        }
        let per = n.div_ceil(blocks);
        // SAFETY: jobs only run while this call blocks on the completion
        // channel, so extending the borrow to 'static never outlives `f`.
        let f_static: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(f) };
        // a panic in `f` is caught on the worker (keeping the pool intact)
        // and resumed on the caller with its original payload
        let (tx, rx) = mpsc::channel::<std::thread::Result<()>>();
        let mut sent = 0usize;
        let mut start = 0usize;
        while start < n {
            let end = (start + per).min(n);
            let tx = tx.clone();
            self.execute(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    f_static(start, end)
                }));
                let _ = tx.send(r);
            });
            sent += 1;
            start = end;
        }
        drop(tx);
        let mut done = 0usize;
        let mut first_panic = None;
        for r in rx {
            done += 1;
            if let Err(p) = r {
                first_panic.get_or_insert(p);
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
        assert_eq!(done, sent, "parallel_for: lost a completion signal");
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_propagates_panics_and_keeps_workers() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = pool.map(vec![0usize, 1, 2, 3], |x| {
                if x == 2 {
                    panic!("boom in map");
                }
                x
            });
        }));
        let payload = caught.unwrap_err();
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom in map", "original payload must survive");
        // the pool must still be fully operational afterwards
        let out = pool.map((0..20).collect::<Vec<_>>(), |x| x + 1);
        assert_eq!(out, (1..=20).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(20)));
        drop(pool); // must not hang
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let pool = ThreadPool::new(4);
        for n in [0usize, 1, 7, 64, 100] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(n, 1, &|s, e| {
                assert!(s < e && e <= n);
                for h in &hits[s..e] {
                    h.fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "n={n}");
        }
    }

    #[test]
    fn parallel_for_respects_min_block_inline() {
        let pool = ThreadPool::new(4);
        // one block: must run inline on the calling thread
        let me = std::thread::current().id();
        let ran = AtomicUsize::new(0);
        pool.parallel_for(5, 100, &|s, e| {
            assert_eq!((s, e), (0, 5));
            assert_eq!(std::thread::current().id(), me);
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_for_borrows_caller_state() {
        let pool = ThreadPool::new(3);
        let data: Vec<usize> = (0..1000).collect();
        let sum = AtomicUsize::new(0);
        pool.parallel_for(data.len(), 10, &|s, e| {
            let part: usize = data[s..e].iter().sum();
            sum.fetch_add(part, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 1000 * 999 / 2);
    }

    #[test]
    fn parallel_for_propagates_panics_and_keeps_workers() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(8, 1, &|s, _| {
                if s == 0 {
                    panic!("boom in block");
                }
            });
        }));
        let payload = caught.unwrap_err();
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "boom in block", "original payload must survive");
        // the pool must still be fully operational afterwards
        let total = AtomicUsize::new(0);
        pool.parallel_for(16, 1, &|s, e| {
            total.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn global_pool_is_shared_and_parallelizes() {
        let a = global() as *const ThreadPool;
        let b = global() as *const ThreadPool;
        assert_eq!(a, b);
        assert!(!global().is_empty());
        let total = AtomicUsize::new(0);
        global().parallel_for(128, 1, &|s, e| {
            total.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 128);
    }

    #[test]
    fn configure_global_after_first_use_is_refused() {
        // force the pool into existence first so the test is deterministic
        // under parallel test scheduling, then the late override must be
        // rejected and the pool size must stay put
        let before = global().len();
        assert!(!configure_global(before + 3));
        assert_eq!(global().len(), before);
    }
}
