//! Fixed-size thread pool over std::sync::mpsc (in-tree substrate; no tokio
//! offline). Used by the coordinator's server loop and the data prefetcher.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("mcnc-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }

    /// Run `f` over `items` in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker panicked")).collect()
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(20)));
        drop(pool); // must not hang
    }
}
