//! TOML-subset config parser (in-tree substrate).
//!
//! Supports the fragment real deployment configs need: `[table]` and
//! `[table.sub]` headers, `key = value` with strings, ints, floats, bools
//! and flat arrays, plus `#` comments. Values land in a flat
//! `section.key → Value` map with typed accessors and defaults.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct Config {
    map: HashMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut map = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let h = h
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: bad section header", lineno + 1))?;
                section = h.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            let val = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            map.insert(key, val);
        }
        Ok(Config { map })
    }

    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        match self.map.get(key) {
            Some(Value::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        match self.map.get(key) {
            Some(Value::Int(i)) if *i >= 0 => *i as usize,
            _ => default,
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        match self.map.get(key) {
            Some(Value::Int(i)) if *i >= 0 => *i as u64,
            _ => default,
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.map.get(key).and_then(Value::as_f64).map(|f| f as f32).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.map.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn f32_list(&self, key: &str) -> Vec<f32> {
        match self.map.get(key) {
            Some(Value::Arr(a)) => {
                a.iter().filter_map(Value::as_f64).map(|f| f as f32).collect()
            }
            _ => Vec::new(),
        }
    }

    pub fn keys(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.map.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    /// Overlay: values in `other` win.
    pub fn merged(mut self, other: Config) -> Config {
        self.map.extend(other.map);
        self
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.starts_with('"') {
        let inner = s
            .strip_prefix('"')
            .and_then(|x| x.strip_suffix('"'))
            .context("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .context("unterminated array")?;
        let mut out = Vec::new();
        for part in inner.split(',') {
            let p = part.trim();
            if !p.is_empty() {
                out.push(parse_value(p)?);
            }
        }
        return Ok(Value::Arr(out));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unparseable value")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
name = "mcnc"
seed = 42

[train]
steps = 500
lr = 0.05            # paper: 5-10x dense lr
rates = [0.5, 0.1, 0.01]
verbose = true

[server.batcher]
max_batch = 16
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "mcnc");
        assert_eq!(c.u64_or("seed", 0), 42);
        assert_eq!(c.usize_or("train.steps", 0), 500);
        assert!((c.f32_or("train.lr", 0.0) - 0.05).abs() < 1e-9);
        assert!(c.bool_or("train.verbose", false));
        assert_eq!(c.f32_list("train.rates"), vec![0.5, 0.1, 0.01]);
        assert_eq!(c.usize_or("server.batcher.max_batch", 0), 16);
    }

    #[test]
    fn defaults_on_missing_or_wrong_type() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.usize_or("nope", 7), 7);
        assert_eq!(c.usize_or("name", 7), 7); // string, not int
    }

    #[test]
    fn comments_inside_strings_kept() {
        let c = Config::parse("x = \"a # b\"").unwrap();
        assert_eq!(c.str_or("x", ""), "a # b");
    }

    #[test]
    fn merge_overlays() {
        let a = Config::parse("x = 1\ny = 2").unwrap();
        let b = Config::parse("y = 3\nz = 4").unwrap();
        let m = a.merged(b);
        assert_eq!(m.usize_or("x", 0), 1);
        assert_eq!(m.usize_or("y", 0), 3);
        assert_eq!(m.usize_or("z", 0), 4);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = @@").is_err());
    }
}
