//! Property-based testing harness (in-tree substrate; no proptest offline).
//!
//! `run_prop` drives a property over N seeded random cases; on failure it
//! retries with a simple halving shrink over every generated integer and
//! reports the failing case's seed so the case is reproducible:
//!
//! ```ignore
//! run_prop("router_routes_once", 200, |g| {
//!     let n = g.usize(1, 64);
//!     ...
//!     ensure!(cond, "message");
//!     Ok(())
//! });
//! ```

use crate::util::prng::Stream;

/// Per-case generator handle: seeded draws + a trace for shrinking.
pub struct Gen {
    s: Stream,
    pub trace: Vec<u64>,
    replay: Option<Vec<u64>>,
    idx: usize,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { s: Stream::new(seed), trace: Vec::new(), replay: None, idx: 0 }
    }

    fn replaying(vals: Vec<u64>) -> Gen {
        Gen { s: Stream::new(0), trace: Vec::new(), replay: Some(vals), idx: 0 }
    }

    fn draw(&mut self) -> u64 {
        let v = match &self.replay {
            Some(vals) => vals.get(self.idx).copied().unwrap_or(0),
            None => self.s.next_u64(),
        };
        self.idx += 1;
        self.trace.push(v);
        v
    }

    /// Uniform usize in [lo, hi] (inclusive).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            // still consume a draw so shrink traces stay aligned
            let _ = self.draw();
            return lo;
        }
        lo + (self.draw() % (hi - lo + 1) as u64) as usize
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.draw() >> 40) as f32 * (1.0 / 16_777_216.0);
        lo + u * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.draw() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize(0, xs.len() - 1);
        &xs[i]
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }
}

/// Run `prop` over `cases` random cases. Panics (test failure) with the
/// seed + shrunk trace on the first violated property.
pub fn run_prop(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0x9E3779B9u64
            .wrapping_mul(case + 1)
            .wrapping_add(name.bytes().map(|b| b as u64).sum::<u64>());
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            let (trace, final_msg) = shrink(g.trace.clone(), msg, &prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}):\n  {final_msg}\n  shrunk trace: {trace:?}"
            );
        }
    }
}

/// Halving shrink over every trace position; keeps the failure alive.
fn shrink(
    mut trace: Vec<u64>,
    mut msg: String,
    prop: &impl Fn(&mut Gen) -> Result<(), String>,
) -> (Vec<u64>, String) {
    let mut improved = true;
    let mut budget = 500;
    while improved && budget > 0 {
        improved = false;
        for i in 0..trace.len() {
            if trace[i] == 0 {
                continue;
            }
            let mut cand = trace.clone();
            cand[i] /= 2;
            let mut g = Gen::replaying(cand.clone());
            if let Err(m) = prop(&mut g) {
                trace = cand;
                msg = m;
                improved = true;
            }
            budget -= 1;
            if budget == 0 {
                break;
            }
        }
    }
    (trace, msg)
}

/// `ensure!`-style helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run_prop("add_commutes", 100, |g| {
            let a = g.usize(0, 1000);
            let b = g.usize(0, 1000);
            prop_assert!(a + b == b + a, "never");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "always_fails")]
    fn failing_property_panics_with_name() {
        run_prop("always_fails", 10, |g| {
            let _ = g.usize(0, 10);
            Err("always_fails".into())
        });
    }

    #[test]
    fn shrink_reduces_values() {
        // property fails for any n >= 10; shrinker should find a small trace.
        let prop = |g: &mut Gen| -> Result<(), String> {
            let n = g.usize(0, 1_000_000);
            prop_assert!(n < 10, "n={n}");
            Ok(())
        };
        let mut g = Gen::new(99);
        // find a failing case first
        while prop(&mut g).is_ok() {
            g = Gen::new(g.draw());
        }
        let (trace, msg) = shrink(g.trace.clone(), "seed".into(), &prop);
        let mut rg = Gen::replaying(trace.clone());
        let n = rg.usize(0, 1_000_000);
        assert!(n >= 10, "shrunk case must still fail: {msg}");
        assert!(trace[trace.len() - 1] <= g.trace[g.trace.len() - 1]);
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let v = g.usize(3, 7);
            assert!((3..=7).contains(&v));
            let f = g.f32(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
        }
        assert_eq!(g.usize(5, 5), 5);
    }
}
