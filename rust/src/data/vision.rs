//! Synthetic vision task: each class owns a smooth random prototype image;
//! samples are prototypes + circular shifts + pixel noise. Shift+noise make
//! the task benefit from both locality (convs) and capacity, and accuracy
//! degrades smoothly with compression — the property Tables 1-3 probe.

use crate::tensor::Tensor;
use crate::util::prng::{tag, Stream};

use super::{Batch, Dataset, Split};

#[derive(Debug, Clone)]
pub struct SynthVision {
    pub classes: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub noise: f32,
    pub max_shift: usize,
    prototypes: Vec<f32>, // [classes, h*w*c]
}

impl SynthVision {
    /// `mnist_like`: 28×28×1, 10 classes. `cifar_like`: 32×32×3, k classes.
    pub fn new(seed: u64, classes: usize, h: usize, w: usize, c: usize) -> SynthVision {
        let dim = h * w * c;
        let mut prototypes = vec![0.0f32; classes * dim];
        for cls in 0..classes {
            let mut s = Stream::sub(seed, tag::DATA + 17 * cls as u64);
            // low-frequency pattern: coarse 8x8 grid, bilinearly upsampled
            let g = 8usize;
            let coarse = s.normal_f32(g * g * c, 1.0);
            for y in 0..h {
                for x in 0..w {
                    for ch in 0..c {
                        let fy = y as f32 * (g - 1) as f32 / (h - 1).max(1) as f32;
                        let fx = x as f32 * (g - 1) as f32 / (w - 1).max(1) as f32;
                        let (y0, x0) = (fy as usize, fx as usize);
                        let (y1, x1) = ((y0 + 1).min(g - 1), (x0 + 1).min(g - 1));
                        let (dy, dx) = (fy - y0 as f32, fx - x0 as f32);
                        let at = |yy: usize, xx: usize| coarse[(yy * g + xx) * c + ch];
                        let v = at(y0, x0) * (1.0 - dy) * (1.0 - dx)
                            + at(y0, x1) * (1.0 - dy) * dx
                            + at(y1, x0) * dy * (1.0 - dx)
                            + at(y1, x1) * dy * dx;
                        prototypes[cls * dim + (y * w + x) * c + ch] = v;
                    }
                }
            }
        }
        SynthVision { classes, h, w, c, noise: 0.6, max_shift: 3, prototypes }
    }

    pub fn mnist_like(seed: u64) -> SynthVision {
        SynthVision::new(seed, 10, 28, 28, 1)
    }

    pub fn cifar_like(seed: u64, classes: usize) -> SynthVision {
        SynthVision::new(seed, classes, 32, 32, 3)
    }

    pub fn dim(&self) -> usize {
        self.h * self.w * self.c
    }

    fn sample_into(&self, s: &mut Stream, x: &mut [f32]) -> i32 {
        let cls = (s.next_u64() % self.classes as u64) as usize;
        let dim = self.dim();
        let proto = &self.prototypes[cls * dim..(cls + 1) * dim];
        let sy = (s.next_u64() % (2 * self.max_shift + 1) as u64) as usize;
        let sx = (s.next_u64() % (2 * self.max_shift + 1) as u64) as usize;
        for y in 0..self.h {
            let yy = (y + sy) % self.h;
            for xx0 in 0..self.w {
                let xx = (xx0 + sx) % self.w;
                for ch in 0..self.c {
                    let v = proto[(yy * self.w + xx) * self.c + ch];
                    x[(y * self.w + xx0) * self.c + ch] =
                        v + self.noise * box_muller_one(s);
                }
            }
        }
        cls as i32
    }
}

#[inline]
fn box_muller_one(s: &mut Stream) -> f32 {
    // single normal draw (wastes the sine half; fine for noise)
    let u1 = ((s.next_u64() >> 40) as f64 + 1.0) * (1.0 / 16_777_216.0);
    let u2 = (s.next_u64() >> 40) as f64 * (1.0 / 16_777_216.0);
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

impl Dataset for SynthVision {
    fn batch(&self, split: Split, step: u64, batch: usize) -> Batch {
        let mut s = Stream::sub(split.salt().wrapping_add(step), tag::DATA);
        let dim = self.dim();
        let mut x = vec![0.0f32; batch * dim];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            y[b] = self.sample_into(&mut s, &mut x[b * dim..(b + 1) * dim]);
        }
        (
            Tensor::from_f32(x, &[batch, dim]).unwrap(),
            Tensor::from_i32(y, &[batch]).unwrap(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let ds = SynthVision::cifar_like(1, 10);
        let (x1, y1) = ds.batch(Split::Train, 5, 8);
        let (x2, y2) = ds.batch(Split::Train, 5, 8);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = ds.batch(Split::Train, 6, 8);
        assert_ne!(x1, x3);
        let (x4, _) = ds.batch(Split::Val, 5, 8);
        assert_ne!(x1, x4);
    }

    #[test]
    fn labels_in_range_and_varied() {
        let ds = SynthVision::mnist_like(2);
        let (_, y) = ds.batch(Split::Train, 0, 256);
        let ys = y.i32s().unwrap();
        assert!(ys.iter().all(|&c| (0..10).contains(&c)));
        let distinct: std::collections::HashSet<i32> = ys.iter().cloned().collect();
        assert!(distinct.len() >= 8, "class draw is degenerate: {distinct:?}");
    }

    #[test]
    fn classes_are_separable() {
        // nearest-prototype classification on clean prototypes must be
        // near-perfect on noisy samples at shift 0 — i.e. the task is
        // learnable, not random labels.
        let mut ds = SynthVision::cifar_like(3, 10);
        ds.max_shift = 0;
        let (x, y) = ds.batch(Split::Train, 1, 64);
        let dim = ds.dim();
        let xs = x.f32s().unwrap();
        let ys = y.i32s().unwrap();
        let mut correct = 0;
        for b in 0..64 {
            let sample = &xs[b * dim..(b + 1) * dim];
            let mut best = (f32::MAX, 0usize);
            for cls in 0..10 {
                let proto = &ds.prototypes[cls * dim..(cls + 1) * dim];
                let d2: f32 = sample.iter().zip(proto).map(|(a, b)| (a - b).powi(2)).sum();
                if d2 < best.0 {
                    best = (d2, cls);
                }
            }
            if best.1 as i32 == ys[b] {
                correct += 1;
            }
        }
        assert!(correct >= 60, "only {correct}/64 nearest-prototype correct");
    }

    #[test]
    fn shapes() {
        let ds = SynthVision::cifar_like(4, 100);
        let (x, y) = ds.batch(Split::Train, 0, 16);
        assert_eq!(x.dims, vec![16, 3072]);
        assert_eq!(y.dims, vec![16]);
    }
}
