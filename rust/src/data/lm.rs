//! Synthetic language-modeling task: order-1 Markov chains over a small
//! vocabulary. The *base* distribution stands in for pretraining data; each
//! PEFT *task* perturbs the transition matrix (sharpened toward a
//! task-specific permutation), so adapters have something real to learn and
//! held-out perplexity measures adaptation quality (the MMLU stand-in).

use crate::tensor::Tensor;
use crate::util::prng::{tag, Stream};

use super::{Batch, Dataset, Split};

#[derive(Debug, Clone)]
pub struct MarkovLm {
    pub vocab: usize,
    pub seq: usize,
    /// Row-stochastic transition matrix [vocab, vocab].
    trans: Vec<f32>,
    /// Cumulative rows for O(log V) sampling.
    cum: Vec<f32>,
    salt: u64,
}

impl MarkovLm {
    /// Base chain: smooth random transitions with mild sparsity.
    pub fn base(seed: u64, vocab: usize, seq: usize) -> MarkovLm {
        let mut s = Stream::sub(seed, tag::DATA + 0x4C4D);
        let mut trans = vec![0.0f32; vocab * vocab];
        for r in 0..vocab {
            let logits = s.normal_f32(vocab, 1.5);
            softmax_into(&logits, &mut trans[r * vocab..(r + 1) * vocab]);
        }
        MarkovLm::from_trans(vocab, seq, trans, seed)
    }

    /// Task variant: mix the base chain with a task-specific deterministic
    /// successor permutation. `strength` ∈ [0,1): how far the task deviates.
    pub fn task(base: &MarkovLm, task_id: u64, strength: f32) -> MarkovLm {
        let v = base.vocab;
        let mut s = Stream::sub(base.salt ^ 0x5441534B, tag::DATA + task_id);
        // random permutation via Fisher-Yates
        let mut perm: Vec<usize> = (0..v).collect();
        for i in (1..v).rev() {
            let j = (s.next_u64() % (i as u64 + 1)) as usize;
            perm.swap(i, j);
        }
        let mut trans = base.trans.clone();
        for r in 0..v {
            let row = &mut trans[r * v..(r + 1) * v];
            for x in row.iter_mut() {
                *x *= 1.0 - strength;
            }
            row[perm[r]] += strength;
        }
        MarkovLm::from_trans(v, base.seq, trans, base.salt ^ (task_id + 1))
    }

    fn from_trans(vocab: usize, seq: usize, trans: Vec<f32>, salt: u64) -> MarkovLm {
        let mut cum = trans.clone();
        for r in 0..vocab {
            let row = &mut cum[r * vocab..(r + 1) * vocab];
            let mut acc = 0.0f32;
            for x in row.iter_mut() {
                acc += *x;
                *x = acc;
            }
        }
        MarkovLm { vocab, seq, trans, cum, salt }
    }

    fn sample_next(&self, cur: usize, s: &mut Stream) -> usize {
        let u = s.next_unit_f32();
        let row = &self.cum[cur * self.vocab..(cur + 1) * self.vocab];
        match row.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(self.vocab - 1),
        }
    }

    /// Entropy rate (bits/token), the floor for achievable loss.
    pub fn entropy_rate_nats(&self) -> f64 {
        let v = self.vocab;
        // stationary distribution ≈ uniform start iterated a few times
        let mut pi = vec![1.0f64 / v as f64; v];
        for _ in 0..50 {
            let mut nxt = vec![0.0f64; v];
            for r in 0..v {
                for c in 0..v {
                    nxt[c] += pi[r] * self.trans[r * v + c] as f64;
                }
            }
            pi = nxt;
        }
        let mut h = 0.0f64;
        for r in 0..v {
            let mut hr = 0.0f64;
            for c in 0..v {
                let p = self.trans[r * v + c] as f64;
                if p > 1e-12 {
                    hr -= p * p.ln();
                }
            }
            h += pi[r] * hr;
        }
        h
    }
}

fn softmax_into(logits: &[f32], out: &mut [f32]) {
    let mx = logits.iter().cloned().fold(f32::MIN, f32::max);
    let mut z = 0.0f32;
    for (o, &l) in out.iter_mut().zip(logits) {
        *o = (l - mx).exp();
        z += *o;
    }
    for o in out.iter_mut() {
        *o /= z;
    }
}

impl Dataset for MarkovLm {
    /// x = tokens[0..T], y = tokens[1..T+1] (next-token targets).
    fn batch(&self, split: Split, step: u64, batch: usize) -> Batch {
        let mut s = Stream::sub(self.salt ^ split.salt().wrapping_add(step), tag::DATA);
        let t = self.seq;
        let mut x = vec![0i32; batch * t];
        let mut y = vec![0i32; batch * t];
        for b in 0..batch {
            let mut cur = (s.next_u64() % self.vocab as u64) as usize;
            for i in 0..t {
                x[b * t + i] = cur as i32;
                cur = self.sample_next(cur, &mut s);
                y[b * t + i] = cur as i32;
            }
        }
        (
            Tensor::from_i32(x, &[batch, t]).unwrap(),
            Tensor::from_i32(y, &[batch, t]).unwrap(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_stochastic() {
        let lm = MarkovLm::base(1, 32, 16);
        for r in 0..32 {
            let s: f32 = lm.trans[r * 32..(r + 1) * 32].iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn deterministic_and_split_dependent() {
        let lm = MarkovLm::base(2, 64, 8);
        let a = lm.batch(Split::Train, 3, 4);
        let b = lm.batch(Split::Train, 3, 4);
        let c = lm.batch(Split::Val, 3, 4);
        assert_eq!(a, b);
        assert_ne!(a.0, c.0);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let lm = MarkovLm::base(3, 16, 12);
        let (x, y) = lm.batch(Split::Train, 0, 2);
        let xs = x.i32s().unwrap();
        let ys = y.i32s().unwrap();
        // y[i] becomes x[i+1] within each row
        for b in 0..2 {
            for i in 0..11 {
                assert_eq!(ys[b * 12 + i], xs[b * 12 + i + 1]);
            }
        }
    }

    #[test]
    fn task_shifts_distribution() {
        let base = MarkovLm::base(4, 32, 8);
        let t1 = MarkovLm::task(&base, 1, 0.5);
        let t2 = MarkovLm::task(&base, 2, 0.5);
        assert_ne!(t1.trans, base.trans);
        assert_ne!(t1.trans, t2.trans);
        // still stochastic
        for r in 0..32 {
            let s: f32 = t1.trans[r * 32..(r + 1) * 32].iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
        // stronger task → lower entropy (more predictable)
        let t_strong = MarkovLm::task(&base, 1, 0.9);
        assert!(t_strong.entropy_rate_nats() < base.entropy_rate_nats());
    }

    #[test]
    fn entropy_rate_bounds() {
        let lm = MarkovLm::base(5, 16, 8);
        let h = lm.entropy_rate_nats();
        assert!(h > 0.0 && h < (16f64).ln() + 1e-9);
    }
}
