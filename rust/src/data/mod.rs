//! Deterministic synthetic datasets: the paper's claims are
//! relative (method A vs B at equal parameter budget), so learnable
//! synthetic tasks with matched shapes/class counts expose the same
//! capacity-vs-compression trade-offs while staying CPU-trainable.

pub mod lm;
pub mod loader;
pub mod vision;

pub use lm::MarkovLm;
pub use loader::Prefetcher;
pub use vision::SynthVision;

use crate::tensor::Tensor;

/// A batch of (inputs, labels) host tensors.
pub type Batch = (Tensor, Tensor);

/// Anything that can produce deterministic batches by step index.
pub trait Dataset: Send + Sync {
    fn batch(&self, split: Split, step: u64, batch: usize) -> Batch;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

impl Split {
    pub fn salt(&self) -> u64 {
        match self {
            Split::Train => 0x7252_4E00,
            Split::Val => 0x7641_4C00,
        }
    }
}
