//! Background batch prefetcher: overlaps synthetic-data generation with the
//! PJRT step on the training hot path (one producer thread, bounded queue).

use std::sync::mpsc;
use std::thread;

use super::Batch;

pub struct Prefetcher {
    rx: mpsc::Receiver<Batch>,
    _handle: thread::JoinHandle<()>,
}

impl Prefetcher {
    /// `make(step)` produces batch `step`; `depth` bounds the queue.
    pub fn new(
        make: impl Fn(u64) -> Batch + Send + 'static,
        steps: u64,
        depth: usize,
    ) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let handle = thread::Builder::new()
            .name("mcnc-prefetch".into())
            .spawn(move || {
                for step in 0..steps {
                    if tx.send(make(step)).is_err() {
                        break; // consumer dropped
                    }
                }
            })
            .expect("spawn prefetcher");
        Prefetcher { rx, _handle: handle }
    }

    pub fn next(&self) -> Option<Batch> {
        self.rx.recv().ok()
    }
}

impl Iterator for Prefetcher {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, Split, SynthVision};

    #[test]
    fn yields_all_batches_in_order() {
        let ds = SynthVision::mnist_like(1);
        let pf = Prefetcher::new(move |s| ds.batch(Split::Train, s, 4), 10, 2);
        let ds2 = SynthVision::mnist_like(1);
        let mut n = 0;
        for (step, (x, y)) in pf.enumerate() {
            let (ex, ey) = ds2.batch(Split::Train, step as u64, 4);
            assert_eq!(x, ex);
            assert_eq!(y, ey);
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn early_drop_does_not_hang() {
        let ds = SynthVision::mnist_like(2);
        let mut pf = Prefetcher::new(move |s| ds.batch(Split::Train, s, 2), 1000, 2);
        let _ = pf.next();
        drop(pf); // producer must exit on closed channel
    }
}
