//! Shared experiment plumbing for the per-table benches: one call trains
//! any manifest executable on any dataset and reports held-out accuracy,
//! with the paper's small learning-rate search when running in full mode.

use std::sync::Arc;

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::{artifacts_dir, Session};
use crate::train::{self, LrSchedule, TrainCfg, TrainState};
use crate::util::bench::bench_steps;

/// Bench context; `None` (and a notice) when artifacts are missing.
pub struct Ctx {
    pub session: Session,
}

impl Ctx {
    pub fn open() -> Option<Ctx> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("[bench] artifacts not built — run `make artifacts`; skipping");
            return None;
        }
        Some(Ctx { session: Session::open(&dir).unwrap() })
    }

    /// Train `exec` for `steps` and return (final val acc, final val loss).
    pub fn train_acc(
        &self,
        exec: &str,
        data: Arc<dyn Dataset>,
        steps: usize,
        lr: f32,
        seed: u64,
    ) -> Result<(f32, f32, TrainState<'_>)> {
        let mut st = TrainState::new(&self.session, exec, seed)?;
        let batch = st
            .entry
            .meta
            .get("batch")
            .and_then(|j| j.as_usize())
            .unwrap_or(64);
        let cfg = TrainCfg {
            steps,
            batch,
            schedule: LrSchedule::Cosine { base: lr, total: steps, floor_frac: 0.05 },
            eval_every: 0,
            eval_batches: 4,
            log_every: 0,
            verbose: false,
        };
        let hist = train::run(&mut st, data, &cfg)?;
        Ok((hist.final_val_acc(), hist.final_val_loss(), st))
    }

    /// Paper-style lr search (only in full mode; quick mode uses lrs[0]).
    pub fn best_acc(
        &self,
        exec: &str,
        data: Arc<dyn Dataset>,
        steps: usize,
        lrs: &[f32],
        seed: u64,
    ) -> Result<(f32, f32)> {
        let search: &[f32] = if full_mode() { lrs } else { &lrs[..1] };
        let mut best = (f32::MIN, f32::MAX);
        for &lr in search {
            let (acc, loss, _) = self.train_acc(exec, Arc::clone(&data), steps, lr, seed)?;
            if acc > best.0 {
                best = (acc, loss);
            }
        }
        Ok(best)
    }
}

pub fn full_mode() -> bool {
    std::env::var("MCNC_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Default step budgets per model family (env-overridable).
pub fn steps_mlp() -> usize {
    bench_steps(80, 800)
}

pub fn steps_vit() -> usize {
    bench_steps(80, 1500)
}

pub fn steps_resnet() -> usize {
    bench_steps(50, 1200)
}

pub fn steps_lm() -> usize {
    bench_steps(60, 600)
}
