//! Pruning baselines (Table 1): one-shot / iterative magnitude pruning and
//! PLATON-lite, both driving the dense train-step's multiplicative `mask`
//! input from the Rust side between steps.
//!
//! PLATON (Zhang et al. 2022) scores weights by an uncertainty-adjusted
//! EMA of the sensitivity |θ·∇θ|; the lite variant keeps the two EMAs
//! (importance Ī and uncertainty Ū, score = Ī·Ū) and the cubic sparsity
//! schedule, dropping the transformer-specific bells.

/// Keep the top-(1-sparsity) fraction of |scores|; returns a 0/1 mask.
pub fn topk_mask(scores: &[f32], sparsity: f32) -> Vec<f32> {
    let n = scores.len();
    let keep = ((1.0 - sparsity as f64) * n as f64).round() as usize;
    if keep >= n {
        return vec![1.0; n];
    }
    if keep == 0 {
        return vec![0.0; n];
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let kth = n - keep; // elements below this index are pruned
    idx.select_nth_unstable_by(kth, |&a, &b| {
        scores[a as usize]
            .abs()
            .partial_cmp(&scores[b as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut mask = vec![0.0f32; n];
    for &i in &idx[kth..] {
        mask[i as usize] = 1.0;
    }
    mask
}

/// Cubic sparsity schedule (PLATON eq. 8 / Zhu & Gupta):
/// s(t) ramps 0 → s_final between t_i and t_f, cubically.
pub fn cubic_sparsity(step: usize, t_i: usize, t_f: usize, s_final: f32) -> f32 {
    if step <= t_i {
        return 0.0;
    }
    if step >= t_f {
        return s_final;
    }
    let frac = (step - t_i) as f32 / (t_f - t_i) as f32;
    s_final * (1.0 - (1.0 - frac).powi(3))
}

/// PLATON-lite importance state.
pub struct Platon {
    pub ibar: Vec<f32>, // EMA of sensitivity
    pub ubar: Vec<f32>, // EMA of |sensitivity - EMA| (uncertainty)
    pub beta1: f32,
    pub beta2: f32,
}

impl Platon {
    pub fn new(n: usize, beta1: f32, beta2: f32) -> Platon {
        Platon { ibar: vec![0.0; n], ubar: vec![0.0; n], beta1, beta2 }
    }

    /// Fold one step's sensitivity |θ·∇θ| into the EMAs.
    pub fn update(&mut self, sensitivity: &[f32]) {
        assert_eq!(sensitivity.len(), self.ibar.len());
        for i in 0..sensitivity.len() {
            let s = sensitivity[i];
            let prev = self.ibar[i];
            self.ibar[i] = self.beta1 * prev + (1.0 - self.beta1) * s;
            let u = (s - self.ibar[i]).abs();
            self.ubar[i] = self.beta2 * self.ubar[i] + (1.0 - self.beta2) * u;
        }
    }

    /// Uncertainty-weighted scores (PLATON's Ī ⊙ Ū).
    pub fn scores(&self) -> Vec<f32> {
        self.ibar.iter().zip(&self.ubar).map(|(i, u)| i * u).collect()
    }

    pub fn mask(&self, sparsity: f32) -> Vec<f32> {
        topk_mask(&self.scores(), sparsity)
    }
}

/// Account for unstructured-pruning index storage the way the paper does:
/// at equal *model size*, pruning must go to 1.5× the sparsity because each
/// surviving weight also stores a half-precision index (§4.1).
pub fn sparsity_for_size(size_fraction: f32) -> f32 {
    // keep fraction = size / 1.5  ⇒  sparsity = 1 − (2/3)·size
    (1.0 - size_fraction * (2.0 / 3.0)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    #[test]
    fn topk_keeps_largest() {
        let scores = vec![0.1, -5.0, 0.3, 2.0, -0.01];
        let m = topk_mask(&scores, 0.6); // keep 2 of 5
        assert_eq!(m, vec![0.0, 1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn topk_edge_cases() {
        assert_eq!(topk_mask(&[1.0, 2.0], 0.0), vec![1.0, 1.0]);
        assert_eq!(topk_mask(&[1.0, 2.0], 1.0), vec![0.0, 0.0]);
    }

    #[test]
    fn topk_mask_count_property() {
        run_prop("topk_count", 100, |g| {
            let n = g.usize(1, 500);
            let s = g.f32(0.0, 1.0);
            let scores = g.vec_f32(n, -1.0, 1.0);
            let m = topk_mask(&scores, s);
            let kept = m.iter().filter(|&&x| x == 1.0).count();
            let want = ((1.0 - s as f64) * n as f64).round() as usize;
            prop_assert!(kept == want.min(n), "kept {kept}, want {want}");
            Ok(())
        });
    }

    #[test]
    fn cubic_schedule_monotone() {
        let mut prev = -1.0f32;
        for t in 0..200 {
            let s = cubic_sparsity(t, 10, 150, 0.9);
            assert!(s >= prev - 1e-6);
            assert!((0.0..=0.9).contains(&s));
            prev = s;
        }
        assert_eq!(cubic_sparsity(0, 10, 150, 0.9), 0.0);
        assert_eq!(cubic_sparsity(199, 10, 150, 0.9), 0.9);
    }

    #[test]
    fn platon_prefers_consistent_importance() {
        let mut p = Platon::new(3, 0.85, 0.95);
        for step in 0..50 {
            // weight 0: consistently important; weight 1: noisy; weight 2: dead
            let noisy = if step % 2 == 0 { 2.0 } else { 0.0 };
            p.update(&[1.0, noisy, 0.001]);
        }
        let s = p.scores();
        let m = p.mask(2.0 / 3.0); // keep 1
        assert!(s[1] > s[0], "noisy weight should have higher uncertainty score");
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 1);
        assert_eq!(m[2], 0.0);
    }

    #[test]
    fn size_accounting_paper_rule() {
        // paper: prune to sparsity 1.5x higher than the size target,
        // i.e. size 10% → keep 6.7% of weights (sparsity 93.3%)
        let s = sparsity_for_size(0.10);
        assert!((s - 0.9333).abs() < 1e-3, "{s}");
        assert!((sparsity_for_size(0.05) - 0.9667).abs() < 1e-3);
        assert_eq!(sparsity_for_size(1.6), 0.0); // no pruning needed
    }
}
