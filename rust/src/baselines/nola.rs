//! Native NOLA reconstruction (Koohpayegani et al. 2024): LoRA factors as
//! linear combinations of m frozen random bases. The PJRT executables carry
//! the same math in-graph; this mirror exists for FLOPs-vs-wallclock
//! micro-benchmarks (Table 4's reconstruction-cost comparison), tests, and
//! the serving engine's native Merged-mode fills. The heavy lifting runs on
//! the same blocked-GEMM kernel as the MCNC generator (`mcnc::kernel`), so
//! the basis combination (GEMV) and the A·B product both pick up the
//! ISA-dispatched microkernels (AVX2+FMA / NEON / scalar) automatically.

use crate::mcnc::kernel;

/// One LoRA target's dimensions.
#[derive(Debug, Clone, Copy)]
pub struct TargetDims {
    pub a: usize,
    pub b: usize,
}

/// Reconstruct one factor: `coef [m]` × `basis [m, rows*cols]` →
/// `[rows*cols]`.
pub fn combine(coef: &[f32], basis: &[f32], len: usize, out: &mut [f32]) {
    assert_eq!(basis.len(), coef.len() * len);
    assert_eq!(out.len(), len);
    kernel::gemv(coef, basis, coef.len(), len, out);
}

/// Full adapter reconstruction: per-target A = Σ cA_j·basisA_j and B
/// likewise, then ΔW = A·B. Returns the per-target ΔW flats.
pub fn reconstruct_deltas(
    dims: &[TargetDims],
    rank: usize,
    coef_a: &[f32], // [L, m]
    coef_b: &[f32],
    basis_a: &[f32], // concatenated [m * a * rank] per target
    basis_b: &[f32],
    m: usize,
) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(dims.len());
    let (mut ao, mut bo) = (0usize, 0usize);
    for (l, t) in dims.iter().enumerate() {
        let alen = t.a * rank;
        let blen = rank * t.b;
        let mut fa = vec![0.0f32; alen];
        let mut fb = vec![0.0f32; blen];
        combine(&coef_a[l * m..(l + 1) * m], &basis_a[m * ao..m * (ao + alen)], alen, &mut fa);
        combine(&coef_b[l * m..(l + 1) * m], &basis_b[m * bo..m * (bo + blen)], blen, &mut fb);
        ao += alen;
        bo += blen;
        // ΔW = A [a, r] @ B [r, b] through the blocked GEMM; packing B costs
        // r·b writes against the a·r·b-FLOP product, and the ascending-rank
        // accumulation keeps results bit-identical to the naive loop
        let pb = kernel::pack_b(&fb, rank, t.b);
        let mut dw = vec![0.0f32; t.a * t.b];
        kernel::gemm(&fa, t.a, &pb, &mut dw);
        out.push(dw);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    #[test]
    fn combine_is_linear() {
        let basis = Stream::new(1).normal_f32(3 * 10, 1.0);
        let mut out1 = vec![0.0; 10];
        let mut out2 = vec![0.0; 10];
        combine(&[1.0, 0.0, 0.0], &basis, 10, &mut out1);
        assert_eq!(out1, &basis[..10]);
        combine(&[2.0, -1.0, 0.5], &basis, 10, &mut out2);
        for i in 0..10 {
            let want = 2.0 * basis[i] - basis[10 + i] + 0.5 * basis[20 + i];
            assert!((out2[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_coefs_zero_delta() {
        let dims = [TargetDims { a: 4, b: 6 }, TargetDims { a: 3, b: 3 }];
        let m = 2;
        let rank = 2;
        let na: usize = dims.iter().map(|t| t.a * rank).sum();
        let nb: usize = dims.iter().map(|t| rank * t.b).sum();
        let basis_a = Stream::new(2).normal_f32(m * na, 1.0);
        let basis_b = Stream::new(3).normal_f32(m * nb, 1.0);
        let coef_a = Stream::new(4).normal_f32(dims.len() * m, 1.0);
        let coef_b = vec![0.0; dims.len() * m];
        let d = reconstruct_deltas(&dims, rank, &coef_a, &coef_b, &basis_a, &basis_b, m);
        assert!(d.iter().flatten().all(|&v| v == 0.0));
    }

    #[test]
    fn rank1_outer_product() {
        let dims = [TargetDims { a: 2, b: 3 }];
        // single basis, coef 1 → A = basisA, B = basisB, ΔW = A·B
        let basis_a = vec![1.0, 2.0]; // A [2,1]
        let basis_b = vec![3.0, 4.0, 5.0]; // B [1,3]
        let d = reconstruct_deltas(&dims, 1, &[1.0], &[1.0], &basis_a, &basis_b, 1);
        assert_eq!(d[0], vec![3.0, 4.0, 5.0, 6.0, 8.0, 10.0]);
    }
}
