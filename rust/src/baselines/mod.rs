//! Baselines the paper compares against, native side: pruning (magnitude /
//! PLATON-lite driving the dense executable's mask), NOLA reconstruction,
//! and simulated base-weight quantization (QLoRA stand-in).

pub mod nola;
pub mod prune;
pub mod quant;

pub use prune::{cubic_sparsity, sparsity_for_size, topk_mask, Platon};
