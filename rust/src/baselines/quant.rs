//! Simulated weight quantization (QLoRA-style frozen base): per-block
//! absmax int-N quantize→dequantize of θ0 before it is fed to the PEFT
//! executables. Stands in for the paper's 4-bit base model.

/// Quantize-dequantize `w` in place: per `block`-sized group, symmetric
/// absmax scaling to `bits`-wide signed integers. Delegates to the real
/// encode/decode pair in `codec::quantizer` so the block layout math lives
/// in one place (the wire format and this simulation cannot drift apart).
pub fn fake_quant(w: &mut [f32], bits: u32, block: usize) {
    use crate::codec::quantizer::{dequantize, quantize};
    let deq = dequantize(&quantize(w, bits, block));
    for (v, d) in w.iter_mut().zip(deq) {
        // the wire codec maps NaN symbols to 0; the in-place simulation
        // keeps propagating NaN so a diverged run stays visibly diverged
        if !v.is_nan() {
            *v = d;
        }
    }
}

/// Bytes to store the quantized block layout (payload + f32 scales).
pub fn quant_bytes(n: usize, bits: u32, block: usize) -> usize {
    (n * bits as usize).div_ceil(8) + n.div_ceil(block) * 4
}

/// Max representable relative error of absmax int-N quantization.
pub fn worst_rel_error(bits: u32) -> f32 {
    0.5 / (((1i32 << (bits - 1)) - 1) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    #[test]
    fn int8_is_accurate() {
        let mut w = Stream::new(1).normal_f32(4096, 0.05);
        let orig = w.clone();
        fake_quant(&mut w, 8, 64);
        let max_rel = orig
            .iter()
            .zip(&w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        // error bounded by scale/2 = absmax/254
        let absmax = orig.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(max_rel <= absmax * worst_rel_error(8) * 1.01);
    }

    #[test]
    fn int4_coarser_than_int8() {
        let base = Stream::new(2).normal_f32(4096, 0.05);
        let mut w4 = base.clone();
        let mut w8 = base.clone();
        fake_quant(&mut w4, 4, 64);
        fake_quant(&mut w8, 8, 64);
        let err = |q: &[f32]| -> f64 {
            base.iter().zip(q).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(err(&w4) > err(&w8) * 4.0);
    }

    #[test]
    fn idempotent() {
        let mut w = Stream::new(3).normal_f32(256, 1.0);
        fake_quant(&mut w, 4, 32);
        let once = w.clone();
        fake_quant(&mut w, 4, 32);
        assert_eq!(once, w);
    }

    #[test]
    fn zero_block_untouched() {
        let mut w = vec![0.0f32; 64];
        fake_quant(&mut w, 4, 32);
        assert!(w.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn nan_propagates() {
        let mut w = vec![0.5f32, f32::NAN, -0.25, 0.125];
        fake_quant(&mut w, 8, 4);
        assert!(w[1].is_nan(), "NaN must stay NaN through fake-quant");
        assert!(w[0].is_finite() && w[2].is_finite() && w[3].is_finite());
    }

    #[test]
    fn storage_accounting() {
        // 4-bit, block 64: n/2 payload bytes + n/64 scales * 4B
        assert_eq!(quant_bytes(4096, 4, 64), 2048 + 256);
        assert_eq!(quant_bytes(10, 4, 64), 5 + 4);
    }
}
