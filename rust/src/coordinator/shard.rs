//! One engine shard: the worker loop behind the sharded serving
//! coordinator. Each shard owns its execution engine (Session, adapter
//! slice, merged LRU) on a dedicated thread — `PjRtClient` is not `Send`,
//! so engines are constructed *inside* the thread via a factory — and
//! drains a bounded admission channel into its own `Router`.
//!
//! Fault isolation is the shard loop's contract: a malformed request is
//! answered with an error `Response` at ingest, a failing or *panicking*
//! batch produces error Responses for exactly that batch's requests, and
//! the loop itself never `?`-aborts on per-request work. Fault *recovery*
//! is the supervisor's: the shard thread runs `run_loop` under
//! `catch_unwind`, and when an incarnation dies (engine factory error, or
//! a panic escaping the loop) it answers every stranded reply channel,
//! rebuilds the engine with bounded exponential backoff — re-warming from
//! the preload artifact when one was configured — and resumes serving.
//! Exhausting the restart budget without serving a single batch marks the
//! shard permanently dead: queued and future messages are answered with
//! errors until `Stop`, so the exactly-one-`Response` invariant holds even
//! for a shard that never comes back.
//!
//! The loop also never busy-waits: between batches it blocks on the
//! channel until the router's next flush deadline — which accounts for
//! per-request deadlines, so an expired request is shed (answered with
//! `ServeError::DeadlineExceeded`) instead of waiting out the heartbeat.

use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::metrics::ServeStats;
use crate::coordinator::router::{Batch, BatchPolicy, Request, Router};
use crate::coordinator::server::{Breaker, Response, RestartPolicy, ServeError};
use crate::coordinator::warm::WarmStats;
use crate::obs;
use crate::util::logging;

/// Messages from the dispatcher to a shard.
pub(crate) enum Msg {
    Req(Request, mpsc::Sender<Response>),
    /// Warm-start from an artifact on disk; the shard acks with what it
    /// installed (see `Server::preload`).
    Preload(PathBuf, mpsc::Sender<Result<WarmStats>>),
    Stop,
}

/// Reply bookkeeping for an admitted request. Kept *outside* the engine
/// loop's unwind boundary (owned by the supervisor, borrowed by
/// `run_loop`) so a crashing incarnation can still answer every request it
/// had accepted — the exactly-one-`Response` invariant survives the crash.
pub(crate) struct PendingReply {
    task: usize,
    enqueued: Instant,
    tx: mpsc::Sender<Response>,
}

/// Shared slot holding the warm-start artifact path, set by
/// `Server::preload`. Supervisor restarts read it so a replacement engine
/// comes back with its adapters installed and its merged LRU pre-filled
/// instead of serving cold.
pub(crate) type WarmSlot = Arc<Mutex<Option<PathBuf>>>;

/// The execution engine a shard drives. `server::Engine` (the PJRT-backed
/// engine) is the production implementation; tests and non-PJRT harnesses
/// can plug in their own (see `Server::start_with`).
pub trait EngineCore {
    /// Token-sequence length the compiled executable expects.
    fn seq(&self) -> usize;
    /// Whether this engine owns an adapter for `task`.
    fn has_task(&self, task: usize) -> bool;
    /// Run one single-task batch; one prediction per (non-padding) request.
    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>>;
    /// The engine's serving counters, updated by the shard loop.
    fn stats_mut(&mut self) -> &mut ServeStats;
    /// Surrender the final counters when the shard drains.
    fn into_stats(self) -> ServeStats
    where
        Self: Sized;
    /// Warm-start from a compressed multi-task artifact (see
    /// `Engine::warm_from_artifact`). Engines without a warm path — test
    /// doubles, minimal backends — inherit this no-op, which reports zero
    /// installed adapters.
    fn preload(&mut self, _artifact: &Path) -> Result<WarmStats> {
        Ok(WarmStats::default())
    }
}

/// Handle to one running shard thread.
pub(crate) struct Shard {
    /// Bounded admission channel into the shard's worker loop.
    pub tx: mpsc::SyncSender<Msg>,
    /// The worker thread; joining yields the shard's final stats.
    pub handle: thread::JoinHandle<Result<ServeStats>>,
    /// This shard's circuit breaker, shared with the dispatcher.
    pub breaker: Arc<Breaker>,
}

impl Shard {
    /// Spawn a shard worker under supervision. `factory` builds the engine
    /// on the shard thread (the engine need not be `Send`) and is called
    /// again on every restart. Thread-spawn failure (fd/thread exhaustion)
    /// surfaces as an `Err` so `Server::start` can refuse to come up
    /// half-sharded instead of panicking the coordinator.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn<E, F>(
        ix: usize,
        policy: BatchPolicy,
        queue_cap: usize,
        heartbeat: Duration,
        restart: RestartPolicy,
        warm: WarmSlot,
        breaker: Arc<Breaker>,
        factory: F,
    ) -> Result<Shard>
    where
        E: EngineCore,
        F: Fn() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(queue_cap.max(1));
        let b = Arc::clone(&breaker);
        let handle = thread::Builder::new()
            .name(format!("mcnc-shard-{ix}"))
            .spawn(move || supervise(ix, rx, policy, heartbeat, restart, warm, b, factory))
            .with_context(|| format!("spawning shard {ix} worker thread"))?;
        Ok(Shard { tx, handle, breaker })
    }
}

pub(crate) fn error_response(req: &Request, err: ServeError) -> Response {
    Response {
        id: req.id,
        task: req.task,
        result: Err(err),
        latency: req.enqueued.elapsed(),
        batch_rows: 0,
    }
}

/// Answer a stranded pending reply with an error Response.
fn answer_pending(id: u64, p: PendingReply, err: ServeError) {
    let _ = p.tx.send(Response {
        id,
        task: p.task,
        result: Err(err),
        latency: p.enqueued.elapsed(),
        batch_rows: 0,
    });
}

/// Best-effort panic payload message (panics carry `&str` or `String`).
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// The shard supervisor: builds an engine, runs the serving loop under
/// `catch_unwind`, and on death answers stranded replies and restarts with
/// bounded exponential backoff. The restart budget counts *consecutive
/// unproductive incarnations* — an incarnation that served at least one
/// batch resets it, so a long-lived shard survives any number of isolated
/// crashes while a shard that can't even start fails fast.
#[allow(clippy::too_many_arguments)]
fn supervise<E, F>(
    ix: usize,
    rx: mpsc::Receiver<Msg>,
    policy: BatchPolicy,
    heartbeat: Duration,
    restart: RestartPolicy,
    warm: WarmSlot,
    breaker: Arc<Breaker>,
    factory: F,
) -> Result<ServeStats>
where
    E: EngineCore,
    F: Fn() -> Result<E>,
{
    let started = Instant::now();
    logging::set_thread_context(&format!("shard {ix}"));
    let sobs = obs::ShardObs::register(ix);
    let mut total = ServeStats::default();
    let mut pending: HashMap<u64, PendingReply> = HashMap::new();
    let mut unproductive = 0u32;
    let mut backoff = restart.backoff;
    loop {
        let cause = match factory() {
            Err(e) => format!("engine factory failed: {e:#}"),
            Ok(mut engine) => {
                if total.restarts > 0 {
                    // Re-warm the replacement engine from the preload
                    // artifact (the original Preload message was consumed
                    // by a previous incarnation). Best-effort: a failed
                    // re-warm leaves the shard serving cold, not dead.
                    let art = match warm.lock() {
                        Ok(g) => g.clone(),
                        Err(p) => p.into_inner().clone(),
                    };
                    if let Some(path) = art {
                        if engine.preload(&path).is_ok() {
                            obs::trace::event(
                                ix,
                                obs::Kind::Rewarm,
                                &format!("from {}", path.display()),
                            );
                        }
                    }
                }
                let served = AtomicBool::new(false);
                let outcome = panic::catch_unwind(AssertUnwindSafe(|| {
                    run_loop(engine, ix, &sobs, &rx, policy, heartbeat, &mut pending, &breaker, &served)
                }));
                match outcome {
                    Ok(stats) => {
                        // clean drain after Stop — the only normal exit
                        total.merge(&stats);
                        total.wall_secs = started.elapsed().as_secs_f64();
                        return Ok(total);
                    }
                    Err(payload) => {
                        if served.load(Ordering::Relaxed) {
                            unproductive = 0;
                            backoff = restart.backoff;
                        }
                        let msg = panic_msg(payload.as_ref());
                        // the crashed incarnation's router died with it:
                        // every request it had admitted must be answered
                        // now or its reply channel hangs forever
                        for (id, p) in pending.drain() {
                            total.errors += 1;
                            sobs.errors.inc();
                            answer_pending(
                                id,
                                p,
                                ServeError::Failed(format!(
                                    "shard {ix} crashed mid-flight: {msg}"
                                )),
                            );
                        }
                        format!("crashed: {msg}")
                    }
                }
            }
        };
        unproductive += 1;
        if unproductive > restart.max_restarts {
            total.wall_secs = started.elapsed().as_secs_f64();
            drain_dead(&rx, ix, &cause, &mut total, &mut pending, &sobs);
            return Err(anyhow!(
                "shard {ix} permanently dead after {unproductive} failed incarnations ({cause})"
            ));
        }
        total.restarts += 1;
        sobs.restarts.inc();
        obs::trace::event(ix, obs::Kind::Restart, &cause);
        thread::sleep(backoff);
        backoff = (backoff * 2).min(restart.max_backoff.max(restart.backoff));
    }
}

/// Terminal state of a permanently dead shard: answer everything queued
/// (and everything still arriving) with an error until `Stop`, so no reply
/// channel ever hangs on a shard that will not come back.
fn drain_dead(
    rx: &mpsc::Receiver<Msg>,
    ix: usize,
    cause: &str,
    total: &mut ServeStats,
    pending: &mut HashMap<u64, PendingReply>,
    sobs: &obs::ShardObs,
) {
    obs::trace::event(ix, obs::Kind::DrainDead, cause);
    for (id, p) in pending.drain() {
        total.errors += 1;
        sobs.errors.inc();
        answer_pending(id, p, ServeError::Failed(format!("shard {ix} dead: {cause}")));
    }
    loop {
        match rx.recv() {
            Ok(Msg::Stop) | Err(_) => break,
            Ok(Msg::Preload(_, ack)) => {
                let _ = ack.send(Err(anyhow!("shard {ix} dead: {cause}")));
            }
            Ok(Msg::Req(req, reply)) => {
                total.errors += 1;
                sobs.errors.inc();
                let _ = reply.send(error_response(
                    &req,
                    ServeError::Failed(format!("shard {ix} dead: {cause}")),
                ));
            }
        }
    }
}

/// Ingest one message: validate the request (wrong token count / unknown
/// task answer immediately with an error Response — they must never poison
/// a batch) or queue it for batching.
fn ingest<E: EngineCore>(
    msg: Msg,
    engine: &mut E,
    router: &mut Router,
    pending: &mut HashMap<u64, PendingReply>,
    stopping: &mut bool,
    sobs: &obs::ShardObs,
) {
    match msg {
        Msg::Stop => *stopping = true,
        Msg::Preload(artifact, ack) => {
            // a failed preload is answered on the ack channel, never a
            // shard abort — the shard keeps serving whatever it has
            let _ = ack.send(engine.preload(&artifact));
        }
        Msg::Req(req, reply) => {
            // register the reply channel *before* touching the engine: if
            // validation itself panics (a dying engine), the supervisor
            // can still answer this request from the pending map
            pending.insert(
                req.id,
                PendingReply { task: req.task, enqueued: req.enqueued, tx: reply },
            );
            let seq = engine.seq();
            let verdict = if req.tokens.len() != seq {
                Some(format!(
                    "request {} has {} tokens, executable wants {seq}",
                    req.id,
                    req.tokens.len()
                ))
            } else if !engine.has_task(req.task) {
                Some(format!("unknown task {}", req.task))
            } else {
                None
            };
            match verdict {
                Some(msg) => {
                    engine.stats_mut().errors += 1;
                    sobs.errors.inc();
                    if let Some(p) = pending.remove(&req.id) {
                        let _ = p.tx.send(error_response(&req, ServeError::Failed(msg)));
                    }
                }
                None => router.push(req),
            }
        }
    }
}

/// The shard worker loop for one engine incarnation. Returns the engine's
/// final stats when drained after `Stop`; a panic escaping this function
/// (engine death during ingest/validation) is the supervisor's restart
/// signal. `pending` is owned by the supervisor so an unwind cannot strand
/// reply channels; `served` reports whether this incarnation completed at
/// least one batch (it resets the restart budget).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_loop<E: EngineCore>(
    mut engine: E,
    ix: usize,
    sobs: &obs::ShardObs,
    rx: &mpsc::Receiver<Msg>,
    policy: BatchPolicy,
    heartbeat: Duration,
    pending: &mut HashMap<u64, PendingReply>,
    breaker: &Breaker,
    served: &AtomicBool,
) -> ServeStats {
    let mut router = Router::default();
    let started = Instant::now();
    let mut stopping = false;
    loop {
        engine.stats_mut().wakeups += 1;
        // 1) ingest everything already queued, without blocking
        loop {
            match rx.try_recv() {
                Ok(msg) => ingest(msg, &mut engine, &mut router, pending, &mut stopping, sobs),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    stopping = true;
                    break;
                }
            }
        }
        // 2) dispatch every ready batch; batch failures (and contained
        //    batch panics) answer that batch's requests with errors and
        //    the loop keeps serving
        loop {
            let now = Instant::now();
            // shed expired requests at batch formation: they are answered
            // with DeadlineExceeded and never packed into a batch
            router.sweep_expired(now);
            for req in router.take_expired() {
                engine.stats_mut().deadline_shed += 1;
                sobs.deadline_shed.inc();
                if let Some(p) = pending.remove(&req.id) {
                    let _ = p.tx.send(error_response(&req, ServeError::DeadlineExceeded));
                }
            }
            let Some(batch) = router.next_batch(policy, now, stopping) else {
                break;
            };
            for req in &batch.requests {
                let wait = now.duration_since(req.enqueued);
                engine.stats_mut().queue_wait.record(wait);
                sobs.queue_wait_us.record(wait);
                // the queue span ends exactly where the batch span starts
                obs::trace::span(req.trace_id(), ix, req.task, obs::Kind::Queue, req.enqueued, now);
            }
            let rows = batch.requests.len();
            sobs.batch_counter(batch.task).inc();
            sobs.batch_requests.add(rows as u64);
            // contain a panicking batch: its requests are answered Failed
            // below, exactly like a batch that returned Err, and the loop
            // keeps serving the other tasks
            let outcome = match panic::catch_unwind(AssertUnwindSafe(|| {
                engine.run_batch(&batch)
            })) {
                Ok(res) => res,
                Err(payload) => {
                    engine.stats_mut().batch_panics += 1;
                    sobs.batch_panics.inc();
                    Err(anyhow!("batch panicked: {}", panic_msg(payload.as_ref())))
                }
            };
            // a short prediction vector would strand the unmatched
            // requests' reply channels below — surface it as a batch error
            let outcome = outcome.and_then(|preds| {
                if preds.len() != rows {
                    bail!("engine returned {} predictions for {rows} requests", preds.len());
                }
                Ok(preds)
            });
            match outcome {
                Ok(preds) => {
                    served.store(true, Ordering::Relaxed);
                    breaker.record_success();
                    let done = Instant::now();
                    obs::trace::span(batch.trace_id(), ix, batch.task, obs::Kind::Batch, now, done);
                    for (req, tok) in batch.requests.iter().zip(preds) {
                        let latency = done.duration_since(req.enqueued);
                        engine.stats_mut().latency.record(latency);
                        sobs.latency_us.record(latency);
                        if let Some(p) = pending.remove(&req.id) {
                            let _ = p.tx.send(Response {
                                id: req.id,
                                task: req.task,
                                result: Ok(tok),
                                latency,
                                batch_rows: rows,
                            });
                        }
                    }
                }
                Err(e) => {
                    if breaker.record_failure() {
                        engine.stats_mut().breaker_opens += 1;
                        sobs.breaker_opens.inc();
                        obs::trace::event(ix, obs::Kind::BreakerOpen, &format!("{e:#}"));
                    }
                    let done = Instant::now();
                    obs::trace::span(batch.trace_id(), ix, batch.task, obs::Kind::Batch, now, done);
                    let msg = format!("batch failed: {e:#}");
                    for req in &batch.requests {
                        engine.stats_mut().errors += 1;
                        sobs.errors.inc();
                        if let Some(p) = pending.remove(&req.id) {
                            let _ = p.tx.send(Response {
                                id: req.id,
                                task: req.task,
                                result: Err(ServeError::Failed(msg.clone())),
                                latency: done.duration_since(req.enqueued),
                                batch_rows: rows,
                            });
                        }
                    }
                }
            }
        }
        if stopping && router.is_empty() {
            break;
        }
        // 3) block until the next router flush deadline — which includes
        //    queued requests' own deadlines, so expired requests are shed
        //    promptly — or the heartbeat when idle; no 200µs spin, and new
        //    messages wake us immediately
        let now = Instant::now();
        let wait = match router.next_deadline(policy) {
            Some(d) => d.saturating_duration_since(now).min(heartbeat),
            None => heartbeat,
        };
        match rx.recv_timeout(wait) {
            Ok(msg) => ingest(msg, &mut engine, &mut router, pending, &mut stopping, sobs),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => stopping = true,
        }
    }
    engine.stats_mut().wall_secs = started.elapsed().as_secs_f64();
    engine.into_stats()
}
