//! One engine shard: the worker loop behind the sharded serving
//! coordinator. Each shard owns its execution engine (Session, adapter
//! slice, merged LRU) on a dedicated thread — `PjRtClient` is not `Send`,
//! so engines are constructed *inside* the thread via a factory — and
//! drains a bounded admission channel into its own `Router`.
//!
//! Fault isolation is the shard loop's contract: a malformed request is
//! answered with an error `Response` at ingest, a failing batch produces
//! error Responses for exactly that batch's requests, and the loop itself
//! never `?`-aborts on per-request work. The loop also never busy-waits:
//! between batches it blocks on the channel until the router's next flush
//! deadline (or a coarse heartbeat when idle).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::metrics::ServeStats;
use crate::coordinator::router::{Batch, BatchPolicy, Request, Router};
use crate::coordinator::server::{Response, ServeError};
use crate::coordinator::warm::WarmStats;

/// Messages from the dispatcher to a shard.
pub(crate) enum Msg {
    Req(Request, mpsc::Sender<Response>),
    /// Warm-start from an artifact on disk; the shard acks with what it
    /// installed (see `Server::preload`).
    Preload(PathBuf, mpsc::Sender<Result<WarmStats>>),
    Stop,
}

/// The execution engine a shard drives. `server::Engine` (the PJRT-backed
/// engine) is the production implementation; tests and non-PJRT harnesses
/// can plug in their own (see `Server::start_with`).
pub trait EngineCore {
    /// Token-sequence length the compiled executable expects.
    fn seq(&self) -> usize;
    /// Whether this engine owns an adapter for `task`.
    fn has_task(&self, task: usize) -> bool;
    /// Run one single-task batch; one prediction per (non-padding) request.
    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>>;
    /// The engine's serving counters, updated by the shard loop.
    fn stats_mut(&mut self) -> &mut ServeStats;
    /// Surrender the final counters when the shard drains.
    fn into_stats(self) -> ServeStats
    where
        Self: Sized;
    /// Warm-start from a compressed multi-task artifact (see
    /// `Engine::warm_from_artifact`). Engines without a warm path — test
    /// doubles, minimal backends — inherit this no-op, which reports zero
    /// installed adapters.
    fn preload(&mut self, _artifact: &Path) -> Result<WarmStats> {
        Ok(WarmStats::default())
    }
}

/// Handle to one running shard thread.
pub(crate) struct Shard {
    /// Bounded admission channel into the shard's worker loop.
    pub tx: mpsc::SyncSender<Msg>,
    /// The worker thread; joining yields the shard's final stats.
    pub handle: thread::JoinHandle<Result<ServeStats>>,
}

impl Shard {
    /// Spawn a shard worker. `factory` builds the engine on the shard
    /// thread (the engine need not be `Send`); a factory error terminates
    /// the shard, surfaced by `Server::stop`.
    pub fn spawn<E, F>(
        ix: usize,
        policy: BatchPolicy,
        queue_cap: usize,
        heartbeat: Duration,
        factory: F,
    ) -> Shard
    where
        E: EngineCore,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(queue_cap.max(1));
        let handle = thread::Builder::new()
            .name(format!("mcnc-shard-{ix}"))
            .spawn(move || -> Result<ServeStats> {
                let engine = factory()?;
                run_loop(engine, rx, policy, heartbeat)
            })
            .expect("spawn shard");
        Shard { tx, handle }
    }
}

pub(crate) fn error_response(req: &Request, err: ServeError) -> Response {
    Response {
        id: req.id,
        task: req.task,
        result: Err(err),
        latency: req.enqueued.elapsed(),
        batch_rows: 0,
    }
}

/// Ingest one message: validate the request (wrong token count / unknown
/// task answer immediately with an error Response — they must never poison
/// a batch) or queue it for batching.
fn ingest<E: EngineCore>(
    msg: Msg,
    engine: &mut E,
    router: &mut Router,
    pending: &mut HashMap<u64, mpsc::Sender<Response>>,
    stopping: &mut bool,
) {
    match msg {
        Msg::Stop => *stopping = true,
        Msg::Preload(artifact, ack) => {
            // a failed preload is answered on the ack channel, never a
            // shard abort — the shard keeps serving whatever it has
            let _ = ack.send(engine.preload(&artifact));
        }
        Msg::Req(req, reply) => {
            let seq = engine.seq();
            if req.tokens.len() != seq {
                engine.stats_mut().errors += 1;
                let _ = reply.send(error_response(
                    &req,
                    ServeError::Failed(format!(
                        "request {} has {} tokens, executable wants {seq}",
                        req.id,
                        req.tokens.len()
                    )),
                ));
            } else if !engine.has_task(req.task) {
                engine.stats_mut().errors += 1;
                let _ = reply.send(error_response(
                    &req,
                    ServeError::Failed(format!("unknown task {}", req.task)),
                ));
            } else {
                pending.insert(req.id, reply);
                router.push(req);
            }
        }
    }
}

/// The shard worker loop. Returns the engine's final stats when drained.
pub(crate) fn run_loop<E: EngineCore>(
    mut engine: E,
    rx: mpsc::Receiver<Msg>,
    policy: BatchPolicy,
    heartbeat: Duration,
) -> Result<ServeStats> {
    let mut router = Router::default();
    let mut pending: HashMap<u64, mpsc::Sender<Response>> = HashMap::new();
    let started = Instant::now();
    let mut stopping = false;
    loop {
        engine.stats_mut().wakeups += 1;
        // 1) ingest everything already queued, without blocking
        loop {
            match rx.try_recv() {
                Ok(msg) => ingest(msg, &mut engine, &mut router, &mut pending, &mut stopping),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    stopping = true;
                    break;
                }
            }
        }
        // 2) dispatch every ready batch; batch failures answer that batch's
        //    requests with errors and the loop keeps serving
        loop {
            let now = Instant::now();
            let Some(batch) = router.next_batch(policy, now, stopping) else {
                break;
            };
            for req in &batch.requests {
                engine.stats_mut().queue_wait.record(now.duration_since(req.enqueued));
            }
            let rows = batch.requests.len();
            // a short prediction vector would strand the unmatched
            // requests' reply channels below — surface it as a batch error
            let outcome = engine.run_batch(&batch).and_then(|preds| {
                if preds.len() != rows {
                    bail!("engine returned {} predictions for {rows} requests", preds.len());
                }
                Ok(preds)
            });
            match outcome {
                Ok(preds) => {
                    let done = Instant::now();
                    for (req, tok) in batch.requests.iter().zip(preds) {
                        let latency = done.duration_since(req.enqueued);
                        engine.stats_mut().latency.record(latency);
                        if let Some(reply) = pending.remove(&req.id) {
                            let _ = reply.send(Response {
                                id: req.id,
                                task: req.task,
                                result: Ok(tok),
                                latency,
                                batch_rows: rows,
                            });
                        }
                    }
                }
                Err(e) => {
                    let done = Instant::now();
                    let msg = format!("batch failed: {e:#}");
                    for req in &batch.requests {
                        engine.stats_mut().errors += 1;
                        if let Some(reply) = pending.remove(&req.id) {
                            let _ = reply.send(Response {
                                id: req.id,
                                task: req.task,
                                result: Err(ServeError::Failed(msg.clone())),
                                latency: done.duration_since(req.enqueued),
                                batch_rows: rows,
                            });
                        }
                    }
                }
            }
        }
        if stopping && router.is_empty() {
            break;
        }
        // 3) block until the next router flush deadline (or the heartbeat
        //    when idle) — no 200µs spin; new messages wake us immediately
        let now = Instant::now();
        let wait = match router.next_deadline(policy) {
            Some(d) => d.saturating_duration_since(now).min(heartbeat),
            None => heartbeat,
        };
        match rx.recv_timeout(wait) {
            Ok(msg) => ingest(msg, &mut engine, &mut router, &mut pending, &mut stopping),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => stopping = true,
        }
    }
    engine.stats_mut().wall_secs = started.elapsed().as_secs_f64();
    Ok(engine.into_stats())
}
