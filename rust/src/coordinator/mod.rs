//! L3 — the serving coordinator: request router, dynamic batcher, adapter
//! cache, single-threaded PJRT engine, workload generators and metrics.
//! This is where the paper's multi-task adapter-serving claim (Table 4)
//! and the transfer claim (Table 8) are exercised.

pub mod cache;
pub mod metrics;
pub mod router;
pub mod server;
pub mod workload;

pub use cache::LruCache;
pub use metrics::{Histogram, ServeStats};
pub use router::{Batch, BatchPolicy, Request, Router};
pub use server::{Engine, Mode, Response, Server, ServerCfg};
pub use workload::{open_loop, Arrival, Zipf};
