//! L3 — the serving coordinator: request router, dynamic batcher, adapter
//! cache, sharded PJRT engine workers behind a dispatching front-end,
//! workload generators and metrics. This is where the paper's multi-task
//! adapter-serving claim (Table 4) and the transfer claim (Table 8) are
//! exercised: requests fan out to `n_shards` engine threads by task
//! affinity, faults stay per-request, and overload is rejected explicitly.
//!
//! Startup is a first-class path too: [`Server::preload`] /
//! [`Engine::warm_from_artifact`] pre-fill every shard's adapter registry
//! (and, natively-reconstructing Merged engines, the merged-θ LRU) from one
//! compressed [`warm`] artifact, decoded in parallel — so a freshly spawned
//! server answers its first request per task from cache instead of paying
//! entropy decode + reconstruction on the request path.
//!
//! The PJRT engine is not the only [`EngineCore`]: [`qserve::QuantEngine`]
//! serves 2-D head tasks straight from decoded GEMM panels, and quantized
//! artifacts stay in the compressed domain end to end — rANS → int8 panels
//! → int8 GEMM, no f32 weight ever materialized (f32 panels remain the
//! per-frame oracle/fallback path).
//!
//! Fault *recovery* is first-class as well: shard engines run under a
//! supervisor that contains batch panics, restarts dead engines with
//! bounded backoff (re-warming from the preload artifact), sheds expired
//! requests ([`ServeError::DeadlineExceeded`]), and trips a per-shard
//! circuit breaker on consecutive batch failures. The [`chaos`] module
//! provides the deterministic fault-injection harness that proves the
//! exactly-one-`Response` invariant under all of it.
//!
//! Every serving counter here is double-booked: the per-shard `ServeStats`
//! (exact, returned by [`Server::stop`]) and a mirror in the process-wide
//! [`crate::obs`] registry ([`Server::metrics_snapshot`], labelled by
//! `shard`/`task_mod`), which also carries the request trace spans — queue
//! wait, batch execution, merged-LRU fill, codec decode — and the
//! supervisor's structured events (restart, re-warm, breaker-open). All
//! coordinator counters go through `obs` handles; mcnc-lint's
//! `metrics-naming` rule keeps bare atomic counters out of this module.

#![warn(missing_docs)]

pub mod cache;
pub mod chaos;
pub mod metrics;
pub mod qserve;
pub mod router;
pub mod server;
pub mod shard;
pub mod warm;
pub mod workload;

pub use cache::LruCache;
pub use chaos::{Chaos, ChaosCfg, ChaosReport, FaultyEngine};
pub use metrics::{Histogram, ServeStats};
pub use qserve::{QServeCfg, QuantEngine, WEIGHT_SLOT};
pub use router::{Batch, BatchPolicy, Request, Router};
pub use server::{
    BreakerCfg, Engine, Mode, Response, RestartPolicy, RetryPolicy, ServeError, Server,
    ServerCfg,
};
pub use shard::EngineCore;
pub use warm::WarmStats;
pub use workload::{
    open_loop, replay, replay_socket, replay_with, Arrival, ReplayReport, SocketReport, Zipf,
};
