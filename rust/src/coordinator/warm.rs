//! Warm-start artifacts: one MCNC2 container carrying *every* task's
//! adapter, so a sharded server can pre-fill its adapter registry and
//! merged-θ LRU at startup instead of paying serial entropy decode +
//! reconstruction on the first request per task (the paper's "fast model
//! reconstruction" claim applied to cold starts; ZipNN makes the same
//! point for checkpoint transfer).
//!
//! Layout: an ordinary MCNC2 stream (see `docs/FORMAT.md`) whose frames
//! are named `task{t}/{slot}` — e.g. `task3/alpha` — with `slot` matching
//! the predict executable's trainable input names. The container `entry`
//! must start with the serving adapter-family kind, exactly like the
//! single-task encoded-adapter path (`Engine::install_adapter_encoded`).
//!
//! Consumption is two-level parallel: `Server::preload` broadcasts the
//! artifact path to every shard (shards decode concurrently and keep only
//! the tasks they own), and each shard's `Engine::warm_from_artifact`
//! fans frame decode across the thread pool via the codec `Decoder`'s
//! `decode_all`.
//!
//! The artifact path also outlives the first preload: `Server::preload`
//! parks it in a slot the shard supervisors read, so an engine rebuilt
//! after a crash re-warms itself from the same artifact and comes back
//! with its adapters installed instead of serving cold (see `shard.rs`).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::codec::{Codec, ContainerHeader, Encoder};
use crate::runtime::manifest::{IoSpec, Role};
use crate::runtime::Session;
use crate::tensor::Tensor;

/// Frame name of task `task`'s adapter slot `slot` in a warm-start
/// artifact (`task{t}/{slot}`).
pub fn frame_name(task: usize, slot: &str) -> String {
    format!("task{task}/{slot}")
}

/// Parse a warm-artifact frame name back into `(task, slot)`; `None` when
/// the name does not follow the `task{t}/{slot}` convention.
pub fn parse_frame_name(name: &str) -> Option<(usize, &str)> {
    let rest = name.strip_prefix("task")?;
    let (t, slot) = rest.split_once('/')?;
    t.parse().ok().map(|t| (t, slot))
}

/// What one warm-start ingest accomplished (summed across shards by
/// `Server::preload`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WarmStats {
    /// Adapters installed into the engine's task registry.
    pub installed: usize,
    /// Merged-θ LRU entries pre-filled through the native reconstruction
    /// engine (only in `Mode::Merged` with `native_recon` on a family that
    /// supports it — otherwise adapters install but θ stays lazy).
    pub prefilled: usize,
    /// Frames skipped because another shard owns their task.
    pub skipped: usize,
    /// Frames that arrived quantized and stayed in the compressed domain
    /// end to end: the panel-serving engine counts the frames it ingested
    /// as `PackedBQ` (int8 GEMM operands, no f32 weight materialized);
    /// the PJRT engine counts quantized-codec frames it decoded. Zero on
    /// lossless artifacts or when the f32 oracle path is forced.
    pub quantized: usize,
}

impl WarmStats {
    /// Fold another shard's warm-start outcome into this one.
    pub fn merge(&mut self, other: &WarmStats) {
        self.installed += other.installed;
        self.prefilled += other.prefilled;
        self.skipped += other.skipped;
        self.quantized += other.quantized;
    }
}

/// Write a warm-start artifact: `adapters` is `(task, slots)` with each
/// slot a `(name, tensor)` pair in the predict executable's trainable
/// order. Returns the wire size.
pub fn write_artifact(
    w: impl Write,
    kind: &str,
    seed: u64,
    codec: Codec,
    adapters: &[(usize, Vec<(String, Tensor)>)],
) -> Result<usize> {
    let n_frames: usize = adapters.iter().map(|(_, slots)| slots.len()).sum();
    let header = ContainerHeader {
        entry: format!("{kind}_warm"),
        seed,
        step: 0.0,
        n_tensors: Some(n_frames),
    };
    let mut enc = Encoder::new(w, &header)?;
    for (task, slots) in adapters {
        for (slot, t) in slots {
            enc.write_tensor(&frame_name(*task, slot), t, codec)?;
        }
    }
    let (_, wire) = enc.finish()?;
    Ok(wire)
}

/// Group a decoded artifact's frames into per-task adapters for one shard:
/// frames whose task is owned elsewhere (`task % n_shards != shard`) are
/// counted as skipped, owned tasks get their slots ordered by `specs`
/// (frames may arrive in any order), and a missing, unknown or duplicate
/// slot is an error. Tasks come back sorted ascending, so installation
/// order is deterministic.
pub fn group_for_shard(
    frames: Vec<(String, Tensor, Codec)>,
    specs: &[IoSpec],
    shard: usize,
    n_shards: usize,
) -> Result<(Vec<(usize, Vec<Tensor>)>, usize)> {
    let n_shards = n_shards.max(1);
    let mut by_task: BTreeMap<usize, Vec<(String, Tensor)>> = BTreeMap::new();
    let mut skipped = 0usize;
    for (name, t, _codec) in frames {
        let Some((task, slot)) = parse_frame_name(&name) else {
            bail!("warm artifact frame {name:?} is not task{{t}}/{{slot}}-named");
        };
        if task % n_shards != shard {
            skipped += 1;
            continue;
        }
        by_task.entry(task).or_default().push((slot.to_string(), t));
    }
    let mut out = Vec::with_capacity(by_task.len());
    for (task, mut slots) in by_task {
        let mut ordered = Vec::with_capacity(specs.len());
        for spec in specs {
            let ix = slots.iter().position(|(n, _)| n == &spec.name).ok_or_else(|| {
                anyhow!("warm artifact task {task} is missing slot {:?}", spec.name)
            })?;
            ordered.push(slots.swap_remove(ix).1);
        }
        if !slots.is_empty() {
            let extra: Vec<&str> = slots.iter().map(|(n, _)| n.as_str()).collect();
            bail!("warm artifact task {task} has unknown slots: {}", extra.join(", "));
        }
        out.push((task, ordered));
    }
    Ok((out, skipped))
}

/// Synthesize the per-task demo adapters an engine seeds itself with (the
/// same task-seed derivation as `Engine::new_sharded`) and write them as a
/// warm-start artifact — the producer behind `mcnc warm`. Needs the
/// artifact manifest (for the predict entry's trainable specs) but no
/// PJRT execution. Returns the wire size.
pub fn write_synth_artifact(
    artifacts: &Path,
    out: &Path,
    kind: &str,
    n_tasks: usize,
    seed: u64,
    codec: Codec,
) -> Result<usize> {
    let session = Session::open(artifacts).context("opening artifact manifest")?;
    let entry = session.entry(&format!("{kind}_predict"))?.clone();
    let slot_names: Vec<String> = entry
        .inputs
        .iter()
        .filter(|s| s.role == Role::Trainable)
        .map(|s| s.name.clone())
        .collect();
    let mut adapters = Vec::with_capacity(n_tasks);
    for task in 0..n_tasks {
        let tr = super::server::synth_adapter(&entry, seed, task)?;
        if tr.len() != slot_names.len() {
            bail!(
                "task {task}: synthesized {} trainables for {} specs",
                tr.len(),
                slot_names.len()
            );
        }
        adapters.push((task, slot_names.iter().cloned().zip(tr).collect()));
    }
    let f = std::fs::File::create(out)
        .with_context(|| format!("creating warm-start artifact {}", out.display()))?;
    write_artifact(std::io::BufWriter::new(f), kind, seed, codec, &adapters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    fn spec(name: &str, shape: &[usize]) -> IoSpec {
        IoSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype: DType::F32,
            role: Role::Trainable,
            init: None,
        }
    }

    fn frames_for(tasks: &[usize]) -> Vec<(String, Tensor, Codec)> {
        let mut out = Vec::new();
        for &t in tasks {
            // deliberately out of spec order: beta before alpha
            out.push((frame_name(t, "beta"), Tensor::ones(&[3]), Codec::Lossless));
            out.push((frame_name(t, "alpha"), Tensor::zeros(&[2, 3]), Codec::Lossless));
        }
        out
    }

    #[test]
    fn frame_names_roundtrip() {
        assert_eq!(frame_name(3, "alpha"), "task3/alpha");
        assert_eq!(parse_frame_name("task3/alpha"), Some((3, "alpha")));
        assert_eq!(parse_frame_name("task12/gen/w0"), Some((12, "gen/w0")));
        assert_eq!(parse_frame_name("alpha"), None);
        assert_eq!(parse_frame_name("taskX/alpha"), None);
        assert_eq!(parse_frame_name("task3"), None);
    }

    #[test]
    fn group_orders_slots_and_filters_ownership() {
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        // 2 shards: shard 1 owns tasks 1 and 3, skips 0 and 2
        let (owned, skipped) = group_for_shard(frames_for(&[0, 1, 2, 3]), &specs, 1, 2).unwrap();
        assert_eq!(skipped, 4, "two frames per foreign task");
        assert_eq!(owned.len(), 2);
        assert_eq!(owned[0].0, 1);
        assert_eq!(owned[1].0, 3);
        for (_, slots) in &owned {
            assert_eq!(slots.len(), 2);
            assert_eq!(slots[0].dims, vec![2, 3], "alpha first (spec order)");
            assert_eq!(slots[1].dims, vec![3]);
        }
    }

    #[test]
    fn group_rejects_missing_unknown_and_misnamed() {
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        let mut frames = frames_for(&[0]);
        frames.pop(); // drop task0/alpha
        let err = group_for_shard(frames, &specs, 0, 1).unwrap_err();
        assert!(format!("{err:#}").contains("missing slot"), "{err:#}");

        let mut frames = frames_for(&[0]);
        frames.push((frame_name(0, "gamma"), Tensor::ones(&[1]), Codec::Lossless));
        let err = group_for_shard(frames, &specs, 0, 1).unwrap_err();
        assert!(format!("{err:#}").contains("unknown slots"), "{err:#}");

        let frames = vec![("alpha".to_string(), Tensor::ones(&[1]), Codec::Lossless)];
        let err = group_for_shard(frames, &specs, 0, 1).unwrap_err();
        assert!(format!("{err:#}").contains("task{t}/{slot}"), "{err:#}");
    }

    #[test]
    fn artifact_roundtrips_through_codec() {
        let adapters: Vec<(usize, Vec<(String, Tensor)>)> = (0..3)
            .map(|t| {
                (
                    t,
                    vec![
                        ("alpha".to_string(), Tensor::ones(&[2, 3])),
                        ("beta".to_string(), Tensor::zeros(&[3])),
                    ],
                )
            })
            .collect();
        let mut bytes = Vec::new();
        let wire =
            write_artifact(&mut bytes, "lm_mcnclora8", 9, Codec::Lossless, &adapters).unwrap();
        assert_eq!(wire, bytes.len());

        let mut dec = crate::codec::Decoder::new(&bytes[..]).unwrap();
        assert!(dec.header().entry.starts_with("lm_mcnclora8"));
        assert_eq!(dec.header().seed, 9);
        assert_eq!(dec.header().n_tensors, Some(6));
        let frames = dec.decode_all().unwrap();
        assert_eq!(frames.len(), 6);
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        let (owned, skipped) = group_for_shard(frames, &specs, 0, 1).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(owned.len(), 3);
        assert_eq!(owned.iter().map(|(t, _)| *t).collect::<Vec<_>>(), vec![0, 1, 2]);
    }
}
