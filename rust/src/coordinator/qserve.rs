//! Compressed-domain panel serving: a PJRT-free [`EngineCore`] whose
//! per-task weight is one 2-D `[seq, vocab]` head matrix held as decoded
//! GEMM panels — *quantized* panels ([`kernel::PackedBQ`], fed to the int8
//! [`kernel::gemm_q`]) when the artifact frame's codec and scale-block
//! layout admit the compressed-domain kernel, f32 panels
//! ([`kernel::PackedB`]) otherwise. Frames go rANS → panels with no f32
//! weight materialization on the quantized path; the f32 path is retained
//! as the oracle and fallback, selected per frame by codec tag (see
//! `codec::container::decode_frame_into_panels`).
//!
//! Panels arrive two ways, mirroring the PJRT engine's Merged mode:
//!
//! * **warm**: [`EngineCore::preload`] (via `Server::preload`) ingests a
//!   whole `task{t}/w`-framed warm artifact in parallel, each shard
//!   keeping only the tasks it owns — the supervisor re-runs this after a
//!   crash, so a killed shard comes back with its panels re-filled;
//! * **cold**: a request for a task with no panels triggers a cold fill
//!   from the configured artifact inside `run_batch`, counted in
//!   `ServeStats::cache_misses` exactly like a Merged-mode cold
//!   reconstruction (quantized fills also count `native_fills` — they run
//!   on the native int8 GEMM).
//!
//! A batch executes as `logits[m, vocab] = tokens[m, seq] · W[seq, vocab]`
//! with the token values as f32 features, then per-row argmax. On the
//! quantized path the activations are absmax-quantized per scale group
//! ([`kernel::quantize_a`]) so the whole product runs in int8×int8 → i32;
//! `force_f32` pins every task to the f32 oracle instead, which is how
//! `rust/tests/integration_quant_serving.rs` proves the two paths agree
//! on every prediction over a live socket.
//!
//! This file is on mcnc-lint's `panic-freedom` list: the fill path runs on
//! live requests, so every fallible step propagates a `Result`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::codec::{self, PackedPanels};
use crate::coordinator::metrics::ServeStats;
use crate::coordinator::router::Batch;
use crate::coordinator::shard::EngineCore;
use crate::coordinator::warm::{self, WarmStats};
use crate::mcnc::kernel;
use crate::obs;

/// The single adapter slot a panel-served task carries: its head matrix,
/// framed as `task{t}/w` in warm artifacts.
pub const WEIGHT_SLOT: &str = "w";

/// Configuration for [`QuantEngine`] — one value shared by every shard's
/// factory (see `Server::start_with`).
#[derive(Debug, Clone)]
pub struct QServeCfg {
    /// Adapter-family kind; warm artifacts must carry a matching
    /// `{kind}_warm` container entry (the same convention as the PJRT
    /// engine's warm path).
    pub kind: String,
    /// Tasks served across all shards; shard `s` owns `t % n_shards == s`.
    pub n_tasks: usize,
    /// Shard count the task space is split over.
    pub n_shards: usize,
    /// Token-sequence length = rows `k` of every task's weight.
    pub seq: usize,
    /// Vocabulary size = columns `n` of every task's weight.
    pub vocab: usize,
    /// Pin every task to the f32 panel path, even for quantized frames —
    /// the oracle switch the parity tests flip.
    pub force_f32: bool,
    /// Artifact backing cold fills: a request for a task with no panels
    /// decodes them from here. `None` means preload-only (cold tasks fail
    /// their batches instead).
    pub artifact: Option<PathBuf>,
}

impl QServeCfg {
    /// A cfg serving `n_tasks` tasks of `[seq, vocab]` heads on one shard,
    /// quantized path enabled, no cold-fill artifact.
    pub fn new(kind: &str, n_tasks: usize, seq: usize, vocab: usize) -> QServeCfg {
        QServeCfg {
            kind: kind.to_string(),
            n_tasks,
            n_shards: 1,
            seq,
            vocab,
            force_f32: false,
            artifact: None,
        }
    }
}

/// One shard's panel-serving engine. Single-threaded by design (one
/// engine per shard thread); `Server` fans requests across shards.
pub struct QuantEngine {
    cfg: QServeCfg,
    shard: usize,
    /// Per-task decoded panels, quantized or f32 per the source frame.
    panels: HashMap<usize, PackedPanels>,
    /// This engine's serving counters (merged across shards on stop).
    pub stats: ServeStats,
}

impl QuantEngine {
    /// Build the engine for one shard. Rejects degenerate geometry up
    /// front so the serving path never sees a zero-sized GEMM.
    pub fn new(cfg: QServeCfg, shard: usize) -> Result<QuantEngine> {
        if cfg.seq == 0 || cfg.vocab == 0 {
            bail!("panel engine needs seq and vocab > 0, got [{}, {}]", cfg.seq, cfg.vocab);
        }
        if shard >= cfg.n_shards.max(1) {
            bail!("shard {shard} out of range for {} shards", cfg.n_shards.max(1));
        }
        Ok(QuantEngine { cfg, shard, panels: HashMap::new(), stats: ServeStats::default() })
    }

    /// Whether this shard owns `task`.
    fn owned(&self, task: usize) -> bool {
        task < self.cfg.n_tasks && task % self.cfg.n_shards.max(1) == self.shard
    }

    /// How many tasks currently have panels resident, and how many of
    /// those are on the compressed-domain path — the warm/parity tests'
    /// introspection hook.
    pub fn resident(&self) -> (usize, usize) {
        let quant = self.panels.values().filter(|p| p.is_quant()).count();
        (self.panels.len(), quant)
    }

    /// Panel geometry must match the configured head shape; decode paths
    /// can't check this (they see only the frame), so install does.
    fn validate_panels(&self, task: usize, p: &PackedPanels) -> Result<()> {
        if p.k() != self.cfg.seq || p.n() != self.cfg.vocab {
            bail!(
                "task {task}: weight is [{}, {}], engine serves [{}, {}] heads",
                p.k(),
                p.n(),
                self.cfg.seq,
                self.cfg.vocab
            );
        }
        Ok(())
    }

    /// Decode one cold task's panels from the configured artifact. Pays a
    /// full container scan (every frame CRC-checked, only the wanted one
    /// entropy-decoded) — the cold path a preload exists to avoid.
    fn cold_fill(&self, task: usize) -> Result<PackedPanels> {
        let Some(path) = &self.cfg.artifact else {
            bail!("task {task} has no panels and no cold-fill artifact is configured");
        };
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening cold-fill artifact {}", path.display()))?;
        let mut dec = codec::Decoder::new(std::io::BufReader::new(f))
            .context("decoding cold-fill artifact")?;
        if !dec.header().entry.starts_with(&self.cfg.kind) {
            bail!(
                "cold-fill artifact is for entry {:?}, this engine serves kind {:?}",
                dec.header().entry,
                self.cfg.kind
            );
        }
        let want = warm::frame_name(task, WEIGHT_SLOT);
        let keep = want.clone();
        let mut frames = dec.decode_all_panels_filtered_with(
            crate::util::threadpool::global(),
            kernel::active(),
            self.cfg.force_f32,
            move |name| name == keep,
        )?;
        if frames.len() > 1 {
            bail!("artifact has {} frames named {want:?}", frames.len());
        }
        let (_, p, codec) =
            frames.pop().ok_or_else(|| anyhow!("artifact has no frame {want:?}"))?;
        self.validate_panels(task, &p)?;
        obs::count_decoded_frame(codec.name());
        Ok(p)
    }

    /// Panels for `task`, filling cold from the artifact if needed; the
    /// bool says whether this call was a (cache-miss) fill.
    fn task_panels(&mut self, task: usize) -> Result<(&PackedPanels, bool)> {
        let filled = if self.panels.contains_key(&task) {
            false
        } else {
            let p = self.cold_fill(task)?;
            self.panels.insert(task, p);
            true
        };
        let p = self
            .panels
            .get(&task)
            .ok_or_else(|| anyhow!("task {task}: panels missing after fill"))?;
        Ok((p, filled))
    }
}

impl EngineCore for QuantEngine {
    fn seq(&self) -> usize {
        self.cfg.seq
    }

    fn has_task(&self, task: usize) -> bool {
        self.owned(task)
    }

    /// One single-task batch: token features × the task head, per-row
    /// argmax. Quantized panels run the whole product in the compressed
    /// domain; f32 panels are the oracle path.
    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        if !self.owned(batch.task) {
            bail!("task {} belongs to another shard, not {}", batch.task, self.shard);
        }
        let (k, n) = (self.cfg.seq, self.cfg.vocab);
        let m = batch.requests.len();
        let mut a = vec![0.0f32; m * k];
        for (i, req) in batch.requests.iter().enumerate() {
            if req.tokens.len() != k {
                bail!("request {} has {} tokens, engine wants {k}", req.id, req.tokens.len());
            }
            for (j, &t) in req.tokens.iter().enumerate() {
                a[i * k + j] = t as f32;
            }
        }

        let (p, filled) = self.task_panels(batch.task)?;
        let mut c = vec![0.0f32; m * n];
        let quant = match p {
            PackedPanels::F32(pb) => {
                kernel::gemm(&a, m, pb, &mut c);
                false
            }
            PackedPanels::Quant(pq) => {
                let qa = kernel::quantize_a(&a, m, k, pq.group_rows());
                kernel::gemm_q(&qa, pq, &mut c);
                true
            }
        };
        if filled {
            self.stats.cache_misses += 1;
            if quant {
                // a quantized fill is served by the native int8 GEMM, the
                // compressed-domain analogue of a Merged native fill
                self.stats.native_fills += 1;
            }
        } else {
            self.stats.cache_hits += 1;
        }

        let preds = (0..m)
            .map(|i| {
                let row = &c[i * n..(i + 1) * n];
                let mut best = (f32::MIN, 0i32);
                for (j, &v) in row.iter().enumerate() {
                    if v > best.0 {
                        best = (v, j as i32);
                    }
                }
                best.1
            })
            .collect();
        self.stats.batches += 1;
        self.stats.rows += m as u64;
        Ok(preds)
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }

    /// Warm-start every owned task's panels from a `task{t}/w`-framed
    /// warm artifact: frames decode in parallel straight to panels (the
    /// quantized ones never touching f32), foreign frames are CRC-checked
    /// and skipped. `WarmStats::quantized` counts the frames that landed
    /// on the compressed-domain path; `prefilled` equals `installed`
    /// because panels *are* the serving form — the first request per
    /// warmed task is a cache hit.
    fn preload(&mut self, artifact: &Path) -> Result<WarmStats> {
        let f = std::fs::File::open(artifact).with_context(|| {
            format!("opening warm-start artifact {}", artifact.display())
        })?;
        let mut dec = codec::Decoder::new(std::io::BufReader::new(f))
            .context("decoding warm-start artifact")?;
        if !dec.header().entry.starts_with(&self.cfg.kind) {
            bail!(
                "warm artifact is for entry {:?}, this engine serves kind {:?}",
                dec.header().entry,
                self.cfg.kind
            );
        }
        let n_shards = self.cfg.n_shards.max(1);
        let shard = self.shard;
        // misnamed frames pass the filter so the naming error below stays
        // precise instead of frames vanishing silently
        let frames = dec.decode_all_panels_filtered_with(
            crate::util::threadpool::global(),
            kernel::active(),
            self.cfg.force_f32,
            move |name| match warm::parse_frame_name(name) {
                Some((task, _)) => task % n_shards == shard,
                None => true,
            },
        )?;
        let skipped = dec.frames_seen() - frames.len();
        // validate everything before the first install so a bad artifact
        // fails the preload without leaving the shard half-warmed
        let mut owned = Vec::with_capacity(frames.len());
        for (name, p, codec) in frames {
            let Some((task, slot)) = warm::parse_frame_name(&name) else {
                bail!("warm artifact frame {name:?} is not task{{t}}/{{slot}}-named");
            };
            if slot != WEIGHT_SLOT {
                bail!(
                    "warm artifact frame {name:?}: the panel engine serves single-slot \
                     {WEIGHT_SLOT:?} adapters"
                );
            }
            if task >= self.cfg.n_tasks {
                bail!(
                    "warm artifact task {task} out of range (server has {} tasks)",
                    self.cfg.n_tasks
                );
            }
            self.validate_panels(task, &p)?;
            owned.push((task, p, codec));
        }
        let mut stats = WarmStats { skipped, ..WarmStats::default() };
        for (task, p, codec) in owned {
            obs::count_decoded_frame(codec.name());
            if p.is_quant() {
                stats.quantized += 1;
            }
            self.panels.insert(task, p);
            stats.installed += 1;
        }
        stats.prefilled = stats.installed;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Codec;
    use crate::coordinator::router::Request;
    use crate::tensor::Tensor;
    use std::time::Instant;

    /// An artifact of `n_tasks` heads where task `t`'s weight steers every
    /// prediction to class `t % vocab` by a wide margin (dominant column 8.0,
    /// noise ±0.25 — far beyond int8 quantization error for these shapes).
    fn fixture_artifact(n_tasks: usize, seq: usize, vocab: usize, codec: Codec) -> Vec<u8> {
        let mut adapters = Vec::new();
        for t in 0..n_tasks {
            let target = t % vocab;
            let mut w = vec![0.0f32; seq * vocab];
            for kk in 0..seq {
                for j in 0..vocab {
                    // deterministic small noise in [-0.25, 0.25]
                    let h = ((kk * 31 + j * 17 + t * 7) % 101) as f32 / 100.0 - 0.5;
                    w[kk * vocab + j] = if j == target { 8.0 } else { h * 0.5 };
                }
            }
            let tensor = Tensor::from_f32(w, &[seq, vocab]).unwrap();
            adapters.push((t, vec![(WEIGHT_SLOT.to_string(), tensor)]));
        }
        let mut bytes = Vec::new();
        warm::write_artifact(&mut bytes, "panelhead", 7, codec, &adapters).unwrap();
        bytes
    }

    fn write_tmp(bytes: &[u8], name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("mcnc_qserve_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{name}_{}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    fn req(id: u64, task: usize, tokens: Vec<i32>) -> Request {
        Request { id, task, tokens, enqueued: Instant::now(), deadline: None }
    }

    fn batch_of(task: usize, reqs: Vec<Request>) -> Batch {
        Batch { task, requests: reqs }
    }

    #[test]
    fn preload_stores_quantized_panels_and_serves_expected_argmax() {
        let (n_tasks, seq, vocab) = (4usize, 8usize, 16usize);
        let bytes = fixture_artifact(n_tasks, seq, vocab, Codec::Int8 { block: vocab });
        let path = write_tmp(&bytes, "warm_int8");
        let mut cfg = QServeCfg::new("panelhead", n_tasks, seq, vocab);
        cfg.artifact = Some(path.clone());
        let mut eng = QuantEngine::new(cfg, 0).unwrap();
        let ws = eng.preload(&path).unwrap();
        assert_eq!(ws.installed, n_tasks);
        assert_eq!(ws.prefilled, n_tasks);
        assert_eq!(ws.quantized, n_tasks, "int8 frames must stay compressed");
        assert_eq!(eng.resident(), (n_tasks, n_tasks));
        for t in 0..n_tasks {
            let tokens: Vec<i32> = (0..seq).map(|j| (j % 5) as i32).collect();
            let preds = eng.run_batch(&batch_of(t, vec![req(1, t, tokens)])).unwrap();
            assert_eq!(preds, vec![(t % vocab) as i32], "task {t}");
        }
        assert_eq!(eng.stats.cache_hits, n_tasks as u64, "warm tasks never cold-fill");
        assert_eq!(eng.stats.cache_misses, 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cold_fill_quantized_vs_forced_f32_agree_on_argmax() {
        let (n_tasks, seq, vocab) = (3usize, 8usize, 12usize);
        let bytes = fixture_artifact(n_tasks, seq, vocab, Codec::Int8 { block: vocab });
        let path = write_tmp(&bytes, "cold_int8");
        let mk = |force_f32: bool| {
            let mut cfg = QServeCfg::new("panelhead", n_tasks, seq, vocab);
            cfg.artifact = Some(path.clone());
            cfg.force_f32 = force_f32;
            QuantEngine::new(cfg, 0).unwrap()
        };
        let mut q = mk(false);
        let mut f = mk(true);
        for t in 0..n_tasks {
            for r in 0..3u64 {
                let tokens: Vec<i32> =
                    (0..seq).map(|j| ((j as u64 + r * 3 + t as u64) % 4) as i32).collect();
                let b = batch_of(t, vec![req(r, t, tokens.clone())]);
                assert_eq!(
                    q.run_batch(&b).unwrap(),
                    f.run_batch(&batch_of(t, vec![req(r, t, tokens)])).unwrap(),
                    "task {t} req {r}"
                );
            }
        }
        assert_eq!(q.stats.cache_misses, n_tasks as u64, "one cold fill per task");
        assert_eq!(q.stats.native_fills, n_tasks as u64, "quantized fills are native");
        assert_eq!(q.resident(), (n_tasks, n_tasks));
        assert_eq!(f.stats.native_fills, 0, "forced-f32 fills are not native");
        assert_eq!(f.resident().1, 0, "forced-f32 engine holds no quantized panels");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn preload_rejects_bad_kind_shape_and_slot() {
        let (n_tasks, seq, vocab) = (2usize, 4usize, 8usize);
        let bytes = fixture_artifact(n_tasks, seq, vocab, Codec::Int8 { block: vocab });
        let path = write_tmp(&bytes, "rejects");
        // wrong kind
        let mut eng = QuantEngine::new(QServeCfg::new("otherkind", n_tasks, seq, vocab), 0).unwrap();
        let err = eng.preload(&path).unwrap_err();
        assert!(format!("{err:#}").contains("serves kind"), "{err:#}");
        // wrong geometry
        let mut eng =
            QuantEngine::new(QServeCfg::new("panelhead", n_tasks, seq + 1, vocab), 0).unwrap();
        let err = eng.preload(&path).unwrap_err();
        assert!(format!("{err:#}").contains("heads"), "{err:#}");
        assert_eq!(eng.resident(), (0, 0), "failed preload must not half-install");
        // wrong slot name
        let w = Tensor::from_f32(vec![0.5; seq * vocab], &[seq, vocab]).unwrap();
        let mut bytes = Vec::new();
        warm::write_artifact(
            &mut bytes,
            "panelhead",
            7,
            Codec::Lossless,
            &[(0, vec![("theta".to_string(), w)])],
        )
        .unwrap();
        let p2 = write_tmp(&bytes, "badslot");
        let mut eng = QuantEngine::new(QServeCfg::new("panelhead", 1, seq, vocab), 0).unwrap();
        let err = eng.preload(&p2).unwrap_err();
        assert!(format!("{err:#}").contains("single-slot"), "{err:#}");
        // cold fill with no artifact configured errors, never panics
        let mut eng = QuantEngine::new(QServeCfg::new("panelhead", 1, seq, vocab), 0).unwrap();
        let err = eng.run_batch(&batch_of(0, vec![req(0, 0, vec![0; seq])])).unwrap_err();
        assert!(format!("{err:#}").contains("no cold-fill artifact"), "{err:#}");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&p2);
    }

    #[test]
    fn sharded_preload_keeps_only_owned_tasks() {
        let (n_tasks, seq, vocab) = (4usize, 4usize, 8usize);
        let bytes = fixture_artifact(n_tasks, seq, vocab, Codec::Int4 { block: vocab });
        let path = write_tmp(&bytes, "sharded");
        let mut cfg = QServeCfg::new("panelhead", n_tasks, seq, vocab);
        cfg.n_shards = 2;
        let mut eng = QuantEngine::new(cfg, 1).unwrap();
        let ws = eng.preload(&path).unwrap();
        assert_eq!(ws.installed, 2, "shard 1 owns tasks 1 and 3");
        assert_eq!(ws.skipped, 2);
        assert_eq!(ws.quantized, 2, "int4 frames stay compressed too");
        assert!(eng.has_task(1) && eng.has_task(3));
        assert!(!eng.has_task(0) && !eng.has_task(2));
        let _ = std::fs::remove_file(&path);
    }
}
