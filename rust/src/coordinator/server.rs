//! The adapter-serving engine + server loop — the L3 systems contribution.
//!
//! Multi-task serving with per-task adapters stored compressed (the MCNC
//! (α, β) representation or baselines). Two execution modes mirror the
//! paper's Table-4 discussion:
//!
//! * **OnTheFly** — the predict executable reconstructs the adapter
//!   in-graph on every batch (MCNC's cheap generation makes this fast);
//! * **Merged** — full per-task weights are reconstructed once, cached in a
//!   byte-bounded LRU, and served through the dense predict executable
//!   (fast per batch, but memory scales with task count and cold tasks pay
//!   a large reconstruction + transfer cost).
//!
//! `PjRtClient` is not `Send`, so the whole engine lives on one dedicated
//! thread; submission/response travel over channels. XLA parallelizes
//! inside ops, so a single execution thread saturates the CPU.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::cache::LruCache;
use crate::coordinator::metrics::ServeStats;
use crate::coordinator::router::{Batch, BatchPolicy, Request, Router};
use crate::mcnc::{kernel, GenCfg, Generator};
use crate::runtime::init::init_inputs;
use crate::runtime::manifest::{Entry, Role};
use crate::runtime::Session;
use crate::tensor::Tensor;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    OnTheFly,
    Merged,
}

#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// Adapter family prefix, e.g. "lm_mcnclora8" / "lm_nola8" / "lm_lora8".
    pub kind: String,
    pub n_tasks: usize,
    pub policy: BatchPolicy,
    pub mode: Mode,
    /// Merged-mode cache capacity in bytes.
    pub cache_bytes: usize,
    pub seed: u64,
    /// Merged mode: fill cold tasks through the native blocked-GEMM
    /// reconstruction engine instead of dispatching the `{kind}_recon`
    /// PJRT executable. Skips a full session round-trip per cold task (and
    /// is the only Merged path when built without the `pjrt` feature's
    /// runtime). Off by default: native f32 summation order differs from
    /// XLA's by ulps, so the strict OnTheFly≡Merged argmax-equality
    /// guarantee only holds with the PJRT fill.
    pub native_recon: bool,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            kind: "lm_mcnclora8".into(),
            n_tasks: 8,
            policy: BatchPolicy::default(),
            mode: Mode::OnTheFly,
            cache_bytes: 64 << 20,
            seed: 1,
            native_recon: false,
        }
    }
}

/// Per-target LoRA piece inside the flattened compressed vector — twin of
/// `python/compile/methods.Registry.lora_dims`.
#[derive(Debug, Clone, Copy)]
struct LoraPiece {
    /// Leaf offset into θ_c.
    off: usize,
    a: usize,
    b: usize,
    /// Offsets into the flattened A / B factor vectors.
    ao: usize,
    bo: usize,
}

#[derive(Debug, Clone)]
struct LoraAssembly {
    rank: usize,
    scale: f32,
    /// Dl = Da + Db, the generator's target vector length.
    dl: usize,
    da: usize,
    /// Frozen A-random/B-zero base point (`lora0` static).
    lora0: Vec<f32>,
    pieces: Vec<LoraPiece>,
}

/// Native Merged-mode reconstruction: θ_c = θ0_c + Δ(α, β) computed with
/// the blocked-GEMM generator engine, mirroring the `mcnc` / `mcnc_lora`
/// reconstruct executables (`python/compile/methods.py`).
struct NativeRecon {
    gen: Generator,
    theta0: Vec<f32>,
    dc: usize,
    alpha_ix: usize,
    beta_ix: usize,
    /// `Some` for mcnc_lora kinds (factor assembly); `None` for plain mcnc.
    lora: Option<LoraAssembly>,
}

impl NativeRecon {
    /// Inspect the predict entry's metadata + statics; `None` when the
    /// adapter family has no native twin (e.g. plain LoRA / NOLA kinds).
    fn build(entry: &Entry, statics: &[Tensor]) -> Option<NativeRecon> {
        let cfg = GenCfg::from_json(entry.meta.get("gen")?).ok()?;
        let static_specs: Vec<_> =
            entry.inputs.iter().filter(|s| s.role == Role::Static).collect();
        let stat = |name: &str| {
            static_specs.iter().position(|s| s.name == name).map(|i| &statics[i])
        };
        let theta0 = stat("theta0_c")?.f32s().ok()?.to_vec();
        let ws = (0..cfg.depth)
            .map(|i| Some(stat(&format!("gw{i}"))?.f32s().ok()?.to_vec()))
            .collect::<Option<Vec<_>>>()?;
        let gen = Generator::with_weights(cfg, ws).ok()?;
        let tr_specs: Vec<_> =
            entry.inputs.iter().filter(|s| s.role == Role::Trainable).collect();
        let alpha_ix = tr_specs.iter().position(|s| s.name == "alpha")?;
        let beta_ix = tr_specs.iter().position(|s| s.name == "beta")?;
        let reg = entry.registry().ok()?;
        let dc = reg.dc;
        if theta0.len() != dc {
            return None;
        }
        let lora = if let Some(dl) = entry.meta.get("lora_dim").and_then(Json::as_usize) {
            let rank = entry.meta.get("rank").and_then(Json::as_usize)?;
            let scale = entry.meta.get("scale").and_then(Json::as_f64).unwrap_or(1.0) as f32;
            let lora0 = stat("lora0")?.f32s().ok()?.to_vec();
            let mut pieces = Vec::new();
            let (mut ao, mut bo, mut off) = (0usize, 0usize, 0usize);
            for leaf in reg.leaves.iter().filter(|l| l.compress) {
                if let Some((a, b)) = leaf.lora {
                    pieces.push(LoraPiece { off, a, b, ao, bo });
                    ao += a * rank;
                    bo += rank * b;
                }
                off += leaf.size();
            }
            if ao + bo != dl || off != dc || lora0.len() != dl {
                return None;
            }
            Some(LoraAssembly { rank, scale, dl, da: ao, lora0, pieces })
        } else if entry.meta.get("n_chunks").is_some() {
            None // plain mcnc: the generator output is the θ_c delta itself
        } else {
            return None;
        };
        Some(NativeRecon { gen, theta0, dc, alpha_ix, beta_ix, lora })
    }

    fn reconstruct(&self, adapter: &[Tensor]) -> Result<Tensor> {
        let alpha = adapter
            .get(self.alpha_ix)
            .ok_or_else(|| anyhow!("adapter missing alpha slot"))?
            .f32s()?;
        let beta = adapter
            .get(self.beta_ix)
            .ok_or_else(|| anyhow!("adapter missing beta slot"))?
            .f32s()?;
        // validate up front: install_adapter accepts arbitrary tensors, and
        // a short alpha/beta must surface as Err, not a generator panic
        let target = self.lora.as_ref().map(|l| l.dl).unwrap_or(self.dc);
        let need = target.div_ceil(self.gen.cfg.d.max(1));
        if alpha.len() < need * self.gen.cfg.k || beta.len() < need {
            bail!(
                "adapter alpha/beta ({}, {}) too small for {} chunks of k={}",
                alpha.len(),
                beta.len(),
                need,
                self.gen.cfg.k
            );
        }
        let mut theta = self.theta0.clone();
        match &self.lora {
            None => {
                let delta = self.gen.reconstruct_delta(alpha, beta, self.dc);
                if delta.len() != self.dc {
                    bail!("adapter generates {} of {} weights", delta.len(), self.dc);
                }
                for (t, d) in theta.iter_mut().zip(&delta) {
                    *t += d;
                }
            }
            Some(l) => {
                let mut lv = self.gen.reconstruct_delta(alpha, beta, l.dl);
                if lv.len() != l.dl {
                    bail!("adapter generates {} of {} LoRA values", lv.len(), l.dl);
                }
                for (v, z) in lv.iter_mut().zip(&l.lora0) {
                    *v += z;
                }
                let (a_flat, b_flat) = lv.split_at(l.da);
                for p in &l.pieces {
                    let fa = &a_flat[p.ao..p.ao + p.a * l.rank];
                    let fb = &b_flat[p.bo..p.bo + l.rank * p.b];
                    let pb = kernel::pack_b(fb, l.rank, p.b);
                    let mut dw = vec![0.0f32; p.a * p.b];
                    kernel::gemm(fa, p.a, &pb, &mut dw);
                    for (t, d) in theta[p.off..p.off + p.a * p.b].iter_mut().zip(&dw) {
                        *t += d * l.scale;
                    }
                }
            }
        }
        Tensor::from_f32(theta, &[self.dc])
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub task: usize,
    /// Next-token prediction for the sequence's last position (proof the
    /// batch really ran through the model).
    pub next_token: i32,
    pub latency: Duration,
    pub batch_rows: usize,
}

/// The engine: everything that touches PJRT. Single-threaded by design.
pub struct Engine {
    session: Session,
    cfg: ServerCfg,
    predict: String,
    statics: Vec<Tensor>,
    /// Per-task compressed adapter state (trainables, manifest order).
    adapters: HashMap<usize, Vec<Tensor>>,
    /// Merged mode: reconstructed full θ per task.
    merged_cache: LruCache<usize, Vec<Tensor>>,
    dense_statics: Vec<Tensor>,
    /// Native GEMM reconstruction twin for Merged cold fills, when the
    /// adapter family supports it (mcnc / mcnc_lora kinds).
    native: Option<NativeRecon>,
    batch_size: usize,
    seq: usize,
    pub stats: ServeStats,
    recon_flops_per_pass: u64,
}

impl Engine {
    pub fn new(session: Session, cfg: ServerCfg) -> Result<Engine> {
        let predict = format!("{}_predict", cfg.kind);
        let entry = session.entry(&predict)?.clone();
        let x_spec = entry.inputs.last().unwrap();
        let (batch_size, seq) = (x_spec.shape[0], x_spec.shape[1]);

        // shared statics (θ0, generator weights / bases) from the base seed
        let slots = init_inputs(&entry, cfg.seed)?;
        let statics: Vec<Tensor> = slots
            .iter()
            .filter(|(s, _)| s.role == Role::Static)
            .map(|(_, t)| t.clone().unwrap())
            .collect();

        // per-task adapters: synthesized from task-specific seeds (replaced
        // by fine-tuned checkpoints via `install_adapter`)
        let mut adapters = HashMap::new();
        for task in 0..cfg.n_tasks {
            let tslots = init_inputs(&entry, cfg.seed ^ (0xAD00 + task as u64))?;
            let mut tr: Vec<Tensor> = tslots
                .into_iter()
                .filter(|(s, _)| s.role == Role::Trainable)
                .map(|(_, t)| t.unwrap())
                .collect();
            // perturb α/coef so adapters differ and reconstruction is
            // non-trivial (zero-init adapters would all produce θ0)
            if let Some(first) = tr.first_mut() {
                let mut s = crate::util::prng::Stream::new(cfg.seed ^ (0x5EED + task as u64));
                let dims = first.dims.clone();
                let n = first.numel();
                *first = Tensor::from_f32(s.normal_f32(n, 0.05), &dims)?;
            }
            adapters.insert(task, tr);
        }

        let recon_flops_per_pass = entry.recon_flops() as u64;
        // only pay the θ0/weight-copy + panel packing when the native fill
        // path can actually be taken
        let native = if cfg.mode == Mode::Merged && cfg.native_recon {
            NativeRecon::build(&entry, &statics)
        } else {
            None
        };

        // merged-mode plumbing: the dense predict path is always required;
        // the PJRT recon executable only when native fills can't cover it
        let mut dense_statics = Vec::new();
        if cfg.mode == Mode::Merged {
            let dense = session.entry("lm_dense_predict")?.clone();
            let dslots = init_inputs(&dense, cfg.seed)?;
            dense_statics = dslots
                .iter()
                .filter(|(s, _)| s.role == Role::Static)
                .map(|(_, t)| t.clone().unwrap())
                .collect();
            if !(cfg.native_recon && native.is_some()) {
                session.entry(&format!("{}_recon", cfg.kind))?; // must exist
            }
        }

        Ok(Engine {
            session,
            predict,
            statics,
            adapters,
            merged_cache: LruCache::new(cfg.cache_bytes),
            dense_statics,
            native,
            batch_size,
            seq,
            stats: ServeStats::default(),
            recon_flops_per_pass,
            cfg,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Install fine-tuned adapter weights for a task.
    pub fn install_adapter(&mut self, task: usize, trainables: Vec<Tensor>) {
        self.adapters.insert(task, trainables);
    }

    fn build_x(&self, batch: &Batch) -> Result<(Tensor, usize)> {
        let b = self.batch_size;
        let t = self.seq;
        let mut x = vec![0i32; b * t];
        for (i, req) in batch.requests.iter().enumerate() {
            if req.tokens.len() != t {
                bail!("request {} has {} tokens, executable wants {t}", req.id, req.tokens.len());
            }
            x[i * t..(i + 1) * t].copy_from_slice(&req.tokens);
        }
        // pad by repeating the first row
        let padded = b - batch.requests.len();
        for i in batch.requests.len()..b {
            let src: Vec<i32> = x[..t].to_vec();
            x[i * t..(i + 1) * t].copy_from_slice(&src);
        }
        Ok((Tensor::from_i32(x, &[b, t])?, padded))
    }

    /// Run one batch; returns per-request next-token predictions.
    pub fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        let (x, padded) = self.build_x(batch)?;
        let adapter = self
            .adapters
            .get(&batch.task)
            .ok_or_else(|| anyhow!("unknown task {}", batch.task))?
            .clone();

        let logits = match self.cfg.mode {
            Mode::OnTheFly => {
                let mut inputs = self.statics.clone();
                inputs.extend(adapter);
                inputs.push(x);
                self.stats.recon_flops += self.recon_flops_per_pass;
                self.session.run(&self.predict, &inputs)?.remove(0)
            }
            Mode::Merged => {
                if self.merged_cache.get(&batch.task).is_none() {
                    // cold task: reconstruct full weights — natively via
                    // the blocked-GEMM engine when built (Engine::new gates
                    // that on cfg.native_recon), else through the PJRT recon
                    let theta = if let Some(nr) = &self.native {
                        self.stats.native_fills += 1;
                        nr.reconstruct(&adapter)?
                    } else {
                        let recon = format!("{}_recon", self.cfg.kind);
                        let mut rin = self.statics.clone();
                        rin.extend(adapter.clone());
                        self.session.run(&recon, &rin)?.remove(0)
                    };
                    self.stats.recon_flops += self.recon_flops_per_pass;
                    self.stats.cache_misses += 1;
                    // dense trainables = [theta_c, raw]; raw comes from the
                    // adapter state (last trainable by convention)
                    let raw = adapter.last().unwrap().clone();
                    self.merged_cache.put(batch.task, vec![theta, raw]);
                } else {
                    self.stats.cache_hits += 1;
                }
                let dense_tr = self.merged_cache.get(&batch.task).unwrap().clone();
                let mut inputs = self.dense_statics.clone();
                inputs.extend(dense_tr);
                inputs.push(x);
                self.session.run("lm_dense_predict", &inputs)?.remove(0)
            }
        };

        // logits [b, t, v] → next-token argmax at the last position per row
        let v = *logits.dims.last().unwrap();
        let lf = logits.f32s()?;
        let row = self.seq * v;
        let preds = (0..batch.requests.len())
            .map(|i| {
                let base = i * row + (self.seq - 1) * v;
                let mut best = (f32::MIN, 0i32);
                for c in 0..v {
                    if lf[base + c] > best.0 {
                        best = (lf[base + c], c as i32);
                    }
                }
                best.1
            })
            .collect();

        self.stats.batches += 1;
        self.stats.rows += self.batch_size as u64;
        self.stats.padded_rows += padded as u64;
        Ok(preds)
    }
}

enum Msg {
    Req(Request, mpsc::Sender<Response>),
    Stop,
}

/// Handle to a running server (engine thread owns the Session).
pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: Option<thread::JoinHandle<Result<ServeStats>>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Spawn the engine thread. The Session is created inside the thread
    /// (PjRtClient is not Send).
    pub fn start(artifacts: std::path::PathBuf, cfg: ServerCfg) -> Server {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = thread::Builder::new()
            .name("mcnc-engine".into())
            .spawn(move || -> Result<ServeStats> {
                let session = Session::open(&artifacts).context("opening session")?;
                let mut engine = Engine::new(session, cfg.clone())?;
                // warm the compile cache off the latency path
                engine.session.load(&engine.predict)?;
                let mut router = Router::default();
                let mut pending: HashMap<u64, mpsc::Sender<Response>> = HashMap::new();
                let started = Instant::now();
                let mut stopping = false;
                loop {
                    // 1) ingest
                    match rx.recv_timeout(Duration::from_micros(200)) {
                        Ok(Msg::Req(r, reply)) => {
                            pending.insert(r.id, reply);
                            router.push(r);
                        }
                        Ok(Msg::Stop) => stopping = true,
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => stopping = true,
                    }
                    // 2) dispatch ready batches
                    let now = Instant::now();
                    while let Some(batch) = router.next_batch(cfg.policy, now, stopping) {
                        let preds = engine.run_batch(&batch)?;
                        let rows = batch.requests.len();
                        let done = Instant::now();
                        for (req, tok) in batch.requests.iter().zip(preds) {
                            engine.stats.latency.record(done.duration_since(req.enqueued));
                            if let Some(reply) = pending.remove(&req.id) {
                                let _ = reply.send(Response {
                                    id: req.id,
                                    task: req.task,
                                    next_token: tok,
                                    latency: done.duration_since(req.enqueued),
                                    batch_rows: rows,
                                });
                            }
                        }
                    }
                    if stopping && router.is_empty() {
                        break;
                    }
                }
                engine.stats.wall_secs = started.elapsed().as_secs_f64();
                Ok(engine.stats)
            })
            .expect("spawn engine");
        Server { tx, handle: Some(handle), next_id: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Submit a request; the returned channel yields the response.
    pub fn submit(&self, task: usize, tokens: Vec<i32>) -> mpsc::Receiver<Response> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        let req = Request { id, task, tokens, enqueued: Instant::now() };
        let _ = self.tx.send(Msg::Req(req, rtx));
        rrx
    }

    /// Stop after draining; returns the engine's serving stats.
    pub fn stop(mut self) -> Result<ServeStats> {
        let _ = self.tx.send(Msg::Stop);
        self.handle
            .take()
            .unwrap()
            .join()
            .map_err(|_| anyhow!("engine thread panicked"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
