//! The adapter-serving engine + server loop — the L3 systems contribution.
//!
//! Multi-task serving with per-task adapters stored compressed (the MCNC
//! (α, β) representation or baselines). Two execution modes mirror the
//! paper's Table-4 discussion:
//!
//! * **OnTheFly** — the predict executable reconstructs the adapter
//!   in-graph on every batch (MCNC's cheap generation makes this fast);
//! * **Merged** — full per-task weights are reconstructed once, cached in a
//!   byte-bounded LRU, and served through the dense predict executable
//!   (fast per batch, but memory scales with task count and cold tasks pay
//!   a large reconstruction + transfer cost).
//!
//! `PjRtClient` is not `Send`, so the whole engine lives on one dedicated
//! thread; submission/response travel over channels. XLA parallelizes
//! inside ops, so a single execution thread saturates the CPU.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::cache::LruCache;
use crate::coordinator::metrics::ServeStats;
use crate::coordinator::router::{Batch, BatchPolicy, Request, Router};
use crate::runtime::init::init_inputs;
use crate::runtime::manifest::Role;
use crate::runtime::Session;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    OnTheFly,
    Merged,
}

#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// Adapter family prefix, e.g. "lm_mcnclora8" / "lm_nola8" / "lm_lora8".
    pub kind: String,
    pub n_tasks: usize,
    pub policy: BatchPolicy,
    pub mode: Mode,
    /// Merged-mode cache capacity in bytes.
    pub cache_bytes: usize,
    pub seed: u64,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            kind: "lm_mcnclora8".into(),
            n_tasks: 8,
            policy: BatchPolicy::default(),
            mode: Mode::OnTheFly,
            cache_bytes: 64 << 20,
            seed: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub task: usize,
    /// Next-token prediction for the sequence's last position (proof the
    /// batch really ran through the model).
    pub next_token: i32,
    pub latency: Duration,
    pub batch_rows: usize,
}

/// The engine: everything that touches PJRT. Single-threaded by design.
pub struct Engine {
    session: Session,
    cfg: ServerCfg,
    predict: String,
    statics: Vec<Tensor>,
    /// Per-task compressed adapter state (trainables, manifest order).
    adapters: HashMap<usize, Vec<Tensor>>,
    /// Merged mode: reconstructed full θ per task.
    merged_cache: LruCache<usize, Vec<Tensor>>,
    dense_statics: Vec<Tensor>,
    batch_size: usize,
    seq: usize,
    pub stats: ServeStats,
    recon_flops_per_pass: u64,
}

impl Engine {
    pub fn new(session: Session, cfg: ServerCfg) -> Result<Engine> {
        let predict = format!("{}_predict", cfg.kind);
        let entry = session.entry(&predict)?.clone();
        let x_spec = entry.inputs.last().unwrap();
        let (batch_size, seq) = (x_spec.shape[0], x_spec.shape[1]);

        // shared statics (θ0, generator weights / bases) from the base seed
        let slots = init_inputs(&entry, cfg.seed)?;
        let statics: Vec<Tensor> = slots
            .iter()
            .filter(|(s, _)| s.role == Role::Static)
            .map(|(_, t)| t.clone().unwrap())
            .collect();

        // per-task adapters: synthesized from task-specific seeds (replaced
        // by fine-tuned checkpoints via `install_adapter`)
        let mut adapters = HashMap::new();
        for task in 0..cfg.n_tasks {
            let tslots = init_inputs(&entry, cfg.seed ^ (0xAD00 + task as u64))?;
            let mut tr: Vec<Tensor> = tslots
                .into_iter()
                .filter(|(s, _)| s.role == Role::Trainable)
                .map(|(_, t)| t.unwrap())
                .collect();
            // perturb α/coef so adapters differ and reconstruction is
            // non-trivial (zero-init adapters would all produce θ0)
            if let Some(first) = tr.first_mut() {
                let mut s = crate::util::prng::Stream::new(cfg.seed ^ (0x5EED + task as u64));
                let dims = first.dims.clone();
                let n = first.numel();
                *first = Tensor::from_f32(s.normal_f32(n, 0.05), &dims)?;
            }
            adapters.insert(task, tr);
        }

        let recon_flops_per_pass = entry.recon_flops() as u64;

        // merged-mode plumbing (requires the dense predict + recon paths)
        let mut dense_statics = Vec::new();
        if cfg.mode == Mode::Merged {
            let dense = session.entry("lm_dense_predict")?.clone();
            let dslots = init_inputs(&dense, cfg.seed)?;
            dense_statics = dslots
                .iter()
                .filter(|(s, _)| s.role == Role::Static)
                .map(|(_, t)| t.clone().unwrap())
                .collect();
            session.entry(&format!("{}_recon", cfg.kind))?; // must exist
        }

        Ok(Engine {
            session,
            predict,
            statics,
            adapters,
            merged_cache: LruCache::new(cfg.cache_bytes),
            dense_statics,
            batch_size,
            seq,
            stats: ServeStats::default(),
            recon_flops_per_pass,
            cfg,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Install fine-tuned adapter weights for a task.
    pub fn install_adapter(&mut self, task: usize, trainables: Vec<Tensor>) {
        self.adapters.insert(task, trainables);
    }

    fn build_x(&self, batch: &Batch) -> Result<(Tensor, usize)> {
        let b = self.batch_size;
        let t = self.seq;
        let mut x = vec![0i32; b * t];
        for (i, req) in batch.requests.iter().enumerate() {
            if req.tokens.len() != t {
                bail!("request {} has {} tokens, executable wants {t}", req.id, req.tokens.len());
            }
            x[i * t..(i + 1) * t].copy_from_slice(&req.tokens);
        }
        // pad by repeating the first row
        let padded = b - batch.requests.len();
        for i in batch.requests.len()..b {
            let src: Vec<i32> = x[..t].to_vec();
            x[i * t..(i + 1) * t].copy_from_slice(&src);
        }
        Ok((Tensor::from_i32(x, &[b, t])?, padded))
    }

    /// Run one batch; returns per-request next-token predictions.
    pub fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        let (x, padded) = self.build_x(batch)?;
        let adapter = self
            .adapters
            .get(&batch.task)
            .ok_or_else(|| anyhow!("unknown task {}", batch.task))?
            .clone();

        let logits = match self.cfg.mode {
            Mode::OnTheFly => {
                let mut inputs = self.statics.clone();
                inputs.extend(adapter);
                inputs.push(x);
                self.stats.recon_flops += self.recon_flops_per_pass;
                self.session.run(&self.predict, &inputs)?.remove(0)
            }
            Mode::Merged => {
                if self.merged_cache.get(&batch.task).is_none() {
                    // cold task: reconstruct full weights through PJRT
                    let recon = format!("{}_recon", self.cfg.kind);
                    let mut rin = self.statics.clone();
                    rin.extend(adapter.clone());
                    let theta = self.session.run(&recon, &rin)?.remove(0);
                    self.stats.recon_flops += self.recon_flops_per_pass;
                    self.stats.cache_misses += 1;
                    // dense trainables = [theta_c, raw]; raw comes from the
                    // adapter state (last trainable by convention)
                    let raw = adapter.last().unwrap().clone();
                    self.merged_cache.put(batch.task, vec![theta, raw]);
                } else {
                    self.stats.cache_hits += 1;
                }
                let dense_tr = self.merged_cache.get(&batch.task).unwrap().clone();
                let mut inputs = self.dense_statics.clone();
                inputs.extend(dense_tr);
                inputs.push(x);
                self.session.run("lm_dense_predict", &inputs)?.remove(0)
            }
        };

        // logits [b, t, v] → next-token argmax at the last position per row
        let v = *logits.dims.last().unwrap();
        let lf = logits.f32s()?;
        let row = self.seq * v;
        let preds = (0..batch.requests.len())
            .map(|i| {
                let base = i * row + (self.seq - 1) * v;
                let mut best = (f32::MIN, 0i32);
                for c in 0..v {
                    if lf[base + c] > best.0 {
                        best = (lf[base + c], c as i32);
                    }
                }
                best.1
            })
            .collect();

        self.stats.batches += 1;
        self.stats.rows += self.batch_size as u64;
        self.stats.padded_rows += padded as u64;
        Ok(preds)
    }
}

enum Msg {
    Req(Request, mpsc::Sender<Response>),
    Stop,
}

/// Handle to a running server (engine thread owns the Session).
pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: Option<thread::JoinHandle<Result<ServeStats>>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Spawn the engine thread. The Session is created inside the thread
    /// (PjRtClient is not Send).
    pub fn start(artifacts: std::path::PathBuf, cfg: ServerCfg) -> Server {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = thread::Builder::new()
            .name("mcnc-engine".into())
            .spawn(move || -> Result<ServeStats> {
                let session = Session::open(&artifacts).context("opening session")?;
                let mut engine = Engine::new(session, cfg.clone())?;
                // warm the compile cache off the latency path
                engine.session.load(&engine.predict)?;
                let mut router = Router::default();
                let mut pending: HashMap<u64, mpsc::Sender<Response>> = HashMap::new();
                let started = Instant::now();
                let mut stopping = false;
                loop {
                    // 1) ingest
                    match rx.recv_timeout(Duration::from_micros(200)) {
                        Ok(Msg::Req(r, reply)) => {
                            pending.insert(r.id, reply);
                            router.push(r);
                        }
                        Ok(Msg::Stop) => stopping = true,
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => stopping = true,
                    }
                    // 2) dispatch ready batches
                    let now = Instant::now();
                    while let Some(batch) = router.next_batch(cfg.policy, now, stopping) {
                        let preds = engine.run_batch(&batch)?;
                        let rows = batch.requests.len();
                        let done = Instant::now();
                        for (req, tok) in batch.requests.iter().zip(preds) {
                            engine.stats.latency.record(done.duration_since(req.enqueued));
                            if let Some(reply) = pending.remove(&req.id) {
                                let _ = reply.send(Response {
                                    id: req.id,
                                    task: req.task,
                                    next_token: tok,
                                    latency: done.duration_since(req.enqueued),
                                    batch_rows: rows,
                                });
                            }
                        }
                    }
                    if stopping && router.is_empty() {
                        break;
                    }
                }
                engine.stats.wall_secs = started.elapsed().as_secs_f64();
                Ok(engine.stats)
            })
            .expect("spawn engine");
        Server { tx, handle: Some(handle), next_id: std::sync::atomic::AtomicU64::new(0) }
    }

    /// Submit a request; the returned channel yields the response.
    pub fn submit(&self, task: usize, tokens: Vec<i32>) -> mpsc::Receiver<Response> {
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        let req = Request { id, task, tokens, enqueued: Instant::now() };
        let _ = self.tx.send(Msg::Req(req, rtx));
        rrx
    }

    /// Stop after draining; returns the engine's serving stats.
    pub fn stop(mut self) -> Result<ServeStats> {
        let _ = self.tx.send(Msg::Stop);
        self.handle
            .take()
            .unwrap()
            .join()
            .map_err(|_| anyhow!("engine thread panicked"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
