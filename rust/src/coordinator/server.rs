//! The adapter-serving engine + sharded coordinator — the L3 systems
//! contribution.
//!
//! Multi-task serving with per-task adapters stored compressed (the MCNC
//! (α, β) representation or baselines). Two execution modes mirror the
//! paper's Table-4 discussion:
//!
//! * **OnTheFly** — the predict executable reconstructs the adapter
//!   in-graph on every batch (MCNC's cheap generation makes this fast);
//! * **Merged** — full per-task weights are reconstructed once, cached in a
//!   byte-bounded LRU, and served through the dense predict executable
//!   (fast per batch, but memory scales with task count and cold tasks pay
//!   a large reconstruction + transfer cost).
//!
//! Execution is horizontally sharded: the front-end `Server` dispatches
//! each request to one of `n_shards` engine worker threads by task
//! affinity (`task % n_shards`), so requests for a task always hit the
//! same Session, adapter slice and merged LRU (see `shard.rs`).
//! `PjRtClient` is not `Send`, so each shard constructs its Session on its
//! own thread; admission is a bounded channel per shard and overload is
//! answered immediately with a rejected `Response` instead of queueing
//! without bound. Per-request faults (malformed tokens, unknown task,
//! batch execution errors) are answered with error Responses — a bad
//! request never kills a shard or strands its neighbours.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::codec;
use crate::coordinator::cache::LruCache;
use crate::coordinator::metrics::ServeStats;
use crate::coordinator::router::{Batch, BatchPolicy, Request};
use crate::coordinator::shard::{error_response, EngineCore, Msg, Shard, WarmSlot};
use crate::coordinator::warm::{self, WarmStats};
use crate::obs;
use crate::util::prng::{tag, Stream};
use crate::mcnc::{kernel, GenCfg, Generator};
use crate::runtime::init::init_inputs;
use crate::runtime::manifest::{Entry, IoSpec, Role};
use crate::runtime::Session;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// How a shard's engine turns a compressed adapter into predictions (the
/// paper's Table-4 trade-off; see the module header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Reconstruct the adapter in-graph on every batch.
    OnTheFly,
    /// Reconstruct full per-task weights once, cache them in a byte-bounded
    /// LRU, and serve through the dense predict executable.
    Merged,
}

/// Configuration of a sharded serving [`Server`].
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// Adapter family prefix, e.g. "lm_mcnclora8" / "lm_nola8" / "lm_lora8".
    pub kind: String,
    /// Number of tasks served (task ids `0..n_tasks`).
    pub n_tasks: usize,
    /// Engine worker threads; task t is owned by shard `t % n_shards`.
    pub n_shards: usize,
    /// Dynamic batching policy each shard's router applies.
    pub policy: BatchPolicy,
    /// Adapter execution mode (see [`Mode`]).
    pub mode: Mode,
    /// Merged-mode cache capacity in bytes, split evenly across shards.
    pub cache_bytes: usize,
    /// Base seed: statics derive from it directly, task adapters from
    /// task-specific mixes of it (see `synth_adapter`).
    pub seed: u64,
    /// Merged mode: fill cold tasks through the native blocked-GEMM
    /// reconstruction engine instead of dispatching the `{kind}_recon`
    /// PJRT executable. Skips a full session round-trip per cold task (and
    /// is the only Merged path when built without the `pjrt` feature's
    /// runtime). Off by default: native f32 summation order differs from
    /// XLA's by ulps, so the strict OnTheFly≡Merged argmax-equality
    /// guarantee only holds with the PJRT fill.
    pub native_recon: bool,
    /// Bounded per-shard admission queue; a full queue rejects instead of
    /// buffering without bound (explicit backpressure).
    pub queue_cap: usize,
    /// Idle wake-up period of each shard loop. Shards otherwise sleep
    /// until the router's next flush deadline or a new message.
    pub heartbeat: Duration,
    /// Default per-request deadline applied by `submit`; a request whose
    /// deadline passes before batch formation is shed with
    /// [`ServeError::DeadlineExceeded`] instead of executed. `None` = no
    /// deadline. Per-request overrides via [`Server::submit_with`].
    pub deadline: Option<Duration>,
    /// Supervisor policy for restarting a dead shard engine.
    pub restart: RestartPolicy,
    /// Dispatcher retry policy on admission backpressure (`Rejected`).
    pub retry: RetryPolicy,
    /// Per-shard circuit breaker policy (`threshold` 0 disables).
    pub breaker: BreakerCfg,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            kind: "lm_mcnclora8".into(),
            n_tasks: 8,
            n_shards: 1,
            policy: BatchPolicy::default(),
            mode: Mode::OnTheFly,
            cache_bytes: 64 << 20,
            seed: 1,
            native_recon: false,
            queue_cap: 1024,
            heartbeat: Duration::from_millis(50),
            deadline: None,
            restart: RestartPolicy::default(),
            retry: RetryPolicy::default(),
            breaker: BreakerCfg::default(),
        }
    }
}

/// How the shard supervisor restarts a dead engine (factory error or a
/// panic escaping the serving loop). The budget counts *consecutive
/// unproductive incarnations*: an incarnation that serves at least one
/// batch resets it, so isolated crashes over a long uptime never add up
/// to permanent death.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RestartPolicy {
    /// Consecutive unproductive restarts before the shard is declared
    /// permanently dead (queued requests are then answered with errors
    /// until `Stop`). 0 = die on the first crash.
    pub max_restarts: u32,
    /// Sleep before the first restart; doubles per consecutive failure.
    pub backoff: Duration,
    /// Upper bound on the doubling backoff.
    pub max_backoff: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            max_restarts: 3,
            backoff: Duration::from_millis(25),
            max_backoff: Duration::from_secs(1),
        }
    }
}

/// Bounded dispatcher-side retry on admission backpressure. With
/// `attempts` 0 (the default) `Rejected` surfaces immediately — existing
/// explicit-backpressure behaviour is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-attempts after the first full-queue bounce.
    pub attempts: u32,
    /// Base sleep before a re-attempt; doubles per attempt, plus a small
    /// deterministic per-request jitter (seeded from the server seed and
    /// the request id) so colliding submitters desynchronize reproducibly.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { attempts: 0, backoff: Duration::from_millis(1) }
    }
}

/// Per-shard circuit breaker policy: after `threshold` consecutive batch
/// failures the breaker opens and the dispatcher fast-fails new requests
/// for that shard (`Rejected`, "circuit open") instead of queueing them
/// into a black hole; after `cooldown` one probe request is let through
/// (half-open) and its outcome closes or re-opens the breaker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerCfg {
    /// Consecutive batch failures that open the breaker; 0 disables it.
    pub threshold: u32,
    /// How long the breaker stays open before admitting a probe.
    pub cooldown: Duration,
}

impl Default for BreakerCfg {
    fn default() -> Self {
        BreakerCfg { threshold: 0, cooldown: Duration::from_millis(250) }
    }
}

const BREAKER_CLOSED: u8 = 0;
const BREAKER_OPEN: u8 = 1;
const BREAKER_HALF_OPEN: u8 = 2;

/// Circuit-breaker state machine shared between one shard's loop (which
/// records batch outcomes) and the dispatcher (which asks `allow` before
/// admitting a request). Lock-free on the hot paths; the open timestamp
/// takes a mutex only on the cold open/probe transitions.
pub(crate) struct Breaker {
    cfg: BreakerCfg,
    state: AtomicU8,
    fails: AtomicU32,
    opened_at: Mutex<Option<Instant>>,
}

impl Breaker {
    pub fn new(cfg: BreakerCfg) -> Breaker {
        Breaker {
            cfg,
            state: AtomicU8::new(BREAKER_CLOSED),
            fails: AtomicU32::new(0),
            opened_at: Mutex::new(None),
        }
    }

    /// Whether the dispatcher may admit a request for this shard. An open
    /// breaker past its cooldown admits exactly one probe (half-open);
    /// the probe's batch outcome then closes or re-opens the breaker.
    pub fn allow(&self) -> bool {
        if self.cfg.threshold == 0 {
            return true;
        }
        match self.state.load(Ordering::Acquire) {
            BREAKER_CLOSED => true,
            BREAKER_HALF_OPEN => false, // a probe is already in flight
            _ => {
                let cooled = match self.opened_at.lock() {
                    Ok(g) => g.map(|t| t.elapsed() >= self.cfg.cooldown).unwrap_or(true),
                    Err(_) => true,
                };
                cooled
                    && self
                        .state
                        .compare_exchange(
                            BREAKER_OPEN,
                            BREAKER_HALF_OPEN,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
            }
        }
    }

    /// A batch for this shard completed: close the breaker.
    pub fn record_success(&self) {
        if self.cfg.threshold == 0 {
            return;
        }
        self.fails.store(0, Ordering::Release);
        self.state.store(BREAKER_CLOSED, Ordering::Release);
    }

    /// A batch for this shard failed. Returns `true` when this failure
    /// opened (or re-opened, for a failed half-open probe) the breaker.
    pub fn record_failure(&self) -> bool {
        if self.cfg.threshold == 0 {
            return false;
        }
        let prior = self.state.load(Ordering::Acquire);
        let fails = self.fails.fetch_add(1, Ordering::AcqRel) + 1;
        if prior == BREAKER_OPEN {
            return false; // already open (stale queued batch failing late)
        }
        if prior == BREAKER_HALF_OPEN || fails >= self.cfg.threshold {
            if let Ok(mut g) = self.opened_at.lock() {
                *g = Some(Instant::now());
            }
            self.state.store(BREAKER_OPEN, Ordering::Release);
            return true;
        }
        false
    }
}

/// Per-target LoRA piece inside the flattened compressed vector — twin of
/// `python/compile/methods.Registry.lora_dims`.
#[derive(Debug, Clone, Copy)]
struct LoraPiece {
    /// Leaf offset into θ_c.
    off: usize,
    a: usize,
    b: usize,
    /// Offsets into the flattened A / B factor vectors.
    ao: usize,
    bo: usize,
}

#[derive(Debug, Clone)]
struct LoraAssembly {
    rank: usize,
    scale: f32,
    /// Dl = Da + Db, the generator's target vector length.
    dl: usize,
    da: usize,
    /// Frozen A-random/B-zero base point (`lora0` static).
    lora0: Vec<f32>,
    pieces: Vec<LoraPiece>,
}

/// Native Merged-mode reconstruction: θ_c = θ0_c + Δ(α, β) computed with
/// the blocked-GEMM generator engine, mirroring the `mcnc` / `mcnc_lora`
/// reconstruct executables (`python/compile/methods.py`).
struct NativeRecon {
    gen: Generator,
    theta0: Vec<f32>,
    dc: usize,
    alpha_ix: usize,
    beta_ix: usize,
    /// `Some` for mcnc_lora kinds (factor assembly); `None` for plain mcnc.
    lora: Option<LoraAssembly>,
}

impl NativeRecon {
    /// Inspect the predict entry's metadata + statics; `None` when the
    /// adapter family has no native twin (e.g. plain LoRA / NOLA kinds).
    fn build(entry: &Entry, statics: &[Tensor]) -> Option<NativeRecon> {
        let cfg = GenCfg::from_json(entry.meta.get("gen")?).ok()?;
        let static_specs: Vec<_> =
            entry.inputs.iter().filter(|s| s.role == Role::Static).collect();
        let stat = |name: &str| {
            static_specs.iter().position(|s| s.name == name).map(|i| &statics[i])
        };
        let theta0 = stat("theta0_c")?.f32s().ok()?.to_vec();
        let ws = (0..cfg.depth)
            .map(|i| Some(stat(&format!("gw{i}"))?.f32s().ok()?.to_vec()))
            .collect::<Option<Vec<_>>>()?;
        let gen = Generator::with_weights(cfg, ws).ok()?;
        let tr_specs: Vec<_> =
            entry.inputs.iter().filter(|s| s.role == Role::Trainable).collect();
        let alpha_ix = tr_specs.iter().position(|s| s.name == "alpha")?;
        let beta_ix = tr_specs.iter().position(|s| s.name == "beta")?;
        let reg = entry.registry().ok()?;
        let dc = reg.dc;
        if theta0.len() != dc {
            return None;
        }
        let lora = if let Some(dl) = entry.meta.get("lora_dim").and_then(Json::as_usize) {
            let rank = entry.meta.get("rank").and_then(Json::as_usize)?;
            let scale = entry.meta.get("scale").and_then(Json::as_f64).unwrap_or(1.0) as f32;
            let lora0 = stat("lora0")?.f32s().ok()?.to_vec();
            let mut pieces = Vec::new();
            let (mut ao, mut bo, mut off) = (0usize, 0usize, 0usize);
            for leaf in reg.leaves.iter().filter(|l| l.compress) {
                if let Some((a, b)) = leaf.lora {
                    pieces.push(LoraPiece { off, a, b, ao, bo });
                    ao += a * rank;
                    bo += rank * b;
                }
                off += leaf.size();
            }
            if ao + bo != dl || off != dc || lora0.len() != dl {
                return None;
            }
            Some(LoraAssembly { rank, scale, dl, da: ao, lora0, pieces })
        } else if entry.meta.get("n_chunks").is_some() {
            None // plain mcnc: the generator output is the θ_c delta itself
        } else {
            return None;
        };
        Some(NativeRecon { gen, theta0, dc, alpha_ix, beta_ix, lora })
    }

    fn reconstruct(&self, adapter: &[Tensor]) -> Result<Tensor> {
        let alpha = adapter
            .get(self.alpha_ix)
            .ok_or_else(|| anyhow!("adapter missing alpha slot"))?
            .f32s()?;
        let beta = adapter
            .get(self.beta_ix)
            .ok_or_else(|| anyhow!("adapter missing beta slot"))?
            .f32s()?;
        // validate up front: install_adapter accepts arbitrary tensors, and
        // a short alpha/beta must surface as Err, not a generator panic
        let target = self.lora.as_ref().map(|l| l.dl).unwrap_or(self.dc);
        let need = target.div_ceil(self.gen.cfg.d.max(1));
        if alpha.len() < need * self.gen.cfg.k || beta.len() < need {
            bail!(
                "adapter alpha/beta ({}, {}) too small for {} chunks of k={}",
                alpha.len(),
                beta.len(),
                need,
                self.gen.cfg.k
            );
        }
        let mut theta = self.theta0.clone();
        match &self.lora {
            None => {
                let delta = self.gen.reconstruct_delta(alpha, beta, self.dc);
                if delta.len() != self.dc {
                    bail!("adapter generates {} of {} weights", delta.len(), self.dc);
                }
                for (t, d) in theta.iter_mut().zip(&delta) {
                    *t += d;
                }
            }
            Some(l) => {
                let mut lv = self.gen.reconstruct_delta(alpha, beta, l.dl);
                if lv.len() != l.dl {
                    bail!("adapter generates {} of {} LoRA values", lv.len(), l.dl);
                }
                for (v, z) in lv.iter_mut().zip(&l.lora0) {
                    *v += z;
                }
                let (a_flat, b_flat) = lv.split_at(l.da);
                for p in &l.pieces {
                    let fa = &a_flat[p.ao..p.ao + p.a * l.rank];
                    let fb = &b_flat[p.bo..p.bo + l.rank * p.b];
                    // ΔW = A·B on the ISA-dispatched microkernel — Merged
                    // cold fills ride the same AVX2/NEON path as the
                    // generator GEMMs (pack_b picks the probed layout)
                    let pb = kernel::pack_b(fb, l.rank, p.b);
                    let mut dw = vec![0.0f32; p.a * p.b];
                    kernel::gemm(fa, p.a, &pb, &mut dw);
                    for (t, d) in theta[p.off..p.off + p.a * p.b].iter_mut().zip(&dw) {
                        *t += d * l.scale;
                    }
                }
            }
        }
        Tensor::from_f32(theta, &[self.dc])
    }
}

/// Why a request did not produce a prediction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Bounced at admission (shard queue full, circuit open, or shard
    /// down) — the request was never queued; explicit backpressure, retry
    /// later.
    Rejected(String),
    /// Accepted but failed validation or execution inside the engine.
    Failed(String),
    /// Accepted but shed at batch formation because its deadline passed
    /// before the engine could run it — never executed.
    DeadlineExceeded,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected(m) => write!(f, "rejected: {m}"),
            ServeError::Failed(m) => write!(f, "failed: {m}"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded"),
        }
    }
}

/// The single reply every submitted request receives.
#[derive(Debug, Clone)]
pub struct Response {
    /// The request id assigned at submission.
    pub id: u64,
    /// The task the request targeted.
    pub task: usize,
    /// Next-token prediction for the sequence's last position (proof the
    /// batch really ran through the model), or why there is none. Every
    /// submitted request receives exactly one Response — errors included.
    pub result: Result<i32, ServeError>,
    /// Submit → response time.
    pub latency: Duration,
    /// How many real requests shared the batch (0 for error responses
    /// produced outside a batch).
    pub batch_rows: usize,
}

impl Response {
    /// Whether the request produced a prediction.
    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }

    /// The predicted next token, if any.
    pub fn next_token(&self) -> Option<i32> {
        self.result.as_ref().ok().copied()
    }
}

/// Decode an MCNC2-encoded adapter stream into the manifest's trainable
/// slot order (frames may arrive in any order; names must match specs
/// exactly). This is the wire side of a Merged-mode cold fill: the
/// coordinator can ingest the encoded bytes a trainer shipped without an
/// intermediate checkpoint file, decoding tensor-by-tensor as they stream
/// in. The container's entry must belong to `kind` — the wire twin of
/// `Checkpoint::restore`'s entry check, so an adapter trained for a
/// different family with coincidentally matching slot shapes is rejected
/// instead of silently serving the wrong weights.
fn decode_adapter(
    kind: &str,
    specs: &[IoSpec],
    reader: impl std::io::Read,
) -> Result<Vec<Tensor>> {
    let mut dec = codec::Decoder::new(reader).context("decoding adapter stream")?;
    if !dec.header().entry.starts_with(kind) {
        bail!(
            "encoded adapter is for entry {:?}, this engine serves kind {kind:?}",
            dec.header().entry
        );
    }
    // frame decode fans across the thread pool (entropy decode dominates a
    // cold fill's CPU cost); corruption on a worker is still a plain Err
    let frames: Vec<(String, Tensor)> = dec
        .decode_all()?
        .into_iter()
        .map(|(name, t, codec)| {
            obs::count_decoded_frame(codec.name());
            (name, t)
        })
        .collect();
    let mut out = Vec::with_capacity(specs.len());
    for spec in specs {
        let ix = frames
            .iter()
            .position(|(n, _)| n == &spec.name)
            .ok_or_else(|| anyhow!("encoded adapter is missing tensor {:?}", spec.name))?;
        out.push(frames.swap_remove(ix).1);
    }
    if !frames.is_empty() {
        let extra: Vec<&str> = frames.iter().map(|(n, _)| n.as_str()).collect();
        bail!("encoded adapter has unknown tensors: {}", extra.join(", "));
    }
    Ok(out)
}

/// Synthesize one task's demo adapter from its task-specific seed: the
/// entry's trainable init tensors, with the first slot (α/coef) perturbed
/// so adapters differ across tasks and reconstruction is non-trivial
/// (zero-init adapters would all produce θ0). Shared by engine seeding and
/// the `mcnc warm` artifact producer, so a warm-start artifact written for
/// the same base seed reproduces exactly what an engine would self-seed.
pub(crate) fn synth_adapter(entry: &Entry, seed: u64, task: usize) -> Result<Vec<Tensor>> {
    let tslots = init_inputs(entry, seed ^ (0xAD00 + task as u64))?;
    let mut tr: Vec<Tensor> = Vec::with_capacity(tslots.len());
    for (spec, t) in tslots {
        if spec.role != Role::Trainable {
            continue;
        }
        // init_inputs materializes every Trainable slot; a hole is a
        // manifest bug and must answer the caller, not panic a shard
        let t = t.ok_or_else(|| anyhow!("trainable slot {} has no init tensor", spec.name))?;
        tr.push(t);
    }
    if let Some(first) = tr.first_mut() {
        let mut s = crate::util::prng::Stream::new(seed ^ (0x5EED + task as u64));
        let dims = first.dims.clone();
        let n = first.numel();
        *first = Tensor::from_f32(s.normal_f32(n, 0.05), &dims)?;
    }
    Ok(tr)
}

/// Collect the materialized Static-role tensors out of an `init_inputs`
/// slot list, in spec order. `init_inputs` fills every Static slot, so a
/// hole is a manifest bug — surfaced as an error that answers the caller
/// instead of panicking a shard thread.
fn static_slots(slots: &[(IoSpec, Option<Tensor>)]) -> Result<Vec<Tensor>> {
    let mut out = Vec::new();
    for (spec, t) in slots {
        if spec.role != Role::Static {
            continue;
        }
        let t = t.clone().ok_or_else(|| anyhow!("static slot {} has no init tensor", spec.name))?;
        out.push(t);
    }
    Ok(out)
}

/// Validate adapter tensors against the executable's trainable specs —
/// `install_adapter` must reject malformed checkpoints up front so the
/// serving path never panics on a bad slot count or shape.
fn validate_adapter(specs: &[IoSpec], trainables: &[Tensor]) -> Result<()> {
    if trainables.is_empty() {
        bail!("adapter has no trainable tensors");
    }
    if trainables.len() != specs.len() {
        bail!(
            "adapter has {} trainable slots, manifest wants {} ({})",
            trainables.len(),
            specs.len(),
            specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(",")
        );
    }
    for (spec, t) in specs.iter().zip(trainables) {
        if t.dims != spec.shape {
            bail!("adapter slot {}: shape {:?} != manifest {:?}", spec.name, t.dims, spec.shape);
        }
        if t.dtype() != spec.dtype {
            bail!("adapter slot {}: dtype mismatch", spec.name);
        }
    }
    Ok(())
}

/// One shard's engine: everything that touches PJRT. Single-threaded by
/// design (one engine per shard thread); the `Server` front-end fans
/// requests out across engines.
pub struct Engine {
    session: Session,
    cfg: ServerCfg,
    /// This engine's shard index; it owns tasks `t % n_shards == shard`.
    shard: usize,
    predict: String,
    statics: Vec<Tensor>,
    /// Trainable input specs of the predict executable (adapter layout).
    trainable_specs: Vec<IoSpec>,
    /// Per-task compressed adapter state (trainables, manifest order).
    adapters: HashMap<usize, Vec<Tensor>>,
    /// Merged mode: reconstructed full θ per task, shared by ref so serving
    /// a cached task never deep-copies the full weight vector.
    merged_cache: LruCache<usize, Arc<Vec<Tensor>>>,
    dense_statics: Vec<Tensor>,
    /// Native GEMM reconstruction twin for Merged cold fills, when the
    /// adapter family supports it (mcnc / mcnc_lora kinds).
    native: Option<NativeRecon>,
    batch_size: usize,
    seq: usize,
    /// This engine's serving counters (merged across shards on stop).
    pub stats: ServeStats,
    recon_flops_per_pass: u64,
    /// Registry mirror of the cache / decode / reconstruction counters.
    obs: obs::EngineObs,
}

impl Engine {
    /// Build an unsharded engine owning every task (a 1-shard server).
    pub fn new(session: Session, mut cfg: ServerCfg) -> Result<Engine> {
        cfg.n_shards = 1;
        Engine::new_sharded(session, cfg, 0)
    }

    /// Build the engine for one shard: it synthesizes adapters only for
    /// tasks it owns (`task % cfg.n_shards == shard`) and gets an even
    /// split of the merged-cache byte budget.
    pub fn new_sharded(session: Session, cfg: ServerCfg, shard: usize) -> Result<Engine> {
        let n_shards = cfg.n_shards.max(1);
        let predict = format!("{}_predict", cfg.kind);
        let entry = session.entry(&predict)?.clone();
        let x_spec = entry.inputs.last().ok_or_else(|| anyhow!("{predict} declares no inputs"))?;
        let (batch_size, seq) = (x_spec.shape[0], x_spec.shape[1]);
        // an oversized router batch would index past build_x's buffer and
        // panic the shard thread — reject the misconfiguration up front
        if cfg.policy.max_batch > batch_size {
            bail!(
                "policy.max_batch {} exceeds {predict}'s compiled batch size {batch_size}",
                cfg.policy.max_batch
            );
        }

        // shared statics (θ0, generator weights / bases) from the base seed
        let slots = init_inputs(&entry, cfg.seed)?;
        let statics = static_slots(&slots)?;
        let trainable_specs: Vec<IoSpec> = entry
            .inputs
            .iter()
            .filter(|s| s.role == Role::Trainable)
            .cloned()
            .collect();

        // per-task adapters: synthesized from task-specific seeds (replaced
        // by fine-tuned checkpoints via `install_adapter`), restricted to
        // the tasks this shard owns
        let mut adapters = HashMap::new();
        for task in (0..cfg.n_tasks).filter(|t| t % n_shards == shard) {
            adapters.insert(task, synth_adapter(&entry, cfg.seed, task)?);
        }

        let recon_flops_per_pass = entry.recon_flops() as u64;
        // only pay the θ0/weight-copy + panel packing when the native fill
        // path can actually be taken
        let native = if cfg.mode == Mode::Merged && cfg.native_recon {
            NativeRecon::build(&entry, &statics)
        } else {
            None
        };

        // merged-mode plumbing: the dense predict path is always required;
        // the PJRT recon executable only when native fills can't cover it
        let mut dense_statics = Vec::new();
        if cfg.mode == Mode::Merged {
            let dense = session.entry("lm_dense_predict")?.clone();
            let dslots = init_inputs(&dense, cfg.seed)?;
            dense_statics = static_slots(&dslots)?;
            if !(cfg.native_recon && native.is_some()) {
                session.entry(&format!("{}_recon", cfg.kind))?; // must exist
            }
        }

        let cache_bytes = (cfg.cache_bytes / n_shards).max(1);
        Ok(Engine {
            session,
            shard,
            predict,
            statics,
            trainable_specs,
            adapters,
            merged_cache: LruCache::new(cache_bytes),
            dense_statics,
            native,
            batch_size,
            seq,
            stats: ServeStats::default(),
            recon_flops_per_pass,
            obs: obs::EngineObs::register(shard),
            cfg,
        })
    }

    /// The predict executable's compiled batch size.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// The token-sequence length the predict executable expects.
    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Whether this engine holds an adapter for `task`.
    pub fn has_task(&self, task: usize) -> bool {
        self.adapters.contains_key(&task)
    }

    /// Compile the hot executables off the latency path.
    pub fn warm(&self) -> Result<()> {
        self.session.load(&self.predict)?;
        if self.cfg.mode == Mode::Merged {
            self.session.load("lm_dense_predict")?;
            if self.native.is_none() {
                // cold fills will dispatch the PJRT recon executable
                self.session.load(&format!("{}_recon", self.cfg.kind))?;
            }
        }
        Ok(())
    }

    /// Install fine-tuned adapter weights for a task. Validates the slot
    /// count/shapes against the manifest's trainable specs and drops any
    /// stale merged θ cached for the task.
    pub fn install_adapter(&mut self, task: usize, trainables: Vec<Tensor>) -> Result<()> {
        let n_shards = self.cfg.n_shards.max(1);
        if task % n_shards != self.shard {
            bail!("task {task} belongs to shard {}, not {}", task % n_shards, self.shard);
        }
        validate_adapter(&self.trainable_specs, &trainables)?;
        self.merged_cache.remove(&task);
        self.adapters.insert(task, trainables);
        Ok(())
    }

    /// Install a task's adapter directly from an encoded MCNC2 stream (the
    /// wire format `Checkpoint::save_v2` / the codec `Encoder` produce), so
    /// a Merged-mode cold fill can ingest what came off the network without
    /// first materializing a checkpoint file. Decoding is streaming and
    /// CRC-checked per frame, the container's entry must belong to this
    /// engine's adapter family, and the decoded slots go through the same
    /// manifest validation as [`Engine::install_adapter`].
    pub fn install_adapter_encoded(
        &mut self,
        task: usize,
        reader: impl std::io::Read,
    ) -> Result<()> {
        // decode is timed here, on the coordinator side of the codec
        // boundary — codec/ itself stays wall-clock-free (determinism lint)
        let t0 = Instant::now();
        let mut meter = obs::MeterRead::new(reader);
        let trainables = decode_adapter(&self.cfg.kind, &self.trainable_specs, &mut meter)?;
        let done = Instant::now();
        self.obs.record_decode(meter.bytes(), trainables.len() as u64, done - t0);
        obs::trace::span(0, self.shard, task, obs::Kind::Decode, t0, done);
        self.install_adapter(task, trainables)
    }

    /// Warm-start this engine from a multi-task warm artifact stream (the
    /// `task{t}/{slot}`-framed MCNC2 container `coordinator::warm` writes,
    /// e.g. via `mcnc warm`): frames decode in parallel across the thread
    /// pool — and only the frames this shard *owns* pay entropy decode +
    /// dequantization (foreign frames are CRC-verified and skipped, so an
    /// S-shard preload does ~1× the artifact's decode work in total, not
    /// S×). Owned adapters go through the same manifest validation as
    /// [`Engine::install_adapter`], and — when the native Merged
    /// reconstruction engine is available — each installed task's full θ is
    /// reconstructed up front into the merged LRU, so the first request per
    /// task is a cache hit instead of a cold fill.
    pub fn warm_from_artifact(&mut self, reader: impl std::io::Read) -> Result<WarmStats> {
        // caller-side decode timing (see install_adapter_encoded)
        let t0 = Instant::now();
        let mut meter = obs::MeterRead::new(reader);
        let mut dec = codec::Decoder::new(&mut meter).context("decoding warm-start artifact")?;
        if !dec.header().entry.starts_with(&self.cfg.kind) {
            bail!(
                "warm artifact is for entry {:?}, this engine serves kind {:?}",
                dec.header().entry,
                self.cfg.kind
            );
        }
        let n_shards = self.cfg.n_shards.max(1);
        let shard = self.shard;
        // misnamed frames pass the filter so group_for_shard still rejects
        // them with its precise error instead of them vanishing silently
        let frames = dec.decode_all_filtered_with(
            crate::util::threadpool::global(),
            move |name| match warm::parse_frame_name(name) {
                Some((task, _)) => task % n_shards == shard,
                None => true,
            },
        )?;
        let skipped = dec.frames_seen() - frames.len();
        drop(dec);
        let done = Instant::now();
        self.obs.record_decode(meter.bytes(), frames.len() as u64, done - t0);
        obs::trace::span(0, shard, 0, obs::Kind::Decode, t0, done);
        for (_, _, codec) in &frames {
            obs::count_decoded_frame(codec.name());
        }
        let quantized = frames.iter().filter(|(_, _, c)| !c.is_lossless()).count();
        let (owned, _) = warm::group_for_shard(frames, &self.trainable_specs, shard, n_shards)?;
        // validate every owned task (range + manifest shapes — the same
        // checks install_adapter runs) *before* the first install, so a
        // bad artifact fails the preload without leaving the shard
        // half-warmed with some adapters silently replaced
        for (task, trainables) in &owned {
            if *task >= self.cfg.n_tasks {
                bail!(
                    "warm artifact task {task} out of range (server has {} tasks)",
                    self.cfg.n_tasks
                );
            }
            validate_adapter(&self.trainable_specs, trainables)
                .with_context(|| format!("warm artifact task {task}"))?;
        }
        let mut stats = WarmStats { skipped, quantized, ..WarmStats::default() };
        let mut warmed_tasks = Vec::new();
        for (task, trainables) in owned {
            self.install_adapter(task, trainables)?;
            stats.installed += 1;
            if let Some(nr) = &self.native {
                let adapter = self
                    .adapters
                    .get(&task)
                    .ok_or_else(|| anyhow!("task {task}: adapter missing after install"))?;
                let theta = nr.reconstruct(adapter)?;
                let raw = adapter
                    .last()
                    .ok_or_else(|| anyhow!("task {task}: adapter has no trainable tensors"))?
                    .clone();
                // same [θ_c, raw] layout as a run_batch cold fill; counted
                // in WarmStats (not native_fills/cache_misses — those stay
                // exact request-path counters)
                self.merged_cache.put(task, Arc::new(vec![theta, raw]));
                warmed_tasks.push(task);
            }
        }
        // put() silently rejects oversized entries and a later task's θ
        // can evict an earlier one's, so count prefills only after every
        // insert has settled — the operator is never told a cold fill was
        // eliminated when it wasn't
        stats.prefilled =
            warmed_tasks.iter().filter(|t| self.merged_cache.contains(t)).count();
        Ok(stats)
    }

    fn build_x(&self, batch: &Batch) -> Result<(Tensor, usize)> {
        let b = self.batch_size;
        let t = self.seq;
        let mut x = vec![0i32; b * t];
        for (i, req) in batch.requests.iter().enumerate() {
            if req.tokens.len() != t {
                bail!("request {} has {} tokens, executable wants {t}", req.id, req.tokens.len());
            }
            x[i * t..(i + 1) * t].copy_from_slice(&req.tokens);
        }
        // pad by repeating the first row
        let padded = b - batch.requests.len();
        for i in batch.requests.len()..b {
            let src: Vec<i32> = x[..t].to_vec();
            x[i * t..(i + 1) * t].copy_from_slice(&src);
        }
        Ok((Tensor::from_i32(x, &[b, t])?, padded))
    }

    /// Run one batch; returns per-request next-token predictions. Errors
    /// are per-batch: the caller (shard loop) answers the batch's requests
    /// with error Responses and keeps serving.
    pub fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        let (x, padded) = self.build_x(batch)?;
        let adapter = self
            .adapters
            .get(&batch.task)
            .ok_or_else(|| anyhow!("unknown task {}", batch.task))?;

        let logits = match self.cfg.mode {
            Mode::OnTheFly => {
                let mut inputs: Vec<&Tensor> =
                    Vec::with_capacity(self.statics.len() + adapter.len() + 1);
                inputs.extend(self.statics.iter());
                inputs.extend(adapter.iter());
                inputs.push(&x);
                self.stats.recon_flops += self.recon_flops_per_pass;
                self.obs.recon_flops.add(self.recon_flops_per_pass);
                self.session.run_refs(&self.predict, &inputs)?.remove(0)
            }
            Mode::Merged => {
                let dense_tr: Arc<Vec<Tensor>> =
                    if let Some(v) = self.merged_cache.get(&batch.task) {
                        self.stats.cache_hits += 1;
                        self.obs.cache_hits.inc();
                        Arc::clone(v)
                    } else {
                        // cold task: reconstruct full weights — natively via
                        // the blocked-GEMM engine when built (new_sharded
                        // gates that on cfg.native_recon), else through the
                        // PJRT recon executable
                        let t_fill = Instant::now();
                        let native = self.native.is_some();
                        let theta = if let Some(nr) = &self.native {
                            self.stats.native_fills += 1;
                            self.obs.native_fills.inc();
                            nr.reconstruct(adapter)?
                        } else {
                            let recon = format!("{}_recon", self.cfg.kind);
                            let mut rin: Vec<&Tensor> = self.statics.iter().collect();
                            rin.extend(adapter.iter());
                            self.session.run_refs(&recon, &rin)?.remove(0)
                        };
                        // the native path's cost is the packed blocked GEMM,
                        // so its fill span doubles as the request's GEMM span
                        obs::trace::span(
                            batch.trace_id(),
                            self.shard,
                            batch.task,
                            if native { obs::Kind::Gemm } else { obs::Kind::Fill },
                            t_fill,
                            Instant::now(),
                        );
                        self.stats.recon_flops += self.recon_flops_per_pass;
                        self.obs.recon_flops.add(self.recon_flops_per_pass);
                        self.stats.cache_misses += 1;
                        self.obs.cache_misses.inc();
                        // dense trainables = [theta_c, raw]; raw comes from
                        // the adapter state (last trainable by convention)
                        let raw = adapter
                            .last()
                            .ok_or_else(|| {
                                anyhow!("task {}: adapter has no trainable tensors", batch.task)
                            })?
                            .clone();
                        let v = Arc::new(vec![theta, raw]);
                        // an entry larger than this shard's cache slice is
                        // rejected by put — still serve it, just uncached
                        let ev0 = self.merged_cache.evictions;
                        self.merged_cache.put(batch.task, Arc::clone(&v));
                        self.obs.cache_evictions.add(self.merged_cache.evictions - ev0);
                        self.obs.cache_used_bytes.set(self.merged_cache.used_bytes() as i64);
                        self.obs.cache_entries.set(self.merged_cache.len() as i64);
                        v
                    };
                let mut inputs: Vec<&Tensor> =
                    Vec::with_capacity(self.dense_statics.len() + dense_tr.len() + 1);
                inputs.extend(self.dense_statics.iter());
                inputs.extend(dense_tr.iter());
                inputs.push(&x);
                self.session.run_refs("lm_dense_predict", &inputs)?.remove(0)
            }
        };

        // logits [b, t, v] → next-token argmax at the last position per row
        let v = *logits.dims.last().ok_or_else(|| anyhow!("predict output has no dims"))?;
        let lf = logits.f32s()?;
        let row = self.seq * v;
        let preds = (0..batch.requests.len())
            .map(|i| {
                let base = i * row + (self.seq - 1) * v;
                let mut best = (f32::MIN, 0i32);
                for c in 0..v {
                    if lf[base + c] > best.0 {
                        best = (lf[base + c], c as i32);
                    }
                }
                best.1
            })
            .collect();

        self.stats.batches += 1;
        self.stats.rows += self.batch_size as u64;
        self.stats.padded_rows += padded as u64;
        Ok(preds)
    }
}

impl EngineCore for Engine {
    // `Engine::x` paths resolve to the inherent methods (inherent items
    // take precedence over trait items), so these are pure delegation
    fn seq(&self) -> usize {
        Engine::seq(self)
    }

    fn has_task(&self, task: usize) -> bool {
        Engine::has_task(self, task)
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        Engine::run_batch(self, batch)
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        &mut self.stats
    }

    fn into_stats(self) -> ServeStats {
        self.stats
    }

    fn preload(&mut self, artifact: &std::path::Path) -> Result<WarmStats> {
        let f = std::fs::File::open(artifact).with_context(|| {
            format!("opening warm-start artifact {}", artifact.display())
        })?;
        self.warm_from_artifact(std::io::BufReader::new(f))
    }
}

/// Front-end handle to a running sharded server: routes each request to
/// the shard owning its task, applies admission control, and merges
/// per-shard stats on stop.
pub struct Server {
    shards: Vec<Shard>,
    /// Request-id mint; the id doubles as the request's trace id.
    next_id: obs::IdGen,
    // Exact per-`Server` admission counters, read by `stop()`. These are
    // local `obs::Counter`s (not registry handles) so `stop()` returns
    // this server's numbers even when several servers share the process;
    // `obs` below mirrors every increment into the global registry.
    rejected: obs::Counter,
    retries: obs::Counter,
    fastfail: obs::Counter,
    /// Process-wide registry mirror of the admission counters.
    obs: obs::ServerObs,
    deadline: Option<Duration>,
    retry: RetryPolicy,
    seed: u64,
    /// Warm-artifact path shared with the shard supervisors so restarted
    /// engines re-warm themselves (set by `preload`).
    warm: WarmSlot,
}

impl Server {
    /// Spawn `cfg.n_shards` PJRT engine shards. Each Session is created
    /// inside its shard thread (PjRtClient is not Send). Errs when a shard
    /// worker thread cannot be spawned (fd/thread exhaustion) — already-
    /// spawned shards are stopped and joined before the error surfaces.
    ///
    /// ```no_run
    /// use mcnc::coordinator::{Server, ServerCfg};
    /// use mcnc::runtime::artifacts_dir;
    ///
    /// // needs `make artifacts`; see Server::start_with for a
    /// // dependency-free runnable example
    /// let cfg = ServerCfg { n_shards: 4, ..ServerCfg::default() };
    /// let server = Server::start(artifacts_dir(), cfg).unwrap();
    /// let rx = server.submit(0, vec![0; 32]);
    /// let response = rx.recv().unwrap();
    /// println!("{:?}", response.result);
    /// server.stop().unwrap();
    /// ```
    pub fn start(artifacts: std::path::PathBuf, cfg: ServerCfg) -> Result<Server> {
        let engine_cfg = cfg.clone();
        Server::start_with(&cfg, move |shard| {
            let session = Session::open(&artifacts).context("opening session")?;
            let engine = Engine::new_sharded(session, engine_cfg.clone(), shard)?;
            engine.warm()?;
            Ok(engine)
        })
    }

    /// Spawn shards around a custom engine factory (called once per shard,
    /// on the shard's own thread). This is how non-PJRT engines — test
    /// doubles, native-only backends — reuse the coordinator: routing,
    /// batching, admission control and fault isolation are identical.
    ///
    /// ```
    /// use mcnc::coordinator::{Batch, EngineCore, ServeStats, Server, ServerCfg};
    ///
    /// /// Minimal engine: echoes each request's first token back.
    /// struct Echo {
    ///     stats: ServeStats,
    /// }
    ///
    /// impl EngineCore for Echo {
    ///     fn seq(&self) -> usize {
    ///         4
    ///     }
    ///     fn has_task(&self, task: usize) -> bool {
    ///         task < 2
    ///     }
    ///     fn run_batch(&mut self, batch: &Batch) -> anyhow::Result<Vec<i32>> {
    ///         Ok(batch.requests.iter().map(|r| r.tokens[0]).collect())
    ///     }
    ///     fn stats_mut(&mut self) -> &mut ServeStats {
    ///         &mut self.stats
    ///     }
    ///     fn into_stats(self) -> ServeStats {
    ///         self.stats
    ///     }
    /// }
    ///
    /// let cfg = ServerCfg { n_shards: 2, ..ServerCfg::default() };
    /// let server = Server::start_with(&cfg, |_shard| -> anyhow::Result<Echo> {
    ///     Ok(Echo { stats: ServeStats::default() })
    /// })
    /// .unwrap();
    /// let rx = server.submit(1, vec![41, 0, 0, 0]);
    /// assert_eq!(rx.recv().unwrap().next_token(), Some(41));
    /// server.stop().unwrap();
    /// ```
    pub fn start_with<E, F>(cfg: &ServerCfg, factory: F) -> Result<Server>
    where
        E: EngineCore,
        F: Fn(usize) -> Result<E> + Send + Clone + 'static,
    {
        let n = cfg.n_shards.max(1);
        let warm: WarmSlot = Arc::new(Mutex::new(None));
        let mut shards: Vec<Shard> = Vec::with_capacity(n);
        for ix in 0..n {
            let f = factory.clone();
            let breaker = Arc::new(Breaker::new(cfg.breaker));
            let spawned = Shard::spawn(
                ix,
                cfg.policy,
                cfg.queue_cap,
                cfg.heartbeat,
                cfg.restart,
                Arc::clone(&warm),
                breaker,
                move || f(ix),
            );
            match spawned {
                Ok(s) => shards.push(s),
                Err(e) => {
                    // refuse to come up half-sharded: stop and join what
                    // already started, then surface the spawn error
                    for s in &shards {
                        let _ = s.tx.send(Msg::Stop);
                    }
                    for s in shards {
                        let _ = s.handle.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(Server {
            shards,
            next_id: obs::IdGen::new(),
            rejected: obs::Counter::new(),
            retries: obs::Counter::new(),
            fastfail: obs::Counter::new(),
            obs: obs::ServerObs::register(),
            deadline: cfg.deadline,
            retry: cfg.retry,
            seed: cfg.seed,
            warm,
        })
    }

    /// Number of engine shards this server dispatches over.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Warm-start every shard from a compressed multi-task artifact (the
    /// `mcnc warm` output): the path is broadcast to all shards, which
    /// decode it concurrently — each additionally fanning frame decode
    /// across the thread pool — install the tasks they own, and pre-fill
    /// their merged LRUs where the native reconstruction engine allows.
    /// Blocks until every shard has finished (or failed); the first shard
    /// error wins, and per-shard [`WarmStats`] are summed. Call before
    /// opening traffic — preloads share the admission queue with requests.
    pub fn preload(&self, artifact: &std::path::Path) -> Result<WarmStats> {
        // remember the artifact so a supervisor restart re-warms the
        // replacement engine from it
        match self.warm.lock() {
            Ok(mut g) => *g = Some(artifact.to_path_buf()),
            Err(p) => *p.into_inner() = Some(artifact.to_path_buf()),
        }
        let mut acks = Vec::with_capacity(self.shards.len());
        for (ix, s) in self.shards.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            s.tx.send(Msg::Preload(artifact.to_path_buf(), tx))
                .map_err(|_| anyhow!("shard {ix} unavailable for preload"))?;
            acks.push((ix, rx));
        }
        let mut total = WarmStats::default();
        for (ix, rx) in acks {
            let stats = rx
                .recv()
                .map_err(|_| anyhow!("shard {ix} dropped its preload ack"))?
                .with_context(|| format!("shard {ix} preload"))?;
            total.merge(&stats);
        }
        Ok(total)
    }

    /// Submit a request under the server's default deadline; the returned
    /// channel yields exactly one Response (a prediction, or an
    /// error/rejected outcome — never a hang).
    pub fn submit(&self, task: usize, tokens: Vec<i32>) -> mpsc::Receiver<Response> {
        self.submit_with(task, tokens, self.deadline)
    }

    /// Submit with an explicit per-request deadline (`None` = none),
    /// overriding the server default. Admission applies, in order: the
    /// shard's circuit breaker (open → fast `Rejected`), then the bounded
    /// admission queue with the configured retry-with-jitter on `Full`.
    /// A `SyncSender` failure of any kind still answers the request — a
    /// dead shard produces an error Response, never a silent drop.
    pub fn submit_with(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Duration>,
    ) -> mpsc::Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        let now = Instant::now();
        self.dispatch(task, tokens, deadline.map(|d| now + d), rtx, self.retry.attempts);
        rrx
    }

    /// Submit a request whose `Response` is routed to a **caller-owned**
    /// channel instead of a fresh per-request one — the socket front-end's
    /// path, where one channel per connection funnels every reply back to
    /// the poll loop. Takes an absolute deadline (remote clients specify
    /// time budgets, not wall-clock instants, so the listener anchors them
    /// on arrival) and returns the server-minted request id, which doubles
    /// as the trace id and keys the connection's reply routing. Admission
    /// retries are disabled (`attempts = 0`): the retry path sleeps, and
    /// the caller is an event loop that must never block — backpressure
    /// surfaces immediately as a `Rejected` response instead.
    pub fn submit_routed(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Instant>,
        reply: &mpsc::Sender<Response>,
    ) -> u64 {
        self.dispatch(task, tokens, deadline, reply.clone(), 0)
    }

    /// Shared admission path behind [`Server::submit_with`] and
    /// [`Server::submit_routed`]: mint an id, run breaker → bounded-queue
    /// admission with up to `attempts` retries, and guarantee exactly one
    /// `Response` reaches `rtx` whatever happens. Returns the minted id.
    fn dispatch(
        &self,
        task: usize,
        tokens: Vec<i32>,
        deadline: Option<Instant>,
        rtx: mpsc::Sender<Response>,
        attempts: u32,
    ) -> u64 {
        let id = self.next_id.next();
        self.obs.requests.inc();
        let req = Request { id, task, tokens, enqueued: Instant::now(), deadline };
        let shard = task % self.shards.len();
        if !self.shards[shard].breaker.allow() {
            self.fastfail.inc();
            self.obs.fastfail.inc();
            let _ = rtx.send(error_response(
                &req,
                ServeError::Rejected(format!("shard {shard} circuit open")),
            ));
            return id;
        }
        let mut msg = Msg::Req(req, rtx);
        let mut attempt = 0u32;
        let (bounced, err) = loop {
            match self.shards[shard].tx.try_send(msg) {
                Ok(()) => return id,
                Err(mpsc::TrySendError::Full(m)) => {
                    if attempt >= attempts {
                        self.rejected.inc();
                        self.obs.rejected.inc();
                        break (
                            m,
                            ServeError::Rejected(format!("shard {shard} admission queue full")),
                        );
                    }
                    attempt += 1;
                    self.retries.inc();
                    self.obs.retries.inc();
                    // doubling backoff + deterministic per-(request,
                    // attempt) jitter so colliding submitters
                    // desynchronize reproducibly
                    let base = self.retry.backoff.as_micros() as u64;
                    let jitter = if base == 0 {
                        0
                    } else {
                        Stream::sub(self.seed ^ id, tag::DATA + attempt as u64).next_u64()
                            % (base / 2 + 1)
                    };
                    let us = base.saturating_mul(1 << (attempt - 1).min(10)) + jitter;
                    thread::sleep(Duration::from_micros(us));
                    msg = m;
                }
                Err(mpsc::TrySendError::Disconnected(m)) => {
                    break (m, ServeError::Failed(format!("shard {shard} unavailable")));
                }
            }
        };
        if let Msg::Req(req, rtx) = bounced {
            let _ = rtx.send(error_response(&req, err));
        }
        id
    }

    /// Snapshot the observability metrics registry: every counter, gauge
    /// and histogram the serving path, codec callers and kernels have
    /// registered. The registry is **process-wide** — when several servers
    /// share the process the snapshot covers all of them; for this
    /// server's exact accounting use the `ServeStats` from [`Server::stop`].
    /// Feed the result to [`crate::obs::export::prometheus_text`] or
    /// [`crate::obs::export::snapshot_json`].
    pub fn metrics_snapshot(&self) -> obs::Snapshot {
        obs::registry().snapshot()
    }

    /// How long a response collector should wait before declaring a
    /// request lost: the configured deadline plus a generous margin, or
    /// two minutes when no deadline is set (see `workload::replay`).
    pub fn collect_timeout(&self) -> Duration {
        match self.deadline {
            Some(d) => d + Duration::from_secs(30),
            None => Duration::from_secs(120),
        }
    }

    /// Stop after draining every shard; joins all shard threads and merges
    /// their ServeStats (counters sum, histograms merge, wall-clock is the
    /// longest shard's). The first shard error, if any, is returned.
    pub fn stop(mut self) -> Result<ServeStats> {
        let shards = std::mem::take(&mut self.shards);
        for s in &shards {
            let _ = s.tx.send(Msg::Stop);
        }
        let mut total = ServeStats::default();
        let mut first_err: Option<anyhow::Error> = None;
        for s in shards {
            match s.handle.join() {
                Ok(Ok(st)) => total.merge(&st),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("shard thread panicked"));
                    }
                }
            }
        }
        total.rejected += self.rejected.get();
        total.retries += self.retries.get();
        total.breaker_fastfail += self.fastfail.get();
        match first_err {
            Some(e) => Err(e),
            None => Ok(total),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let shards = std::mem::take(&mut self.shards);
        for s in &shards {
            let _ = s.tx.send(Msg::Stop);
        }
        for s in shards {
            let _ = s.handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    fn spec(name: &str, shape: &[usize]) -> IoSpec {
        IoSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype: DType::F32,
            role: Role::Trainable,
            init: None,
        }
    }

    fn t(shape: &[usize]) -> Tensor {
        Tensor::zeros(shape)
    }

    #[test]
    fn validate_adapter_rejects_empty() {
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        let err = validate_adapter(&specs, &[]).unwrap_err();
        assert!(err.to_string().contains("no trainable"), "{err}");
    }

    #[test]
    fn validate_adapter_rejects_wrong_slot_count() {
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        let err = validate_adapter(&specs, &[t(&[2, 3])]).unwrap_err();
        assert!(err.to_string().contains("trainable slots"), "{err}");
    }

    #[test]
    fn validate_adapter_rejects_wrong_shape() {
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        let err = validate_adapter(&specs, &[t(&[2, 3]), t(&[4])]).unwrap_err();
        assert!(err.to_string().contains("shape"), "{err}");
    }

    #[test]
    fn validate_adapter_accepts_matching() {
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        validate_adapter(&specs, &[t(&[2, 3]), t(&[3])]).unwrap();
    }

    fn encoded_adapter(tensors: &[(&str, Tensor)]) -> Vec<u8> {
        let header = codec::ContainerHeader {
            entry: "lm_mcnclora8_predict".into(),
            seed: 1,
            step: 0.0,
            n_tensors: Some(tensors.len()),
        };
        let mut enc = codec::Encoder::new(Vec::new(), &header).unwrap();
        for (name, t) in tensors {
            enc.write_tensor(name, t, codec::Codec::Lossless).unwrap();
        }
        enc.finish().unwrap().0
    }

    #[test]
    fn decode_adapter_orders_by_spec() {
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        // frames arrive in the opposite order; decode must return spec order
        let bytes = encoded_adapter(&[("beta", t(&[3])), ("alpha", t(&[2, 3]))]);
        let tr = decode_adapter("lm_mcnclora8", &specs, &bytes[..]).unwrap();
        assert_eq!(tr.len(), 2);
        assert_eq!(tr[0].dims, vec![2, 3]);
        assert_eq!(tr[1].dims, vec![3]);
        validate_adapter(&specs, &tr).unwrap();
    }

    #[test]
    fn decode_adapter_rejects_missing_and_unknown() {
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        let bytes = encoded_adapter(&[("alpha", t(&[2, 3]))]);
        let err = decode_adapter("lm_mcnclora8", &specs, &bytes[..]).unwrap_err();
        assert!(format!("{err:#}").contains("missing tensor"), "{err:#}");

        let bytes = encoded_adapter(&[
            ("alpha", t(&[2, 3])),
            ("beta", t(&[3])),
            ("gamma", t(&[1])),
        ]);
        let err = decode_adapter("lm_mcnclora8", &specs, &bytes[..]).unwrap_err();
        assert!(format!("{err:#}").contains("unknown tensors"), "{err:#}");
    }

    #[test]
    fn decode_adapter_rejects_wrong_family() {
        // same slot names/shapes, different adapter family: must not install
        let specs = vec![spec("alpha", &[2, 3]), spec("beta", &[3])];
        let bytes = encoded_adapter(&[("alpha", t(&[2, 3])), ("beta", t(&[3]))]);
        let err = decode_adapter("lm_nola8", &specs, &bytes[..]).unwrap_err();
        assert!(format!("{err:#}").contains("serves kind"), "{err:#}");
    }

    #[test]
    fn decode_adapter_rejects_corrupt_stream() {
        let specs = vec![spec("alpha", &[2, 3])];
        let mut bytes = encoded_adapter(&[("alpha", t(&[2, 3]))]);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        assert!(decode_adapter("lm_mcnclora8", &specs, &bytes[..]).is_err());
        assert!(decode_adapter("lm_mcnclora8", &specs, &bytes[..4]).is_err());
    }

    #[test]
    fn serve_error_display() {
        let r = ServeError::Rejected("queue full".into());
        let f = ServeError::Failed("bad tokens".into());
        assert!(r.to_string().contains("rejected"));
        assert!(f.to_string().contains("failed"));
        assert!(ServeError::DeadlineExceeded.to_string().contains("deadline"));
    }

    #[test]
    fn breaker_disabled_by_default() {
        let b = Breaker::new(BreakerCfg::default());
        for _ in 0..100 {
            assert!(!b.record_failure(), "threshold 0 must never open");
            assert!(b.allow());
        }
    }

    #[test]
    fn breaker_opens_after_threshold_and_probes_after_cooldown() {
        let cfg = BreakerCfg { threshold: 3, cooldown: Duration::from_millis(5) };
        let b = Breaker::new(cfg);
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        assert!(b.allow(), "still closed below threshold");
        assert!(b.record_failure(), "third consecutive failure opens");
        assert!(!b.allow(), "open: fast-fail before cooldown");
        std::thread::sleep(Duration::from_millis(6));
        assert!(b.allow(), "cooled down: one probe admitted");
        assert!(!b.allow(), "half-open: only one probe in flight");
        // probe succeeded → closed again
        b.record_success();
        assert!(b.allow());
    }

    #[test]
    fn breaker_failed_probe_reopens() {
        let cfg = BreakerCfg { threshold: 1, cooldown: Duration::from_millis(2) };
        let b = Breaker::new(cfg);
        assert!(b.record_failure(), "threshold 1 opens immediately");
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.allow(), "probe admitted");
        assert!(b.record_failure(), "failed probe re-opens");
        assert!(!b.allow(), "back to open, cooldown restarted");
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.allow(), "second probe after second cooldown");
        b.record_success();
        assert!(b.allow());
        assert!(b.allow(), "closed admits freely");
    }

    #[test]
    fn response_accessors() {
        let ok = Response {
            id: 1,
            task: 0,
            result: Ok(7),
            latency: Duration::from_millis(1),
            batch_rows: 4,
        };
        let err = Response {
            id: 2,
            task: 0,
            result: Err(ServeError::Failed("x".into())),
            latency: Duration::ZERO,
            batch_rows: 0,
        };
        assert!(ok.is_ok());
        assert_eq!(ok.next_token(), Some(7));
        assert!(!err.is_ok());
        assert_eq!(err.next_token(), None);
    }
}
