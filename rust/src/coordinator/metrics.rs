//! Serving metrics: the per-shard `ServeStats` counters behind the
//! Table-4/8 reports. The log-bucketed latency [`Histogram`] that used to
//! live here was promoted to [`crate::obs::hist`] so every layer shares
//! one bucket layout; it is re-exported here unchanged for existing
//! callers. `ServeStats` remains the exact per-`Server` accounting
//! returned by `stop()`; the obs registry mirrors these counters as the
//! process-wide live view (`Server::metrics_snapshot()`).

pub use crate::obs::Histogram;

/// Aggregate serving counters. Each shard keeps its own; `merge` folds
/// them into the server-wide totals on stop.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Submit → response time of completed (Ok) requests.
    pub latency: Histogram,
    /// Submit → batch-formation time of every dispatched request.
    pub queue_wait: Histogram,
    /// Batches executed.
    pub batches: u64,
    /// Rows executed (batch size × batches, padding included).
    pub rows: u64,
    /// Padding rows that carried no real request.
    pub padded_rows: u64,
    /// Merged-mode batches served from the merged-θ LRU.
    pub cache_hits: u64,
    /// Merged-mode batches that paid a cold reconstruction.
    pub cache_misses: u64,
    /// Merged cold fills served by the native blocked-GEMM engine (the
    /// remainder of `cache_misses` went through the PJRT recon executable).
    pub native_fills: u64,
    /// Reconstruction FLOPs spent (per the manifest's analytic count).
    pub recon_flops: u64,
    /// Requests answered with an error Response (malformed tokens, unknown
    /// task, batch execution failure) instead of a prediction.
    pub errors: u64,
    /// Requests bounced at admission (shard queue full) — counted by the
    /// front-end dispatcher, folded in on stop.
    pub rejected: u64,
    /// Engine-loop iterations; at zero load this tracks the heartbeat rate
    /// (the loop blocks between batches instead of spinning).
    pub wakeups: u64,
    /// Shard engine restarts performed by the supervisor after a crash.
    pub restarts: u64,
    /// Requests shed at batch formation because their deadline had passed
    /// (answered with `ServeError::DeadlineExceeded`, never executed).
    pub deadline_shed: u64,
    /// Batches whose execution panicked; the panic was contained and every
    /// request in the batch was answered with `ServeError::Failed`.
    pub batch_panics: u64,
    /// Times a per-shard circuit breaker transitioned closed → open.
    pub breaker_opens: u64,
    /// Requests fast-failed by an open circuit breaker at admission.
    pub breaker_fastfail: u64,
    /// Admission retries performed by the dispatcher after `Rejected`
    /// backpressure (successful or not).
    pub retries: u64,
    /// Serving window in seconds (the longest shard's, after `merge`).
    pub wall_secs: f64,
}

impl ServeStats {
    /// Real (non-padding) rows served per second of wall-clock.
    pub fn throughput(&self) -> f64 {
        self.rows.saturating_sub(self.padded_rows) as f64 / self.wall_secs.max(1e-9)
    }

    /// Fraction of executed rows that carried a real request.
    pub fn occupancy(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        1.0 - self.padded_rows as f64 / self.rows.max(1) as f64
    }

    /// Fold another shard's stats into this one: counters sum, histograms
    /// merge bucket-wise, and wall-clock is the longest shard's (shards
    /// run concurrently, so summing would overstate the serving window).
    pub fn merge(&mut self, other: &ServeStats) {
        self.latency.merge(&other.latency);
        self.queue_wait.merge(&other.queue_wait);
        self.batches += other.batches;
        self.rows += other.rows;
        self.padded_rows += other.padded_rows;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.native_fills += other.native_fills;
        self.recon_flops += other.recon_flops;
        self.errors += other.errors;
        self.rejected += other.rejected;
        self.wakeups += other.wakeups;
        self.restarts += other.restarts;
        self.deadline_shed += other.deadline_shed;
        self.batch_panics += other.batch_panics;
        self.breaker_opens += other.breaker_opens;
        self.breaker_fastfail += other.breaker_fastfail;
        self.retries += other.retries;
        self.wall_secs = self.wall_secs.max(other.wall_secs);
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;

    // Histogram unit tests (bucket semantics, merge, percentile
    // monotonicity) live with the type in `obs::hist`; these cover the
    // `ServeStats` aggregation that stayed behind.

    #[test]
    fn stats_merge_sums_counters_and_merges_histograms() {
        let mut a = ServeStats::default();
        a.latency.record(Duration::from_micros(100));
        a.queue_wait.record(Duration::from_micros(10));
        a.batches = 2;
        a.rows = 32;
        a.padded_rows = 3;
        a.cache_hits = 5;
        a.cache_misses = 1;
        a.errors = 1;
        a.wakeups = 10;
        a.wall_secs = 1.5;
        let mut b = ServeStats::default();
        b.latency.record(Duration::from_micros(200));
        b.latency.record(Duration::from_micros(300));
        b.batches = 1;
        b.rows = 16;
        b.cache_misses = 2;
        b.rejected = 4;
        b.recon_flops = 7;
        b.restarts = 2;
        b.deadline_shed = 3;
        b.batch_panics = 1;
        b.breaker_opens = 1;
        b.breaker_fastfail = 6;
        b.retries = 5;
        b.wall_secs = 2.0;
        a.merge(&b);
        assert_eq!(a.latency.count(), 3);
        assert_eq!(a.queue_wait.count(), 1);
        assert_eq!(a.batches, 3);
        assert_eq!(a.rows, 48);
        assert_eq!(a.padded_rows, 3);
        assert_eq!(a.cache_hits, 5);
        assert_eq!(a.cache_misses, 3);
        assert_eq!(a.errors, 1);
        assert_eq!(a.rejected, 4);
        assert_eq!(a.wakeups, 10);
        assert_eq!(a.recon_flops, 7);
        assert_eq!(a.restarts, 2);
        assert_eq!(a.deadline_shed, 3);
        assert_eq!(a.batch_panics, 1);
        assert_eq!(a.breaker_opens, 1);
        assert_eq!(a.breaker_fastfail, 6);
        assert_eq!(a.retries, 5);
        // concurrent shards: wall-clock is the max, not the sum
        assert!((a.wall_secs - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_throughput() {
        let mut s = ServeStats::default();
        s.rows = 110;
        s.padded_rows = 10;
        s.wall_secs = 2.0;
        s.batches = 10;
        assert!((s.throughput() - 50.0).abs() < 1e-9);
        assert!((s.occupancy() - 10.0 / 11.0).abs() < 1e-9);
    }
}
