//! Deterministic workload generation for serving benchmarks: Poisson
//! (open-loop) arrivals with Zipf task popularity — the standard model for
//! multi-tenant adapter serving (few hot tasks, long cold tail).

use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::prng::{tag, Stream};

/// Zipf sampler over `n` tasks with exponent `s` (s=0 → uniform).
#[derive(Debug, Clone)]
pub struct Zipf {
    cum: Vec<f64>,
}

impl Zipf {
    /// Build the cumulative distribution for `n` tasks, exponent `s`.
    /// Validates up front — a non-finite exponent (NaN/∞) would poison
    /// the cumulative weights and a zero task count has nothing to draw —
    /// so `sample` can never hit an unordered comparison.
    pub fn try_new(n: usize, s: f64) -> Result<Zipf> {
        if n == 0 {
            bail!("Zipf over 0 tasks has nothing to sample");
        }
        if !s.is_finite() {
            bail!("Zipf exponent must be finite, got {s}");
        }
        let mut w: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
        let total: f64 = w.iter().sum();
        if !total.is_finite() || total <= 0.0 {
            bail!("Zipf weights degenerate (sum {total}) for n={n}, s={s}");
        }
        let mut acc = 0.0;
        for x in w.iter_mut() {
            acc += *x / total;
            *x = acc;
        }
        Ok(Zipf { cum: w })
    }

    /// `try_new` for known-good parameters; panics with the validation
    /// message on bad input (callers with operator-supplied exponents
    /// should use [`Zipf::try_new`]).
    pub fn new(n: usize, s: f64) -> Zipf {
        Zipf::try_new(n, s).expect("invalid Zipf parameters")
    }

    /// Draw one task id from the distribution.
    pub fn sample(&self, s: &mut Stream) -> usize {
        let u = s.next_unit_f32() as f64;
        // total order: cum is finite by construction, u is finite
        match self.cum.binary_search_by(|p| p.total_cmp(&u)) {
            Ok(i) | Err(i) => i.min(self.cum.len() - 1),
        }
    }
}

/// One scheduled arrival.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Offset from the start of the replay.
    pub at: Duration,
    /// Which task the request targets.
    pub task: usize,
}

/// Open-loop Poisson arrival schedule: `rate_hz` requests/sec over
/// `duration`, tasks Zipf(s)-distributed. Fully deterministic in `seed`.
pub fn open_loop(seed: u64, rate_hz: f64, duration: Duration, n_tasks: usize, zipf_s: f64) -> Vec<Arrival> {
    let mut s = Stream::sub(seed, tag::DATA + 0xA331);
    let zipf = Zipf::new(n_tasks, zipf_s);
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        // exponential inter-arrival
        let u = (s.next_unit_f32() as f64).max(1e-9);
        t += -u.ln() / rate_hz;
        if t >= duration.as_secs_f64() {
            break;
        }
        out.push(Arrival { at: Duration::from_secs_f64(t), task: zipf.sample(&mut s) });
    }
    out
}

/// Deterministic token sequence for a request (from the task's Markov LM).
pub fn request_tokens(lm: &crate::data::MarkovLm, seed: u64, id: u64) -> Vec<i32> {
    use crate::data::{Dataset, Split};
    let (x, _) = lm.batch(Split::Val, seed ^ id, 1);
    x.i32s().unwrap().to_vec()
}

/// What came back from replaying one schedule (`replay`).
#[derive(Debug, Default)]
pub struct ReplayReport {
    /// One entry per *answered* request, in submission order (dropped or
    /// timed-out receivers leave no entry, so don't index this against
    /// the schedule — match on `Response.id`).
    pub responses: Vec<crate::coordinator::server::Response>,
    /// Requests that came back with a prediction.
    pub ok: usize,
    /// Requests bounced at admission (backpressure).
    pub rejected: usize,
    /// Requests answered with an execution/validation error.
    pub failed: usize,
    /// Requests shed because their deadline passed before execution.
    pub deadline_exceeded: usize,
    /// Receivers that closed without any Response (a dead shard).
    pub dropped: usize,
    /// Receivers still pending after the collection timeout (shard alive
    /// but backlogged; the late Response is discarded).
    pub timed_out: usize,
}

/// Replay `schedule` against a running server open-loop: sleep to each
/// arrival time, submit, then collect every response. This is the shared
/// driver of the serve CLI, the adapter_server example and the Table-4
/// bench, so all three exercise the coordinator identically. Stragglers
/// are waited on for the server's [`collect_timeout`] — the configured
/// request deadline plus a margin, or 120s without one.
///
/// [`collect_timeout`]: crate::coordinator::server::Server::collect_timeout
pub fn replay(
    server: &crate::coordinator::server::Server,
    lm: &crate::data::MarkovLm,
    token_seed: u64,
    schedule: &[Arrival],
) -> ReplayReport {
    replay_with(server, lm, token_seed, schedule, server.collect_timeout())
}

/// [`replay`] with an explicit per-response collection timeout.
pub fn replay_with(
    server: &crate::coordinator::server::Server,
    lm: &crate::data::MarkovLm,
    token_seed: u64,
    schedule: &[Arrival],
    collect_timeout: Duration,
) -> ReplayReport {
    use crate::coordinator::server::ServeError;
    let started = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(schedule.len());
    for (i, arr) in schedule.iter().enumerate() {
        if let Some(wait) = arr.at.checked_sub(started.elapsed()) {
            std::thread::sleep(wait);
        }
        rxs.push(server.submit(arr.task, request_tokens(lm, token_seed, i as u64)));
    }
    let mut rep = ReplayReport::default();
    for rx in rxs {
        match rx.recv_timeout(collect_timeout) {
            Ok(resp) => {
                match &resp.result {
                    Ok(_) => rep.ok += 1,
                    Err(ServeError::Rejected(_)) => rep.rejected += 1,
                    Err(ServeError::Failed(_)) => rep.failed += 1,
                    Err(ServeError::DeadlineExceeded) => rep.deadline_exceeded += 1,
                }
                rep.responses.push(resp);
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => rep.timed_out += 1,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => rep.dropped += 1,
        }
    }
    rep
}

/// What came back from a socket replay (`replay_socket`): the remote-
/// client mirror of [`ReplayReport`], with client-measured end-to-end
/// latency (submit write → reply decode) instead of in-process channel
/// latency.
#[derive(Debug, Default)]
pub struct SocketReport {
    /// Requests written to sockets.
    pub sent: usize,
    /// Requests answered with a prediction.
    pub ok: usize,
    /// Requests bounced at admission (backpressure / open breaker),
    /// delivered as `ERR_REJECTED` protocol replies.
    pub rejected: usize,
    /// Requests answered with `ERR_FAILED` (unknown task, dead shard,
    /// execution error).
    pub failed: usize,
    /// Requests shed past their deadline (`ERR_DEADLINE`).
    pub deadline_exceeded: usize,
    /// Fatal connection-level errors observed (`ConnErr` frames or corrupt
    /// reply streams); each ends its connection's collection early.
    pub conn_errors: usize,
    /// Requests sent but never answered before the collection timeout.
    pub missing: usize,
    /// Client-side end-to-end latency of every answered request.
    pub latency: crate::obs::Histogram,
}

impl SocketReport {
    /// Fold another connection's report into this one.
    pub fn merge(&mut self, other: &SocketReport) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.rejected += other.rejected;
        self.failed += other.failed;
        self.deadline_exceeded += other.deadline_exceeded;
        self.conn_errors += other.conn_errors;
        self.missing += other.missing;
        self.latency.merge(&other.latency);
    }

    /// Requests that got any per-request reply.
    pub fn answered(&self) -> usize {
        self.ok + self.rejected + self.failed + self.deadline_exceeded
    }
}

/// Replay `schedule` against a **remote** server over `conns` concurrent
/// MCNP1 connections — the socket mirror of [`replay`], and the driver
/// behind `mcnc replay --connect` and table4's C-connections sweep.
///
/// Arrival `i` goes to connection `i % conns` with its global index as the
/// wire id; all connections share one epoch so the open-loop clock matches
/// the in-process replay. Each connection writes requests from its own
/// thread while a paired reader thread deframes replies and records
/// client-measured latency; after its last request the sender half-closes
/// (`shutdown(Write)`), which the listener answers by finishing every
/// in-flight request before dropping the connection. `deadline` is sent on
/// the wire per request (`None` = no deadline); `collect_timeout` bounds
/// how long each reader waits for stragglers.
pub fn replay_socket(
    addr: &str,
    lm: &crate::data::MarkovLm,
    token_seed: u64,
    schedule: &[Arrival],
    conns: usize,
    deadline: Option<Duration>,
    collect_timeout: Duration,
) -> Result<SocketReport> {
    let conns = conns.max(1);
    let mut per_conn: Vec<Vec<(Duration, usize, u64, Vec<i32>)>> = vec![Vec::new(); conns];
    for (i, arr) in schedule.iter().enumerate() {
        per_conn[i % conns].push((
            arr.at,
            arr.task,
            i as u64,
            request_tokens(lm, token_seed, i as u64),
        ));
    }
    let deadline_us = deadline.map(|d| d.as_micros() as u64).unwrap_or(0);
    let epoch = std::time::Instant::now();
    let reports = std::thread::scope(|scope| {
        let handles: Vec<_> = per_conn
            .iter()
            .map(|reqs| {
                scope.spawn(move || run_conn(addr, reqs, deadline_us, collect_timeout, epoch))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => bail!("socket replay connection thread panicked"),
            })
            .collect::<Result<Vec<SocketReport>>>()
    })?;
    let mut total = SocketReport::default();
    for r in &reports {
        total.merge(r);
    }
    Ok(total)
}

/// One connection's worth of [`replay_socket`]: connect, preamble, write
/// requests open-loop, half-close, join the reader.
fn run_conn(
    addr: &str,
    reqs: &[(Duration, usize, u64, Vec<i32>)],
    deadline_us: u64,
    collect_timeout: Duration,
    epoch: std::time::Instant,
) -> Result<SocketReport> {
    use std::io::Write as _;

    use crate::net::protocol::{self, Msg};

    let mut rep = SocketReport::default();
    if reqs.is_empty() {
        return Ok(rep);
    }
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting {addr}: {e}"))?;
    let _ = stream.set_nodelay(true);
    stream.write_all(protocol::NET_MAGIC)?;
    let sent_at: std::sync::Arc<std::sync::Mutex<std::collections::HashMap<u64, std::time::Instant>>> =
        Default::default();
    let reader_stream = stream.try_clone()?;
    let reader_sent = std::sync::Arc::clone(&sent_at);
    let expect = reqs.len();
    let reader =
        std::thread::spawn(move || read_replies(reader_stream, expect, collect_timeout, reader_sent));
    for (at, task, wire, tokens) in reqs {
        if let Some(wait) = at.checked_sub(epoch.elapsed()) {
            std::thread::sleep(wait);
        }
        let frame = protocol::encode_frame(&Msg::Req {
            id: *wire,
            task: *task as u64,
            tokens: tokens.clone(),
            deadline_us,
        });
        // record before the write so a fast reply can't race the insert
        if let Ok(mut g) = sent_at.lock() {
            g.insert(*wire, std::time::Instant::now());
        }
        stream.write_all(&frame)?;
        rep.sent += 1;
    }
    // half-close: tell the server we are done sending; it finishes every
    // in-flight request, flushes, and closes its side
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let got = reader.join().unwrap_or_default();
    rep.merge(&got);
    rep.missing = rep.sent.saturating_sub(rep.answered());
    Ok(rep)
}

/// Reader half of one replay connection: deframe replies until `expect`
/// per-request answers arrived, the stream ended, or `timeout` passed with
/// nothing to read.
fn read_replies(
    mut stream: std::net::TcpStream,
    expect: usize,
    timeout: Duration,
    sent_at: std::sync::Arc<std::sync::Mutex<std::collections::HashMap<u64, std::time::Instant>>>,
) -> SocketReport {
    use std::io::{ErrorKind, Read as _};

    use crate::net::protocol::{Deframer, Msg, ERR_DEADLINE, ERR_FAILED, ERR_REJECTED};

    let mut rep = SocketReport::default();
    let _ = stream.set_read_timeout(Some(timeout.max(Duration::from_millis(1))));
    let mut de = Deframer::new();
    let mut buf = [0u8; 16 * 1024];
    let mut got = 0usize;
    'read: while got < expect {
        let n = match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break,
        };
        de.push(&buf[..n]);
        loop {
            match de.next() {
                Ok(Some(msg)) => {
                    let id = match &msg {
                        Msg::ReplyOk { id, .. } => {
                            rep.ok += 1;
                            Some(*id)
                        }
                        Msg::ReplyErr { id, code, .. } => {
                            match *code {
                                ERR_REJECTED => rep.rejected += 1,
                                ERR_FAILED => rep.failed += 1,
                                ERR_DEADLINE => rep.deadline_exceeded += 1,
                                // decode_body validated the code; count
                                // anything else defensively as failed
                                _ => rep.failed += 1,
                            }
                            Some(*id)
                        }
                        Msg::ConnErr { .. } => {
                            rep.conn_errors += 1;
                            break 'read;
                        }
                        _ => None, // Pong / echoed requests: not replies
                    };
                    if let Some(id) = id {
                        got += 1;
                        if let Some(t) = sent_at.lock().ok().and_then(|mut g| g.remove(&id)) {
                            rep.latency.record(t.elapsed());
                        }
                    }
                }
                Ok(None) => break,
                Err(_) => {
                    rep.conn_errors += 1;
                    break 'read;
                }
            }
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn socket_report_merge_sums_and_merges_latency() {
        let mut a = SocketReport::default();
        a.sent = 4;
        a.ok = 3;
        a.rejected = 1;
        a.latency.record(Duration::from_micros(100));
        let mut b = SocketReport::default();
        b.sent = 2;
        b.failed = 1;
        b.deadline_exceeded = 1;
        b.conn_errors = 1;
        b.missing = 0;
        b.latency.record(Duration::from_micros(200));
        a.merge(&b);
        assert_eq!(a.sent, 6);
        assert_eq!(a.answered(), 6);
        assert_eq!(a.latency.count(), 2);
        assert_eq!(a.conn_errors, 1);
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let z = Zipf::new(16, 1.2);
        let mut s = Stream::new(1);
        let mut counts = vec![0usize; 16];
        for _ in 0..10_000 {
            counts[z.sample(&mut s)] += 1;
        }
        assert!(counts[0] > counts[8] * 3, "{counts:?}");
        assert!(counts[0] > counts[15] * 5);
    }

    #[test]
    fn zipf_zero_is_uniformish() {
        let z = Zipf::new(8, 0.0);
        let mut s = Stream::new(2);
        let mut counts = vec![0usize; 8];
        for _ in 0..8_000 {
            counts[z.sample(&mut s)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn zipf_rejects_degenerate_parameters() {
        assert!(Zipf::try_new(0, 1.0).is_err(), "no tasks");
        assert!(Zipf::try_new(8, f64::NAN).is_err(), "NaN exponent");
        assert!(Zipf::try_new(8, f64::INFINITY).is_err(), "infinite exponent");
        assert!(Zipf::try_new(8, 1.0).is_ok());
    }

    #[test]
    fn open_loop_rate_and_determinism() {
        let a = open_loop(3, 1000.0, Duration::from_secs(1), 4, 1.0);
        let b = open_loop(3, 1000.0, Duration::from_secs(1), 4, 1.0);
        assert_eq!(a.len(), b.len());
        assert!((a.len() as f64 - 1000.0).abs() < 150.0, "{} arrivals", a.len());
        // sorted in time
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(a.iter().all(|x| x.task < 4));
    }

    #[test]
    fn request_tokens_deterministic() {
        let lm = crate::data::MarkovLm::base(1, 32, 16);
        let a = request_tokens(&lm, 5, 10);
        let b = request_tokens(&lm, 5, 10);
        let c = request_tokens(&lm, 5, 11);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 16);
    }
}
