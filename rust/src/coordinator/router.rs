//! Request router + dynamic batcher (pure data structures; the engine
//! thread drives them). Requests for different tasks can never share a
//! batch — their adapters differ — which is exactly why reconstruction
//! speed matters for multi-task serving (the paper's Table-4 argument).

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

/// One inference request (LM serving: a token sequence).
#[derive(Debug, Clone)]
pub struct Request {
    /// Server-assigned id; the matching `Response` echoes it.
    pub id: u64,
    /// Adapter task the request targets.
    pub task: usize,
    /// Input token sequence (must match the executable's length).
    pub tokens: Vec<i32>,
    /// When the request was admitted (queue-wait accounting).
    pub enqueued: Instant,
    /// Latest instant the request is still worth executing; past it the
    /// batcher sheds the request (`ServeError::DeadlineExceeded`) instead
    /// of packing it. `None` = no deadline.
    pub deadline: Option<Instant>,
}

impl Request {
    /// The request's trace id: the server-assigned `id` minted at
    /// `submit`/`submit_with` doubles as the id every obs span for this
    /// request is recorded under (`MCNC_TRACE` sampling keys off it).
    pub fn trace_id(&self) -> u64 {
        self.id
    }

    /// Whether the request's deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }
}

/// A single-task group of requests ready to execute together.
#[derive(Debug)]
pub struct Batch {
    /// The task every request in the batch belongs to.
    pub task: usize,
    /// The batched requests, FIFO within the task.
    pub requests: Vec<Request>,
}

impl Batch {
    /// Trace id the batch's execution spans are recorded under: the first
    /// request's id (FIFO head — the request that waited longest and thus
    /// triggered the flush), or 0 for an empty batch. Per-request queue
    /// spans keep their own ids; only batch-granular work (engine run,
    /// cache fill, GEMM) shares this one.
    pub fn trace_id(&self) -> u64 {
        self.requests.first().map_or(0, |r| r.id)
    }
}

/// When the batcher flushes a task queue.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard upper bound = the predict executable's compiled batch size.
    pub max_batch: usize,
    /// Flush a non-full batch once its oldest request waited this long.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(5) }
    }
}

/// Per-task FIFO queues + the dynamic batcher over them.
#[derive(Debug, Default)]
pub struct Router {
    queues: HashMap<usize, VecDeque<Request>>,
    /// Round-robin cursor over task ids for fairness.
    rr: Vec<usize>,
    rr_pos: usize,
    /// Requests ever pushed.
    pub enqueued: u64,
    /// Requests ever handed out in batches.
    pub dispatched: u64,
    /// Requests swept out by `sweep_expired`, awaiting `take_expired`.
    expired: Vec<Request>,
}

impl Router {
    /// Queue a request on its task's FIFO.
    pub fn push(&mut self, req: Request) {
        if !self.queues.contains_key(&req.task) {
            self.rr.push(req.task);
        }
        self.queues.entry(req.task).or_default().push_back(req);
        self.enqueued += 1;
    }

    /// Requests queued and not yet batched.
    pub fn pending(&self) -> usize {
        self.queues.values().map(VecDeque::len).sum()
    }

    /// Whether nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.pending() == 0
    }

    /// Age of the oldest queued request.
    pub fn oldest_wait(&self, now: Instant) -> Option<Duration> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|r| now.duration_since(r.enqueued))
            .max()
    }

    /// Earliest instant at which a queued partial batch must flush under
    /// `policy`; `None` when nothing is queued. Full batches dispatch
    /// immediately via `next_batch`, so after a dispatch sweep this is
    /// exactly how long the engine loop may sleep without missing a
    /// deadline (the shard loop caps it with a coarse heartbeat).
    pub fn next_deadline(&self, policy: BatchPolicy) -> Option<Instant> {
        let flush = self
            .queues
            .values()
            .filter_map(|q| q.front())
            .map(|r| r.enqueued + policy.max_delay)
            .min();
        // A queued request's own deadline also bounds the sleep: the loop
        // must wake in time to shed it (else a lone expired request would
        // sit unanswered until the next heartbeat).
        let shed = self
            .queues
            .values()
            .flat_map(|q| q.iter())
            .filter_map(|r| r.deadline)
            .min();
        match (flush, shed) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Move every queued request whose deadline has passed at `now` into
    /// the expired buffer (collect with `take_expired`). FIFO order within
    /// each task is preserved for the survivors.
    pub fn sweep_expired(&mut self, now: Instant) {
        for q in self.queues.values_mut() {
            if q.iter().any(|r| r.expired(now)) {
                for r in std::mem::take(q) {
                    if r.expired(now) {
                        self.expired.push(r);
                    } else {
                        q.push_back(r);
                    }
                }
            }
        }
    }

    /// Drain the requests shed by `sweep_expired` so the shard loop can
    /// answer them with `DeadlineExceeded`.
    pub fn take_expired(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.expired)
    }

    /// Pop the next ready batch under `policy`, scanning tasks round-robin
    /// from the fairness cursor. `drain` forces flushing partial batches.
    /// Expired requests are swept out first and never packed — collect
    /// them with `take_expired`.
    pub fn next_batch(&mut self, policy: BatchPolicy, now: Instant, drain: bool) -> Option<Batch> {
        self.sweep_expired(now);
        let n = self.rr.len();
        for step in 0..n {
            let task = self.rr[(self.rr_pos + step) % n];
            // single get_mut: no second lookup whose miss would need an
            // unwrap after the readiness check above it already passed
            let Some(q) = self.queues.get_mut(&task) else {
                continue;
            };
            if q.is_empty() {
                continue;
            }
            let ready = q.len() >= policy.max_batch
                || drain
                || q.front()
                    .map(|r| now.duration_since(r.enqueued) >= policy.max_delay)
                    .unwrap_or(false);
            if !ready {
                continue;
            }
            let take = q.len().min(policy.max_batch);
            let requests: Vec<Request> = q.drain(..take).collect();
            self.rr_pos = (self.rr_pos + step + 1) % n;
            self.dispatched += requests.len() as u64;
            return Some(Batch { task, requests });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    fn req(id: u64, task: usize, at: Instant) -> Request {
        Request { id, task, tokens: vec![0; 4], enqueued: at, deadline: None }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut r = Router::default();
        let now = Instant::now();
        for i in 0..20 {
            r.push(req(i, 1, now));
        }
        let p = BatchPolicy { max_batch: 16, max_delay: Duration::from_secs(10) };
        let b = r.next_batch(p, now, false).unwrap();
        assert_eq!(b.requests.len(), 16);
        assert_eq!(b.task, 1);
        // remaining 4 wait (not timed out, not full)
        assert!(r.next_batch(p, now, false).is_none());
        assert_eq!(r.pending(), 4);
    }

    #[test]
    fn deadline_flushes_partial() {
        let mut r = Router::default();
        let t0 = Instant::now();
        r.push(req(0, 2, t0));
        let p = BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(5) };
        assert!(r.next_batch(p, t0, false).is_none());
        let later = t0 + Duration::from_millis(6);
        let b = r.next_batch(p, later, false).unwrap();
        assert_eq!(b.requests.len(), 1);
    }

    #[test]
    fn drain_flushes_everything() {
        let mut r = Router::default();
        let now = Instant::now();
        r.push(req(0, 1, now));
        r.push(req(1, 2, now));
        let p = BatchPolicy::default();
        let mut seen = 0;
        while let Some(b) = r.next_batch(p, now, true) {
            seen += b.requests.len();
        }
        assert_eq!(seen, 2);
        assert!(r.is_empty());
    }

    #[test]
    fn round_robin_fairness() {
        let mut r = Router::default();
        let now = Instant::now();
        for i in 0..64 {
            r.push(req(i, (i % 2) as usize, now));
        }
        let p = BatchPolicy { max_batch: 16, max_delay: Duration::ZERO };
        let b1 = r.next_batch(p, now, false).unwrap();
        let b2 = r.next_batch(p, now, false).unwrap();
        assert_ne!(b1.task, b2.task, "consecutive batches must alternate tasks");
    }

    #[test]
    fn next_deadline_tracks_oldest_head() {
        let mut r = Router::default();
        let p = BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(5) };
        assert!(r.next_deadline(p).is_none(), "empty router has no deadline");
        let t0 = Instant::now();
        r.push(req(0, 1, t0 + Duration::from_millis(3)));
        r.push(req(1, 2, t0)); // older head on another task queue
        assert_eq!(r.next_deadline(p), Some(t0 + Duration::from_millis(5)));
        // draining the older queue moves the deadline to the younger head
        let b = r.next_batch(p, t0 + Duration::from_millis(6), false).unwrap();
        assert_eq!(b.task, 2);
        assert_eq!(r.next_deadline(p), Some(t0 + Duration::from_millis(8)));
    }

    #[test]
    fn expired_requests_never_packed() {
        let mut r = Router::default();
        let t0 = Instant::now();
        let mut a = req(0, 1, t0);
        a.deadline = Some(t0 + Duration::from_millis(2));
        r.push(a);
        r.push(req(1, 1, t0)); // no deadline, survives
        let p = BatchPolicy { max_batch: 16, max_delay: Duration::ZERO };
        let later = t0 + Duration::from_millis(3);
        let b = r.next_batch(p, later, true).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert_eq!(b.requests[0].id, 1);
        let shed = r.take_expired();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 0);
        assert!(r.take_expired().is_empty(), "take_expired drains");
    }

    #[test]
    fn sweep_preserves_fifo_among_survivors() {
        let mut r = Router::default();
        let t0 = Instant::now();
        for i in 0..6u64 {
            let mut q = req(i, 1, t0);
            if i % 2 == 0 {
                q.deadline = Some(t0); // already expired
            }
            r.push(q);
        }
        r.sweep_expired(t0 + Duration::from_millis(1));
        let p = BatchPolicy { max_batch: 16, max_delay: Duration::ZERO };
        let b = r.next_batch(p, t0 + Duration::from_millis(1), true).unwrap();
        let ids: Vec<u64> = b.requests.iter().map(|q| q.id).collect();
        assert_eq!(ids, vec![1, 3, 5]);
        assert_eq!(r.take_expired().len(), 3);
    }

    #[test]
    fn next_deadline_considers_request_deadlines() {
        let mut r = Router::default();
        let p = BatchPolicy { max_batch: 16, max_delay: Duration::from_millis(50) };
        let t0 = Instant::now();
        let mut a = req(0, 1, t0);
        a.deadline = Some(t0 + Duration::from_millis(10));
        r.push(a);
        // request deadline (t0+10ms) beats the flush deadline (t0+50ms)
        assert_eq!(r.next_deadline(p), Some(t0 + Duration::from_millis(10)));
    }

    #[test]
    fn router_invariants_property() {
        run_prop("router_exactly_once", 100, |g| {
            let mut r = Router::default();
            let now = Instant::now();
            let n = g.usize(1, 200);
            let tasks = g.usize(1, 8);
            for i in 0..n {
                r.push(req(i as u64, g.usize(0, tasks - 1), now));
            }
            let p = BatchPolicy { max_batch: g.usize(1, 32), max_delay: Duration::ZERO };
            let mut ids = std::collections::HashSet::new();
            while let Some(b) = r.next_batch(p, now, true) {
                prop_assert!(b.requests.len() <= p.max_batch, "batch too big");
                prop_assert!(
                    b.requests.iter().all(|q| q.task == b.task),
                    "mixed-task batch"
                );
                // FIFO within task
                for w in b.requests.windows(2) {
                    prop_assert!(w[0].id < w[1].id, "FIFO violated within batch");
                }
                for q in &b.requests {
                    prop_assert!(ids.insert(q.id), "request {} dispatched twice", q.id);
                }
            }
            prop_assert!(ids.len() == n, "dispatched {} of {n}", ids.len());
            prop_assert!(r.is_empty(), "requests left behind");
            Ok(())
        });
    }
}
