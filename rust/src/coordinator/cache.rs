//! Byte-bounded LRU cache — the adapter cache of the serving engine
//! ("merged" mode caches reconstructed full weights per task; the cap makes
//! the memory/recompute trade-off of Table 4's discussion explicit).
//!
//! The cache keeps no metrics of its own (it is a pure data structure; the
//! `evictions` counter and `used_bytes`/`len` accessors are its only
//! accounting). The serving engine mirrors them into the obs registry —
//! `mcnc_cache_{hits,misses,evictions}_total{shard}` and the
//! `mcnc_cache_used_bytes`/`mcnc_cache_entries` gauges — at its put/get
//! call sites, so hit/miss semantics stay where they are decided.

use std::collections::HashMap;
use std::hash::Hash;

/// How many bytes a cache entry accounts for against the capacity.
pub trait Weigh {
    /// Payload size in bytes.
    fn weight(&self) -> usize;
}

impl Weigh for crate::tensor::Tensor {
    fn weight(&self) -> usize {
        self.size_bytes()
    }
}

impl<T: Weigh> Weigh for Vec<T> {
    fn weight(&self) -> usize {
        self.iter().map(Weigh::weight).sum()
    }
}

/// Shared-ref entries (the serving engine caches `Arc<Vec<Tensor>>` so a
/// hit never deep-copies θ): weight is the *inner* value's bytes, not the
/// size of the `Arc` handle — the cache bounds payload memory.
impl<T: Weigh + ?Sized> Weigh for std::sync::Arc<T> {
    fn weight(&self) -> usize {
        (**self).weight()
    }
}

/// Byte-capacity-bounded LRU map: inserts evict least-recently-used
/// entries until the new value fits (oversized values are rejected
/// outright rather than flushing the whole cache).
pub struct LruCache<K: Eq + Hash + Clone, V: Weigh> {
    capacity_bytes: usize,
    used_bytes: usize,
    tick: u64,
    map: HashMap<K, (V, u64)>,
    /// `get` calls that found their key.
    pub hits: u64,
    /// `get` calls that missed.
    pub misses: u64,
    /// Entries pushed out by capacity pressure (`remove` not included).
    pub evictions: u64,
}

impl<K: Eq + Hash + Clone, V: Weigh> LruCache<K, V> {
    /// An empty cache bounded to `capacity_bytes` of payload.
    pub fn new(capacity_bytes: usize) -> Self {
        LruCache {
            capacity_bytes,
            used_bytes: 0,
            tick: 0,
            map: HashMap::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look up `k`, marking it most-recently-used on a hit.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(k) {
            Some((v, t)) => {
                *t = tick;
                self.hits += 1;
                Some(&*v)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) `k`, evicting LRU entries until `v` fits; a
    /// value bigger than the whole capacity is dropped silently.
    pub fn put(&mut self, k: K, v: V) {
        let w = v.weight();
        if w > self.capacity_bytes {
            return; // would never fit; don't thrash the rest out
        }
        if let Some((old, _)) = self.map.remove(&k) {
            self.used_bytes -= old.weight();
        }
        while self.used_bytes + w > self.capacity_bytes {
            // evict least-recently-used
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| k.clone());
            match victim {
                Some(vk) => {
                    if let Some((old, _)) = self.map.remove(&vk) {
                        self.used_bytes -= old.weight();
                        self.evictions += 1;
                    }
                }
                None => break,
            }
        }
        self.tick += 1;
        self.used_bytes += w;
        self.map.insert(k, (v, self.tick));
    }

    /// Drop an entry (e.g. when a task's adapter is reinstalled and the
    /// cached merged θ goes stale). Not counted as an eviction.
    pub fn remove(&mut self, k: &K) -> Option<V> {
        match self.map.remove(k) {
            Some((v, _)) => {
                self.used_bytes -= v.weight();
                Some(v)
            }
            None => None,
        }
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache holds nothing.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total payload bytes currently cached.
    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    /// Whether `k` is cached (without touching recency).
    pub fn contains(&self, k: &K) -> bool {
        self.map.contains_key(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    #[derive(Clone, Debug, PartialEq)]
    struct Blob(usize);

    impl Weigh for Blob {
        fn weight(&self) -> usize {
            self.0
        }
    }

    #[test]
    fn hit_miss_counting() {
        let mut c: LruCache<u32, Blob> = LruCache::new(100);
        assert!(c.get(&1).is_none());
        c.put(1, Blob(10));
        assert_eq!(c.get(&1), Some(&Blob(10)));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn evicts_lru_not_mru() {
        let mut c: LruCache<u32, Blob> = LruCache::new(30);
        c.put(1, Blob(10));
        c.put(2, Blob(10));
        c.put(3, Blob(10));
        let _ = c.get(&1); // 1 is now MRU
        c.put(4, Blob(10)); // must evict 2 (LRU)
        assert!(c.contains(&1));
        assert!(!c.contains(&2));
        assert!(c.contains(&3) && c.contains(&4));
        assert_eq!(c.evictions, 1);
    }

    #[test]
    fn oversized_value_rejected() {
        let mut c: LruCache<u32, Blob> = LruCache::new(10);
        c.put(1, Blob(5));
        c.put(2, Blob(100));
        assert!(c.contains(&1));
        assert!(!c.contains(&2));
    }

    #[test]
    fn replace_updates_bytes() {
        let mut c: LruCache<u32, Blob> = LruCache::new(100);
        c.put(1, Blob(40));
        c.put(1, Blob(10));
        assert_eq!(c.used_bytes(), 10);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn remove_updates_bytes() {
        let mut c: LruCache<u32, Blob> = LruCache::new(100);
        c.put(1, Blob(40));
        c.put(2, Blob(10));
        assert_eq!(c.remove(&1), Some(Blob(40)));
        assert_eq!(c.remove(&1), None);
        assert_eq!(c.used_bytes(), 10);
        assert_eq!(c.len(), 1);
        assert_eq!(c.evictions, 0, "remove is not an eviction");
    }

    #[test]
    fn arc_entries_weigh_inner_bytes() {
        use std::sync::Arc;
        // 3 × 10-byte payloads fit a 30-byte cap exactly; if Arc weighed
        // as a pointer (or as 0), a 4th insert would not evict
        let mut c: LruCache<u32, Arc<Blob>> = LruCache::new(30);
        c.put(1, Arc::new(Blob(10)));
        c.put(2, Arc::new(Blob(10)));
        c.put(3, Arc::new(Blob(10)));
        assert_eq!(c.used_bytes(), 30);
        let held = c.get(&1).map(Arc::clone).unwrap(); // 1 is now MRU
        c.put(4, Arc::new(Blob(10))); // must evict 2 (LRU)
        assert!(c.contains(&1));
        assert!(!c.contains(&2));
        assert!(c.contains(&3) && c.contains(&4));
        assert_eq!(c.evictions, 1);
        assert_eq!(c.used_bytes(), 30);
        // an outstanding shared ref does not distort the accounting
        assert_eq!(held.weight(), 10);
        // oversized payload still rejected by inner weight
        c.put(5, Arc::new(Blob(31)));
        assert!(!c.contains(&5));
    }

    #[test]
    fn arc_vec_weighs_payload_sum() {
        use std::sync::Arc;
        let v = Arc::new(vec![Blob(3), Blob(4)]);
        assert_eq!(v.weight(), 7);
    }

    #[test]
    fn capacity_invariant_property() {
        run_prop("lru_capacity", 100, |g| {
            let cap = g.usize(1, 200);
            let mut c: LruCache<usize, Blob> = LruCache::new(cap);
            for _ in 0..50 {
                if g.bool() {
                    c.put(g.usize(0, 10), Blob(g.usize(1, 50)));
                } else {
                    let _ = c.get(&g.usize(0, 10));
                }
                prop_assert!(c.used_bytes() <= cap, "over capacity");
                let real: usize = c.map.values().map(|(v, _)| v.weight()).sum();
                prop_assert!(real == c.used_bytes(), "byte accounting drift");
            }
            Ok(())
        });
    }
}
