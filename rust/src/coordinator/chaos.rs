//! Deterministic chaos injection for the serving coordinator.
//!
//! A [`Chaos`] harness owns a *precomputed fault schedule* generated from
//! a seed via `util::prng`, and wraps any [`EngineCore`] in a
//! [`FaultyEngine`] that consults the schedule on every batch call. All
//! scheduling is indexed by atomic call counters — never wall-clock — so
//! a given `(seed, config)` injects byte-identical fault sequences on
//! every run, and the integration tests can assert exact convergence
//! (restart counters, exactly-one-Response) without flakes.
//!
//! Fault classes, mapped to the recovery layer they exercise:
//!
//! * **batch panic** — `run_batch` panics; the shard loop's containment
//!   must answer the batch `Failed` and keep serving;
//! * **batch error** — `run_batch` returns `Err`; same containment path,
//!   plus circuit-breaker accounting;
//! * **slow batch** — `run_batch` sleeps before delegating; exercises
//!   deadlines and queue growth;
//! * **shard kill** — a panic fired from `has_task` during ingest, which
//!   *escapes* the batch containment and forces a supervisor restart;
//! * **preload failure** — `preload` fails from a bounded budget; the
//!   shard must keep serving cold (and re-warm retries eventually pass);
//! * **factory failure** — [`Chaos::factory_gate`] fails from a bounded
//!   budget inside an engine factory; the supervisor's restart backoff
//!   must absorb it.
//!
//! Budgets and counters live behind one shared [`Chaos`] handle (cheap to
//! clone), so they persist across engine rebuilds — a restarted shard
//! keeps consuming the *same* schedule instead of starting a fresh one.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::metrics::ServeStats;
use crate::coordinator::router::Batch;
use crate::coordinator::shard::EngineCore;
use crate::coordinator::warm::WarmStats;
use crate::util::prng::{tag, Stream};

/// One scheduled batch-call fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fault {
    Panic,
    Error,
    Slow,
}

/// What a [`Chaos`] harness injects, and when. Counts are totals across
/// the whole server (shards share the schedule through the global
/// batch-call counter).
#[derive(Debug, Clone, Copy)]
pub struct ChaosCfg {
    /// Seed for the fault schedule (`util::prng` substream).
    pub seed: u64,
    /// Batch-call window the faults are scattered over; auto-extended to
    /// at least twice the scheduled fault count so the schedule always
    /// fits and a fault-free tail exists for convergence assertions.
    pub window: usize,
    /// Batches that panic inside `run_batch` (contained by the shard loop).
    pub panics: usize,
    /// Batches that return `Err` from `run_batch`.
    pub errors: usize,
    /// Batches delayed by `slow_for` before executing normally.
    pub slows: usize,
    /// Sleep injected into each slow batch.
    pub slow_for: Duration,
    /// Shard kills: panics fired from `has_task` during ingest once the
    /// global batch-call counter crosses scheduled thresholds — these
    /// escape batch containment and force a supervisor restart.
    pub kills: usize,
    /// `preload` calls that fail before delegating (bounded budget).
    pub preload_fails: usize,
    /// [`Chaos::factory_gate`] calls that fail (bounded budget).
    pub factory_fails: usize,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        ChaosCfg {
            seed: 0,
            window: 0,
            panics: 0,
            errors: 0,
            slows: 0,
            slow_for: Duration::from_millis(5),
            kills: 0,
            preload_fails: 0,
            factory_fails: 0,
        }
    }
}

/// Injected-fault totals so far (see [`Chaos::report`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosReport {
    /// Batch panics fired.
    pub panics: usize,
    /// Batch errors fired.
    pub errors: usize,
    /// Slow batches fired.
    pub slows: usize,
    /// Shard kills fired.
    pub kills: usize,
    /// Preload failures fired.
    pub preload_fails: usize,
    /// Factory failures fired.
    pub factory_fails: usize,
}

struct ChaosState {
    /// Fault (or none) per global batch call, index = call number.
    schedule: Vec<Option<Fault>>,
    slow_for: Duration,
    /// Sorted batch-call thresholds at which `has_task` kills the shard.
    kill_at: Vec<usize>,
    next_kill: AtomicUsize,
    batch_calls: AtomicUsize,
    preload_budget: AtomicUsize,
    factory_budget: AtomicUsize,
    panics: AtomicUsize,
    errors: AtomicUsize,
    slows: AtomicUsize,
    kills: AtomicUsize,
    preload_fails: AtomicUsize,
    factory_fails: AtomicUsize,
}

/// Shared handle to one deterministic fault schedule. Clone it into
/// engine factories freely: all clones consume the same counters, so the
/// schedule is global across shards and survives engine restarts.
#[derive(Clone)]
pub struct Chaos(Arc<ChaosState>);

impl Chaos {
    /// Precompute the fault schedule for `cfg`.
    pub fn new(cfg: ChaosCfg) -> Chaos {
        let n_faults = cfg.panics + cfg.errors + cfg.slows;
        let window = cfg.window.max(2 * n_faults).max(1);
        let mut schedule: Vec<Option<Fault>> = vec![None; window];
        let mut s = Stream::sub(cfg.seed, tag::DATA + 0xC405);
        let mut place = |fault: Fault, schedule: &mut Vec<Option<Fault>>| {
            let mut pos = (s.next_u64() as usize) % window;
            // bounded probing: the window is ≥ 2× the fault count, so a
            // free slot is always within one wrap
            for _ in 0..window {
                if schedule[pos].is_none() {
                    schedule[pos] = Some(fault);
                    return;
                }
                pos = (pos + 1) % window;
            }
        };
        for _ in 0..cfg.panics {
            place(Fault::Panic, &mut schedule);
        }
        for _ in 0..cfg.errors {
            place(Fault::Error, &mut schedule);
        }
        for _ in 0..cfg.slows {
            place(Fault::Slow, &mut schedule);
        }
        let mut kill_at: Vec<usize> =
            (0..cfg.kills).map(|_| 1 + (s.next_u64() as usize) % window).collect();
        kill_at.sort_unstable();
        Chaos(Arc::new(ChaosState {
            schedule,
            slow_for: cfg.slow_for,
            kill_at,
            next_kill: AtomicUsize::new(0),
            batch_calls: AtomicUsize::new(0),
            preload_budget: AtomicUsize::new(cfg.preload_fails),
            factory_budget: AtomicUsize::new(cfg.factory_fails),
            panics: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
            slows: AtomicUsize::new(0),
            kills: AtomicUsize::new(0),
            preload_fails: AtomicUsize::new(0),
            factory_fails: AtomicUsize::new(0),
        }))
    }

    /// Wrap an engine so its calls consult this schedule. Call from the
    /// engine factory so every (re)built engine is wrapped.
    pub fn wrap<E: EngineCore>(&self, inner: E) -> FaultyEngine<E> {
        FaultyEngine { inner, chaos: Arc::clone(&self.0) }
    }

    /// Consume one scheduled factory failure, if any remain. Engine
    /// factories under test call this first: `chaos.factory_gate()?`.
    pub fn factory_gate(&self) -> Result<()> {
        if take_budget(&self.0.factory_budget) {
            self.0.factory_fails.fetch_add(1, Ordering::SeqCst);
            bail!("chaos: injected engine factory failure");
        }
        Ok(())
    }

    /// Whether every scheduled batch fault and kill has fired (budgeted
    /// preload/factory failures may remain if nothing drew on them).
    /// After this, traffic must converge back to 100% success.
    pub fn exhausted(&self) -> bool {
        self.0.batch_calls.load(Ordering::SeqCst) >= self.0.schedule.len()
            && self.0.next_kill.load(Ordering::SeqCst) >= self.0.kill_at.len()
    }

    /// Injected-fault totals so far.
    pub fn report(&self) -> ChaosReport {
        ChaosReport {
            panics: self.0.panics.load(Ordering::SeqCst),
            errors: self.0.errors.load(Ordering::SeqCst),
            slows: self.0.slows.load(Ordering::SeqCst),
            kills: self.0.kills.load(Ordering::SeqCst),
            preload_fails: self.0.preload_fails.load(Ordering::SeqCst),
            factory_fails: self.0.factory_fails.load(Ordering::SeqCst),
        }
    }
}

/// Decrement `b` if positive; true when a unit was consumed.
fn take_budget(b: &AtomicUsize) -> bool {
    b.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1)).is_ok()
}

/// Flip one deterministic bit in the second half of `bytes` — frame-CRC
/// corruption for codec-path chaos (the decoder must detect the flip and
/// err, never serve corrupt weights). The second half is targeted so the
/// container header stays intact and the corruption lands in frame data.
pub fn corrupt(bytes: &mut [u8], seed: u64) {
    if bytes.is_empty() {
        return;
    }
    let mut s = Stream::sub(seed, tag::DATA + 0xC0DE);
    let lo = bytes.len() / 2;
    let ix = lo + (s.next_u64() as usize) % (bytes.len() - lo).max(1);
    let bit = (s.next_u64() % 8) as u8;
    bytes[ix.min(bytes.len() - 1)] ^= 1 << bit;
}

/// [`EngineCore`] wrapper that injects the faults scheduled by [`Chaos`].
pub struct FaultyEngine<E> {
    inner: E,
    chaos: Arc<ChaosState>,
}

impl<E> FaultyEngine<E> {
    /// Kill the shard if the batch-call counter crossed the next kill
    /// threshold. Fired from `has_task` — the ingest path, outside the
    /// shard loop's batch containment — so the panic reaches the
    /// supervisor.
    fn maybe_kill(&self) {
        let calls = self.chaos.batch_calls.load(Ordering::SeqCst);
        let k = self.chaos.next_kill.load(Ordering::SeqCst);
        if k < self.chaos.kill_at.len()
            && calls >= self.chaos.kill_at[k]
            && self
                .chaos
                .next_kill
                .compare_exchange(k, k + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
        {
            self.chaos.kills.fetch_add(1, Ordering::SeqCst);
            panic!("chaos: injected shard kill after {calls} batch calls");
        }
    }
}

impl<E: EngineCore> EngineCore for FaultyEngine<E> {
    fn seq(&self) -> usize {
        self.inner.seq()
    }

    fn has_task(&self, task: usize) -> bool {
        self.maybe_kill();
        self.inner.has_task(task)
    }

    fn run_batch(&mut self, batch: &Batch) -> Result<Vec<i32>> {
        let i = self.chaos.batch_calls.fetch_add(1, Ordering::SeqCst);
        match self.chaos.schedule.get(i).copied().flatten() {
            Some(Fault::Panic) => {
                self.chaos.panics.fetch_add(1, Ordering::SeqCst);
                panic!("chaos: injected batch panic at call {i}");
            }
            Some(Fault::Error) => {
                self.chaos.errors.fetch_add(1, Ordering::SeqCst);
                bail!("chaos: injected batch error at call {i}");
            }
            Some(Fault::Slow) => {
                self.chaos.slows.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(self.chaos.slow_for);
                self.inner.run_batch(batch)
            }
            None => self.inner.run_batch(batch),
        }
    }

    fn stats_mut(&mut self) -> &mut ServeStats {
        self.inner.stats_mut()
    }

    fn into_stats(self) -> ServeStats {
        self.inner.into_stats()
    }

    fn preload(&mut self, artifact: &Path) -> Result<WarmStats> {
        if take_budget(&self.chaos.preload_budget) {
            self.chaos.preload_fails.fetch_add(1, Ordering::SeqCst);
            bail!("chaos: injected preload failure");
        }
        self.inner.preload(artifact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_deterministic_and_complete() {
        let cfg =
            ChaosCfg { seed: 7, panics: 3, errors: 4, slows: 2, kills: 2, ..ChaosCfg::default() };
        let a = Chaos::new(cfg);
        let b = Chaos::new(cfg);
        assert_eq!(a.0.schedule, b.0.schedule, "same seed, same schedule");
        assert_eq!(a.0.kill_at, b.0.kill_at);
        let count = |f: Fault| a.0.schedule.iter().filter(|x| **x == Some(f)).count();
        assert_eq!(count(Fault::Panic), 3);
        assert_eq!(count(Fault::Error), 4);
        assert_eq!(count(Fault::Slow), 2);
        assert!(a.0.schedule.len() >= 18, "window auto-extends to 2x faults");
        assert_eq!(a.0.kill_at.len(), 2);
        let c = Chaos::new(ChaosCfg { seed: 8, ..cfg });
        assert_ne!(a.0.schedule, c.0.schedule, "different seed, different schedule");
    }

    #[test]
    fn budgets_fire_exactly_n_times() {
        let chaos = Chaos::new(ChaosCfg { factory_fails: 2, ..ChaosCfg::default() });
        assert!(chaos.factory_gate().is_err());
        assert!(chaos.factory_gate().is_err());
        for _ in 0..10 {
            assert!(chaos.factory_gate().is_ok(), "budget exhausted: always pass");
        }
        assert_eq!(chaos.report().factory_fails, 2);
    }

    #[test]
    fn exhausted_after_schedule_consumed() {
        let chaos = Chaos::new(ChaosCfg { window: 4, ..ChaosCfg::default() });
        assert!(!chaos.exhausted());
        chaos.0.batch_calls.fetch_add(4, Ordering::SeqCst);
        assert!(chaos.exhausted());
    }

    #[test]
    fn corrupt_flips_one_bit_in_second_half() {
        let clean: Vec<u8> = (0..64u8).collect();
        let mut a = clean.clone();
        let mut b = clean.clone();
        corrupt(&mut a, 42);
        corrupt(&mut b, 42);
        assert_eq!(a, b, "deterministic in seed");
        let diffs: Vec<usize> =
            (0..64).filter(|&i| a[i] != clean[i]).collect();
        assert_eq!(diffs.len(), 1, "exactly one byte touched");
        assert!(diffs[0] >= 32, "corruption lands past the header half");
        assert_eq!((a[diffs[0]] ^ clean[diffs[0]]).count_ones(), 1, "single bit");
        corrupt(&mut a, 42);
        assert_eq!(a, clean, "same flip twice round-trips");
        // tiny buffers never panic
        let mut empty: Vec<u8> = Vec::new();
        corrupt(&mut empty, 1);
        let mut one = vec![0u8; 1];
        corrupt(&mut one, 1);
    }
}
