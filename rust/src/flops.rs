//! Analytic adapter-reconstruction FLOPs — reproduces the paper's Appendix
//! A.6 accounting exactly, then applies the same formulas to this repo's
//! scaled models (Table 4's "Generation GFLOPs" column).

use crate::mcnc::GenCfg;

/// NOLA: one generated factor element costs 2·m FLOPs (m-basis combination).
pub fn nola_factor_flops(rows: usize, cols: usize, bases: usize) -> usize {
    2 * bases * rows * cols
}

/// MCNC: generator passes to cover `rows*cols` elements at chunk size d,
/// plus the per-output β scale (paper counts ceil(r·c/d) full passes).
pub fn mcnc_factor_flops(rows: usize, cols: usize, gen: &GenCfg) -> usize {
    let passes = (rows * cols).div_ceil(gen.d);
    passes * 2 * gen.n_weights() + passes * gen.d
}

/// LLaMA-2 shape set from A.6: (n_layers, hidden, intermediate, rank).
pub struct LlamaShape {
    pub layers: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub rank: usize,
}

pub const LLAMA_7B: LlamaShape =
    LlamaShape { layers: 32, hidden: 4096, intermediate: 11008, rank: 8 };
pub const LLAMA_13B: LlamaShape =
    LlamaShape { layers: 40, hidden: 5120, intermediate: 13824, rank: 16 };

/// Per the paper: 4 attention matrices [h, h] + 3 MLP matrices [h, i] per
/// layer; adapters generate factors of size [h, r] (11 of them: 4 attn ×
/// 2? — the paper counts 11 [h,r] and 3 [i,r] per layer).
pub fn llama_total_flops(
    shape: &LlamaShape,
    per_factor: impl Fn(usize, usize) -> usize,
) -> usize {
    shape.layers
        * (11 * per_factor(shape.hidden, shape.rank)
            + 3 * per_factor(shape.intermediate, shape.rank))
}

pub fn paper_nola_7b() -> f64 {
    llama_total_flops(&LLAMA_7B, |r, c| nola_factor_flops(r, c, 64)) as f64
}

pub fn paper_mcnc_7b() -> f64 {
    let gen = GenCfg { k: 5, width: 32, d: 5000, depth: 3, ..GenCfg::default() };
    llama_total_flops(&LLAMA_7B, |r, c| mcnc_factor_flops(r, c, &gen)) as f64
}

pub fn paper_nola_13b() -> f64 {
    llama_total_flops(&LLAMA_13B, |r, c| nola_factor_flops(r, c, 140)) as f64
}

pub fn paper_mcnc_13b() -> f64 {
    let gen = GenCfg { k: 5, width: 32, d: 5000, depth: 3, ..GenCfg::default() };
    llama_total_flops(&LLAMA_13B, |r, c| mcnc_factor_flops(r, c, &gen)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline efficiency claim, derived (not asserted): MCNC needs
    /// ~46% fewer generation FLOPs than NOLA at LLaMA-7B shapes.
    #[test]
    fn reproduces_appendix_a6_7b() {
        let nola = paper_nola_7b();
        let mcnc = paper_mcnc_7b();
        assert!((nola / 1e9 - 2.56).abs() < 0.02, "NOLA 7B: {} GF", nola / 1e9);
        assert!((mcnc / 1e9 - 1.37).abs() < 0.02, "MCNC 7B: {} GF", mcnc / 1e9);
        let saving = 1.0 - mcnc / nola;
        assert!((saving - 0.46).abs() < 0.03, "saving {saving}");
    }

    #[test]
    fn reproduces_appendix_a6_13b() {
        let nola = paper_nola_13b();
        let mcnc = paper_mcnc_13b();
        assert!((nola / 1e9 - 17.53).abs() < 0.2, "NOLA 13B: {} GF", nola / 1e9);
        assert!((mcnc / 1e9 - 4.22).abs() < 0.1, "MCNC 13B: {} GF", mcnc / 1e9);
        assert!(nola / mcnc > 4.0, "13B ratio {}", nola / mcnc);
    }

    #[test]
    fn single_factor_counts_match_paper() {
        // A.6 spot values: NOLA F(4096x8)=4.19 MF, F(11008x8)=11.27 MF;
        // MCNC F(4096x8)=2.29 MF, F(11008x8)=5.89 MF.
        assert_eq!(nola_factor_flops(4096, 8, 64), 4_194_304);
        assert_eq!(nola_factor_flops(11008, 8, 64), 11_272_192);
        let gen = GenCfg { k: 5, width: 32, d: 5000, depth: 3, ..GenCfg::default() };
        let f1 = mcnc_factor_flops(4096, 8, &gen);
        let f2 = mcnc_factor_flops(11008, 8, &gen);
        assert_eq!(f1, 7 * 2 * (5 * 32 + 32 * 32 + 32 * 5000) + 7 * 5000);
        assert_eq!(f2, 18 * 2 * (5 * 32 + 32 * 32 + 32 * 5000) + 18 * 5000);
    }

    #[test]
    fn mcnc_advantage_grows_with_bases() {
        let gen = GenCfg { k: 5, width: 32, d: 5000, depth: 3, ..GenCfg::default() };
        let m64 = nola_factor_flops(4096, 8, 64);
        let m140 = nola_factor_flops(4096, 8, 140);
        let ours = mcnc_factor_flops(4096, 8, &gen);
        assert!(m140 as f64 / ours as f64 > m64 as f64 / ours as f64);
    }
}
