//! Sphere-coverage analysis (paper §3.1, Fig 2): uniform sphere sampling,
//! sliced Wasserstein-2 distance between point clouds, and the paper's
//! uniformity score exp(−τ·W2²).

use crate::util::prng::{tag, Stream};

/// n uniform points on S^{d-1} (normalized Gaussians), row-major [n, d].
pub fn sample_sphere(seed: u64, n: usize, d: usize) -> Vec<f32> {
    let mut z = Stream::sub(seed, tag::SPHERE).normal_f32(n * d, 1.0);
    for row in z.chunks_mut(d) {
        let nrm = row.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt() as f32;
        if nrm > 0.0 {
            for v in row.iter_mut() {
                *v /= nrm;
            }
        }
    }
    z
}

/// n random unit projection directions, row-major [p, d].
pub fn sample_projections(seed: u64, p: usize, d: usize) -> Vec<f32> {
    sample_sphere(seed ^ tag::PROJ, p, d)
}

/// Sliced W2² between clouds x, t (both [n, d]) under p projections.
/// Exact 1-D optimal transport per direction: project, sort, mean sq diff.
pub fn sw2(x: &[f32], t: &[f32], d: usize, proj: &[f32], p: usize) -> f64 {
    let n = x.len() / d;
    let m = t.len() / d;
    assert_eq!(n, m, "clouds must have equal size for the sorted coupling");
    assert_eq!(proj.len(), p * d);
    let mut xs = vec![0.0f32; n];
    let mut ts = vec![0.0f32; n];
    let mut total = 0.0f64;
    for pi in 0..p {
        let dir = &proj[pi * d..(pi + 1) * d];
        for i in 0..n {
            xs[i] = dot(&x[i * d..(i + 1) * d], dir);
            ts[i] = dot(&t[i * d..(i + 1) * d], dir);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut acc = 0.0f64;
        for i in 0..n {
            let diff = (xs[i] - ts[i]) as f64;
            acc += diff * diff;
        }
        total += acc / n as f64;
    }
    total / p as f64
}

/// The paper's Fig-2 uniformity score: exp(−τ·W2²) against a uniform
/// sphere reference of the same cardinality.
pub fn uniformity(points: &[f32], d: usize, tau: f64, seed: u64, n_proj: usize) -> f64 {
    let n = points.len() / d;
    let target = sample_sphere(seed, n, d);
    let proj = sample_projections(seed.wrapping_add(1), n_proj, d);
    let w2sq = sw2(points, &target, d, &proj, n_proj);
    (-tau * w2sq).exp()
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sphere_samples_are_unit() {
        let pts = sample_sphere(1, 100, 5);
        for row in pts.chunks(5) {
            let nrm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((nrm - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn sw2_zero_for_identical() {
        let x = sample_sphere(2, 64, 3);
        let proj = sample_projections(3, 16, 3);
        assert!(sw2(&x, &x, 3, &proj, 16) < 1e-12);
    }

    #[test]
    fn sw2_symmetricish() {
        let x = sample_sphere(4, 64, 3);
        let t = sample_sphere(5, 64, 3);
        let proj = sample_projections(6, 16, 3);
        let a = sw2(&x, &t, 3, &proj, 16);
        let b = sw2(&t, &x, 3, &proj, 16);
        assert!((a - b).abs() < 1e-9);
        assert!(a > 0.0);
    }

    #[test]
    fn uniform_cloud_scores_high_collapsed_low() {
        let uni = sample_sphere(7, 256, 3);
        let mut collapsed = vec![0.0f32; 256 * 3];
        for i in 0..256 {
            collapsed[i * 3] = 1.0; // all mass at one pole
        }
        let u_uni = uniformity(&uni, 3, 10.0, 11, 32);
        let u_col = uniformity(&collapsed, 3, 10.0, 11, 32);
        assert!(u_uni > 0.9, "uniform cloud scored {u_uni}");
        assert!(u_col < 0.5 * u_uni, "collapsed {u_col} vs uniform {u_uni}");
    }

    #[test]
    fn two_sample_noise_floor_small() {
        // two independent uniform clouds: SW2 ≈ O(1/n), far below collapse
        let a = sample_sphere(8, 512, 3);
        let b = sample_sphere(9, 512, 3);
        let proj = sample_projections(10, 32, 3);
        assert!(sw2(&a, &b, 3, &proj, 32) < 0.01);
    }
}
