//! # MCNC — Manifold-Constrained Reparameterization for Neural Compression
//!
//! Rust + JAX + Pallas reproduction of Thrash et al., ICLR 2025.
//!
//! Three layers (see DESIGN.md):
//! * **L1** — Pallas generator kernel (`python/compile/kernels/`), lowered
//!   into every compressed executable.
//! * **L2** — jax model/method graphs, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the coordinator that trains, serves and benchmarks
//!   compressed models through the PJRT CPU client. Python never runs on
//!   the request path.

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod flops;
pub mod mcnc;
pub mod runtime;
pub mod sphere;
pub mod tensor;
pub mod train;
pub mod util;
