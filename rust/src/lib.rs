//! # MCNC — Manifold-Constrained Reparameterization for Neural Compression
//!
//! Rust + JAX + Pallas reproduction of Thrash et al., ICLR 2025 — see
//! README.md for the quickstart and ARCHITECTURE.md for the dataflow.
//!
//! Three layers:
//! * **L1** — Pallas generator kernel (`python/compile/kernels/`), lowered
//!   into every compressed executable.
//! * **L2** — jax model/method graphs, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the coordinator that trains, serves and benchmarks
//!   compressed models through the PJRT CPU client. Python never runs on
//!   the request path.
//!
//! Native-side module map (each module's own header goes deeper):
//!
//! * [`mcnc`] — the paper's core: generator φ, chunk partitioning, and the
//!   SIMD-dispatched GEMM microkernel layer ([`mcnc::kernel`]).
//! * [`coordinator`] — sharded multi-task adapter serving: router, dynamic
//!   batcher, engine shards with per-request fault isolation, caches.
//! * [`codec`] — the MCNC2 compressed checkpoint wire format (quantizer,
//!   rANS, framed container, streaming adapters).
//! * [`train`] / [`runtime`] — training orchestration and the PJRT
//!   boundary (stubbed offline behind the `pjrt` feature).
//! * [`net`] — the MCNP1 framed socket protocol and nonblocking serving
//!   loop exposing the coordinator to remote clients (`mcnc serve
//!   --listen`; byte-level spec in docs/PROTOCOL.md).
//! * [`obs`] — observability: the metrics registry, request tracing, and
//!   Prometheus / Chrome-trace exporters (callable from every layer; see
//!   docs/OBSERVABILITY.md for the metric catalog).
//! * [`baselines`], [`sphere`], [`flops`], [`data`] — paper comparisons
//!   and analyses.
//! * [`util`] — in-tree substrates: JSON, CLI, config, PRNG, thread pool
//!   ([`util::threadpool`], sized by `--threads` / `MCNC_THREADS`),
//!   property testing, bench harness.

// The `pjrt` feature swaps `runtime/xla_stub.rs` for the real `xla` crate,
// whose dependency line is commented out in Cargo.toml (this workspace
// builds offline). Fail fast with instructions instead of E0433 noise if
// someone enables the feature (e.g. `--all-features`) without the dep.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature needs the `xla` crate: uncomment its dependency in \
     Cargo.toml (network + libxla required), then delete this guard in \
     rust/src/lib.rs"
);

pub mod baselines;
pub mod codec;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod flops;
pub mod mcnc;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod sphere;
pub mod tensor;
pub mod train;
pub mod util;
