//! # MCNC — Manifold-Constrained Reparameterization for Neural Compression
//!
//! Rust + JAX + Pallas reproduction of Thrash et al., ICLR 2025.
//!
//! Three layers (see DESIGN.md):
//! * **L1** — Pallas generator kernel (`python/compile/kernels/`), lowered
//!   into every compressed executable.
//! * **L2** — jax model/method graphs, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the coordinator that trains, serves and benchmarks
//!   compressed models through the PJRT CPU client. Python never runs on
//!   the request path.

// The `pjrt` feature swaps `runtime/xla_stub.rs` for the real `xla` crate,
// whose dependency line is commented out in Cargo.toml (this workspace
// builds offline). Fail fast with instructions instead of E0433 noise if
// someone enables the feature (e.g. `--all-features`) without the dep.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature needs the `xla` crate: uncomment its dependency in \
     Cargo.toml (network + libxla required), then delete this guard in \
     rust/src/lib.rs"
);

pub mod baselines;
pub mod codec;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod flops;
pub mod mcnc;
pub mod runtime;
pub mod sphere;
pub mod tensor;
pub mod train;
pub mod util;
